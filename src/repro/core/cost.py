"""Three-term roofline cost model.

SystemML's optimizer is *cost-based*: it compares candidate plans with an
analytic cost model before emitting one. Ours scores each candidate plan
with the three roofline terms used throughout EXPERIMENTS.md:

    compute term    = FLOPs            / (chips x peak_FLOP/s)
    memory term     = HBM bytes        / (chips x HBM_bw)
    collective term = collective bytes / (chips x link_bw)

Two entry points:

* :func:`analytic_cost` — napkin-math terms from the model config alone
  (planner-side, used to *choose* plans).
* :func:`roofline_terms` — the same three terms from *measured* numbers
  (``compiled.cost_analysis()`` + HLO-parsed collective bytes), used by
  ``launch.roofline`` to *report* plans.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

from repro.config import HardwareSpec, InputShape, MeshConfig, ModelConfig
from repro.core.memory import (ACT_BYTES, PARAM_BYTES, _cache_dense_bytes,
                               _cache_eff_seq, dtype_bytes)
from repro.core.strategies import PlanConfig

# Paged-kernel grid dispatch cost per (layer, row, kv-head, page) grid step,
# in seconds. TPU grid steps are pipelined DMAs, not kernel launches, so the
# constant is tens of nanoseconds — but it keeps the selection a genuine
# comparison (SystemML-style operator selection by data characteristics,
# not a fixed winner): a bucket with many tiny pages pays it linearly.
PAGED_STEP_LATENCY_S = 2e-8


@dataclass(frozen=True)
class CostTerm:
    """One named addend of the analytic model, queryable by the auditors.

    ``physical`` distinguishes bytes that actually cross the HBM interface
    from latency folded into byte currency (the paged-kernel grid-dispatch
    term): a jaxpr-derived traffic bound can only be compared against the
    physical subtotal, never the folded-latency one.
    """

    name: str
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    physical: bool = True


@dataclass
class CostEstimate:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    model_flops: float = 0.0
    # the named addends behind flops / hbm_bytes (empty for measured
    # estimates from roofline_terms: measurement has no decomposition)
    terms: List[CostTerm] = field(default_factory=list)

    def term(self, name: str) -> CostTerm:
        for t in self.terms:
            if t.name == name:
                return t
        return CostTerm(name)

    def physical_hbm_bytes(self) -> float:
        """HBM bytes excluding folded-latency terms — the quantity a
        traffic bound derived from the program can be compared against.
        Falls back to ``hbm_bytes`` when no decomposition is recorded."""
        if not self.terms:
            return self.hbm_bytes
        return sum(t.hbm_bytes for t in self.terms if t.physical)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic overlap model: max of terms (lower bound on step time)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def summary(self) -> str:
        return (
            f"cost/chip: compute={self.compute_s * 1e3:.3f}ms "
            f"memory={self.memory_s * 1e3:.3f}ms "
            f"collective={self.collective_s * 1e3:.3f}ms "
            f"dominant={self.dominant} "
            f"useful_flops={100 * self.useful_flops_ratio:.1f}%"
        )


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    collective_bytes: float,
    chips: int,
    hw: HardwareSpec,
    model_flops: float = 0.0,
    per_chip: bool = False,
) -> CostEstimate:
    """Terms in seconds. ``flops``/``hbm_bytes`` are global unless
    ``per_chip`` (XLA's cost_analysis on an SPMD module is per-chip)."""
    div = 1 if per_chip else chips
    return CostEstimate(
        compute_s=flops / (div * hw.peak_flops),
        memory_s=hbm_bytes / (div * hw.hbm_bandwidth),
        collective_s=collective_bytes / (div * hw.ici_bandwidth),
        flops=flops / div * chips if per_chip else flops,
        hbm_bytes=hbm_bytes / div * chips if per_chip else hbm_bytes,
        collective_bytes=collective_bytes,
        model_flops=model_flops,
    )


# ---------------------------------------------------------------------------
# Analytic (planner-side) estimators
# ---------------------------------------------------------------------------


def model_flops_per_step(model: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE); forward-only kinds
    use 2 N D. Decode processes one token per sequence."""
    n = model.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: 1 new token / sequence


def _attention_flops(model: ModelConfig, shape: InputShape) -> float:
    """Quadratic attention FLOPs not captured by 6ND."""
    pat = model.layer_pattern()
    n_attn = pat.count("a")
    hd = model.num_heads * model.head_dim
    s = shape.seq_len
    if shape.kind == "decode":
        # one query against S cached keys
        win = model.window_size or (model.serve_window if s > 262_144 else s)
        per_layer = 4.0 * shape.global_batch * min(s, win) * hd
        mult = 1.0
    else:
        win = model.window_size or s
        per_layer = 4.0 * shape.global_batch * s * min(s, win) * hd / 2  # causal
        mult = 3.0 if shape.kind == "train" else 1.0
    flops = n_attn * per_layer * mult
    if model.is_encdec and shape.kind != "decode":
        flops += model.encoder_layers * 4.0 * shape.global_batch * model.encoder_seq**2 * hd
    return flops


def decode_attention_traffic(
    model: ModelConfig,
    shape: InputShape,
    kernel: str,
    committed_frac: float = 1.0,
    nb: int = ACT_BYTES,
    donated: bool = True,
) -> float:
    """Decode-attention HBM bytes for one physical operator choice.

    The three operators move very different amounts of cache-sized data
    per decode step (C = committed KV bytes at ``nb`` bytes/element,
    g = query heads per kv head):

    - ``paged``:  the fused kernel streams committed pages straight from
      the slot stack — C * committed_frac, no intermediates.
    - ``gather``: jnp indexing materializes the gathered copy (write) and
      the GQA-expanded copy (write + read) on top of the base stream:
      (2 + 2g) * C, uncommitted bucket slots included regardless of pos.
    - ``ref``:    the oracle path, same shape of traffic in fp32: 2x gather.

    ``donated=False`` adds the full cache write-back C: an un-donated step
    materializes a fresh output copy of the arena every tick, where the
    donated step writes only the new token's slice in place. Kernel
    *selection* never passes it (the write-back is identical for every
    operator, so it cannot move the crossover — the donation-independence
    invariant ``cost_audit`` certifies); the planner's per-plan traffic
    statistic does.
    """
    c = _cache_dense_bytes(model, shape.seq_len, shape.global_batch, nb=nb)
    if kernel == "paged":
        t = c * committed_frac
    else:
        mult = 2.0 + 2.0 * model.q_per_kv
        if kernel == "ref":
            mult *= 2.0
        t = c * mult
    if not donated:
        t += c
    return t


def _paged_grid_steps(model: ModelConfig, shape: InputShape, page: int) -> float:
    """Grid steps per decode step: one per (attn layer, row, kv head, page)."""
    n_attn = model.layer_pattern().count("a")
    pages = -(-_cache_eff_seq(model, shape.seq_len) // page)
    return n_attn * shape.global_batch * model.num_kv_heads * pages


def decode_kernel_seconds(
    model: ModelConfig,
    shape: InputShape,
    hw: HardwareSpec,
    kernel: str,
    page: int,
    committed_frac: float = 1.0,
) -> float:
    """Analytic decode-attention term (seconds) for one operator choice.

    This is the quantity :class:`~repro.core.planner.PlanCompiler` compares
    to *choose* the decode kernel per bucket: page count, window (via the
    effective cached sequence), batch, and head dims all enter.
    """
    t = decode_attention_traffic(model, shape, kernel, committed_frac) / hw.hbm_bandwidth
    if kernel == "paged" and page > 0:
        t += _paged_grid_steps(model, shape, page) * PAGED_STEP_LATENCY_S
    return t


def analytic_cost(
    model: ModelConfig,
    shape: InputShape,
    mesh: MeshConfig,
    plan: PlanConfig,
    hw: HardwareSpec,
    page: int = 0,
    dtype: str = "bfloat16",
) -> CostEstimate:
    """Planner-side cost statistic, decomposed into named :class:`CostTerm`
    addends so ``repro.analysis.cost_audit`` can sandwich each aggregate
    between jaxpr-derived bounds (and exclude the folded-latency dispatch
    term from traffic comparisons). ``dtype`` is the compute dtype the
    byte-sized terms are priced at — the serving stack runs bf16 and fp32
    streams through the same planner, and an fp32 plan moves twice the
    bytes per element."""
    chips = mesh.num_devices
    nb = dtype_bytes(dtype)
    mf = model_flops_per_step(model, shape)
    terms: List[CostTerm] = [CostTerm("model_matmul", flops=mf)]
    attn = _attention_flops(model, shape)
    if attn:
        terms.append(CostTerm("attention", flops=attn))
    if shape.kind == "train" and plan.remat:
        # one extra forward
        terms.append(CostTerm("remat_recompute", flops=(mf + attn) / 3.0))

    p_bytes = model.param_count() * max(PARAM_BYTES, nb)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    terms.append(CostTerm(
        "params_stream", hbm_bytes=p_bytes * (3 if shape.kind == "train" else 1)))
    terms.append(CostTerm(
        "activations",
        hbm_bytes=tokens * model.d_model * nb * model.num_layers * 6))
    terms.append(CostTerm(
        "logits_write", hbm_bytes=tokens * model.vocab_size * nb))
    if shape.kind == "decode":
        terms.append(CostTerm(
            "decode_attention",
            hbm_bytes=decode_attention_traffic(
                model, shape, plan.decode_kernel, nb=nb,
                donated=plan.donate_cache)))
        if plan.decode_kernel == "paged" and page > 0:
            # grid dispatch overhead, folded in as equivalent HBM bytes so
            # the roofline terms stay in one currency — latency, not
            # physical traffic (physical=False keeps it out of the
            # jaxpr-derived traffic sandwich)
            terms.append(CostTerm(
                "paged_dispatch", physical=False,
                hbm_bytes=_paged_grid_steps(model, shape, page)
                * PAGED_STEP_LATENCY_S * hw.hbm_bandwidth))

    coll = _collective_bytes(model, shape, mesh, plan)
    if coll:
        terms.append(CostTerm("collectives", collective_bytes=coll))
    est = roofline_terms(sum(t.flops for t in terms),
                         sum(t.hbm_bytes for t in terms),
                         coll, chips, hw, model_flops=mf)
    est.terms = terms
    return est


def _collective_bytes(
    model: ModelConfig, shape: InputShape, mesh: MeshConfig, plan: PlanConfig
) -> float:
    """Per-chip collective traffic estimate for the candidate plan."""
    p_bytes = model.param_count() * PARAM_BYTES
    mp = mesh.model_parallelism
    dp = mesh.data_parallelism
    total = 0.0
    if shape.kind == "train" and plan.batch_axes:
        if plan.params_over_data:
            # FSDP: all-gather fwd + all-gather bwd + reduce-scatter grads
            total += 3 * p_bytes / (mp if plan.tensor_parallel else 1)
        else:
            # DP: ring all-reduce of full grads ~ 2x payload
            total += 2 * p_bytes / (mp if plan.tensor_parallel else 1)
    if plan.tensor_parallel:
        tokens_dev = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1) / max(1, dp)
        per_layer = 2 * tokens_dev * model.d_model * ACT_BYTES  # 2 allreduce/layer
        mult = 2 if shape.kind == "train" else 1
        total += model.num_layers * per_layer * mult
    if plan.expert_parallel and model.num_experts:
        tokens_dev = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1) / max(1, dp)
        # all-to-all dispatch + combine, fwd (+bwd for train)
        mult = 4 if shape.kind == "train" else 2
        total += model.num_layers * tokens_dev * model.d_model * ACT_BYTES * mult * (
            model.experts_per_token / max(1, model.experts_per_token)
        )
    return total
