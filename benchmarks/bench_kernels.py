"""Kernel micro-benchmarks (paper §3 "Native BLAS Exploitation"/"GPU
Backend"). On this CPU container the Pallas path runs interpreted (not
timed); we time the XLA fallback operator and report the kernel's
structural roofline: per-block VMEM bytes and arithmetic intensity —
the quantities that determine MXU utilization on the v5e target."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.config import TPU_V5E
from repro.kernels import ref


def _time(fn, *args, reps=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # matmul 1024^3, MXU tile 128: per-block VMEM = bm*bk + bk*bn + bm*bn(f32)
    a = jax.random.normal(key, (1024, 1024), jnp.bfloat16)
    b = jax.random.normal(key, (1024, 1024), jnp.bfloat16)
    us = _time(jax.jit(ref.matmul_ref), a, b)
    vmem = (128 * 128 * 2) * 2 + 128 * 128 * 4
    ai = (2 * 1024**3) / (2 * 2 * 1024 * 1024)
    rows.append(f"kernel_matmul_1024,{us:.1f},vmem_block={vmem};intensity={ai:.0f};"
                f"vmem_ok={vmem < TPU_V5E.vmem_bytes}")

    # flash attention 2x8x1024x64
    q = jax.random.normal(key, (2, 8, 1024, 64), jnp.bfloat16)
    us = _time(jax.jit(lambda q: ref.attention_ref(q, q, q)), q)
    vmem = (128 * 64 * 2) * 3 + 128 * 128 * 4 + 128 * 64 * 4
    rows.append(f"kernel_flash_attn_1k,{us:.1f},vmem_block={vmem};"
                f"vmem_ok={vmem < TPU_V5E.vmem_bytes}")

    # ssd scan: mamba2-like (chunked BLAS-3 form)
    B, S, H, P, N = 2, 512, 8, 64, 128
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    av = -jnp.exp(jax.random.normal(ks[2], (H,)))
    bm = jax.random.normal(ks[3], (B, S, N))
    cm = jax.random.normal(ks[4], (B, S, N))
    d = jnp.ones((H,))
    seq = jax.jit(lambda *a: ref.ssd_ref(*a)[0])
    chk = jax.jit(lambda *a: ref.ssd_chunked_ref(*a, chunk=64)[0])
    us_seq = _time(seq, x, dt, av, bm, cm, d, reps=3)
    us_chk = _time(chk, x, dt, av, bm, cm, d, reps=3)
    rows.append(f"kernel_ssd_sequential,{us_seq:.1f},form=scan")
    rows.append(f"kernel_ssd_chunked,{us_chk:.1f},form=blas3;"
                f"speedup={us_seq / us_chk:.2f}x")

    # conv2d im2col (the paper's lowering)
    x = jax.random.normal(key, (8, 16, 32, 32), jnp.float32)
    w = jax.random.normal(key, (32, 16, 3, 3), jnp.float32)
    us = _time(jax.jit(lambda x, w: ref.conv2d_ref(x, w, 1, 1)), x, w)
    rows.append(f"kernel_conv2d_im2col,{us:.1f},lowering=im2col")
    return rows
