"""Loss layers, SystemML ``nn/layers/*_loss.dml`` style: forward returns the
scalar loss, backward returns dScores."""

from __future__ import annotations

import jax.numpy as jnp


class cross_entropy_loss:
    """Expects probabilities (post-softmax), one-hot targets — exactly
    SystemML's nn/layers/cross_entropy_loss.dml."""

    eps = 1e-10

    @staticmethod
    def forward(probs, y):
        n = probs.shape[0]
        return -jnp.sum(y * jnp.log(probs + cross_entropy_loss.eps)) / n

    @staticmethod
    def backward(probs, y):
        n = probs.shape[0]
        return -(y / (probs + cross_entropy_loss.eps)) / n


class softmax_cross_entropy:
    """Fused logits->loss (numerically stable; used by the big models)."""

    @staticmethod
    def forward(logits, y):
        n = logits.shape[0]
        z = logits - jnp.max(logits, axis=1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
        return -jnp.sum(y * (z - lse)) / n

    @staticmethod
    def backward(logits, y):
        n = logits.shape[0]
        z = logits - jnp.max(logits, axis=1, keepdims=True)
        p = jnp.exp(z) / jnp.sum(jnp.exp(z), axis=1, keepdims=True)
        return (p - y) / n


class l2_loss:
    @staticmethod
    def forward(pred, y):
        n = pred.shape[0]
        return 0.5 * jnp.sum((pred - y) ** 2) / n

    @staticmethod
    def backward(pred, y):
        n = pred.shape[0]
        return (pred - y) / n


class log_loss:
    eps = 1e-10

    @staticmethod
    def forward(pred, y):
        n = pred.shape[0]
        e = log_loss.eps
        return -jnp.sum(y * jnp.log(pred + e) + (1 - y) * jnp.log(1 - pred + e)) / n

    @staticmethod
    def backward(pred, y):
        n = pred.shape[0]
        e = log_loss.eps
        return (-(y / (pred + e)) + (1 - y) / (1 - pred + e)) / n


class l2_reg:
    @staticmethod
    def forward(w, lam):
        return 0.5 * lam * jnp.sum(w * w)

    @staticmethod
    def backward(w, lam):
        return lam * w


class l1_reg:
    @staticmethod
    def forward(w, lam):
        return lam * jnp.sum(jnp.abs(w))

    @staticmethod
    def backward(w, lam):
        return lam * jnp.sign(w)
