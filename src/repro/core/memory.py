"""Worst-case memory estimation (paper §3, "Distributed Operations").

SystemML compiles a single-node plan "if the input, output and intermediate
matrices fit in the driver JVM" and escalates to a distributed plan
otherwise. The estimator here plays the same role for the TPU mesh: given a
(model x shape x mesh) and a candidate :class:`PlanConfig`, compute the
worst-case **per-chip HBM bytes** for every tensor class. The planner
escalates through the plan lattice until the estimate fits the HBM budget.

Estimates are deliberately *worst-case* (SystemML's estimator is too): they
must never under-estimate, or a "fitting" plan OOMs at runtime.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.config import HardwareSpec, InputShape, MeshConfig, ModelConfig, TrainConfig
from repro.core.strategies import PlanConfig

ACT_BYTES = 2       # bf16 default (cost-model roofline terms)
PARAM_BYTES = 2     # bf16 default

# serving/training dtype -> bytes per element. The estimator threads the
# *actual* compute dtype through every tensor class instead of assuming
# bf16: an fp32 server's first estimate must already be fp32-sized, or the
# first request in every bucket burns a corrective recompile.
DTYPE_BYTES = {
    "float64": 8,
    "float32": 4,
    "bfloat16": 2,
    "float16": 2,
    "float8_e4m3fn": 1,
    "float8_e5m2": 1,
    "int8": 1,
}


def dtype_bytes(dtype: str) -> int:
    """Bytes per element for a dtype name (worst-case 4 for unknown names:
    the estimator must never under-estimate)."""
    return DTYPE_BYTES.get(str(dtype), 4)

# optimizer -> number of per-param state slots (repro.nn.optim)
OPTIMIZER_SLOTS = {
    "sgd": 0,
    "sgd_momentum": 1,
    "sgd_nesterov": 1,
    "adagrad": 1,
    "rmsprop": 1,
    "adam": 2,
}


@dataclass
class MemoryEstimate:
    per_device: Dict[str, float] = field(default_factory=dict)
    budget: int = 0

    @property
    def total(self) -> float:
        return sum(self.per_device.values())

    def fits(self, headroom: float = 0.9) -> bool:
        return self.total <= self.budget * headroom

    def scaled(self, factor: float) -> "MemoryEstimate":
        """Runtime-corrected copy: every tensor-class estimate multiplied by
        the observed/estimated correction factor. Dynamic recompilation
        replaces compile-time worst-case statistics with these."""
        return MemoryEstimate(
            per_device={k: v * factor for k, v in self.per_device.items()},
            budget=self.budget,
        )

    def summary(self) -> str:
        gib = 1024**3
        parts = "  ".join(f"{k}={v / gib:.2f}GiB" for k, v in self.per_device.items())
        return (
            f"memory/chip: total={self.total / gib:.2f}GiB "
            f"budget={self.budget / gib:.1f}GiB fits={self.fits()}  [{parts}]"
        )


def _opt_bytes_per_param(optimizer: str, opt_dtype: str) -> float:
    slots = OPTIMIZER_SLOTS.get(optimizer, 2)
    slot_bytes = 4 if opt_dtype == "float32" else 2
    # fp32 master copy kept only with fp32 optimizer state (mixed precision)
    master = 4 if opt_dtype == "float32" else 0
    return slots * slot_bytes + master


def _param_divisors(plan: PlanConfig, mesh: MeshConfig) -> float:
    div = 1.0
    if plan.tensor_parallel or plan.expert_parallel:
        div *= mesh.model_parallelism
    if plan.params_over_data:
        div *= mesh.data_parallelism
    return div


def estimate_memory(
    model: ModelConfig,
    shape: InputShape,
    mesh: MeshConfig,
    plan: PlanConfig,
    train: TrainConfig,
    hw: HardwareSpec,
    dtype: str = "bfloat16",
    cache_pool_arenas: int = 1,
    cache_pages: int = 0,
    cache_page_size: int = 0,
    donate_cache: bool = True,
) -> MemoryEstimate:
    """``dtype`` is the actual compute dtype (params + activations + grads +
    KV cache); compile-time statistics follow it instead of assuming bf16.

    ``cache_pool_arenas`` sizes the decode KV-cache statistic for a
    row-addressable cache pool (``repro.runtime.kv_cache``) provisioned for
    that many concurrent bucket arenas; 1 is the single-blob behaviour. The
    pool's live bytes at runtime are checked against this compile-time
    statistic by the dynamic-recompilation predicate.

    ``cache_pages``/``cache_page_size`` switch the decode cache statistic
    to block granularity: the attention K/V term is sized as ``cache_pages``
    fixed-size pages (what a paged pool can physically commit — see
    :func:`cache_page_count`) instead of ``arenas x bucket`` dense blobs,
    while per-row recurrent state still scales with the arena count. The
    paged pool's page-exact live bytes are compared against exactly this.

    ``donate_cache=False`` charges the ``kv_double_buffer`` class: a step
    compiled without buffer donation transiently holds a second full copy
    of the group's arena (XLA writes the output cache next to the input
    one). Donated plans — the default — update in place, which
    ``repro.analysis.memory_audit`` certifies from the executable's
    input-output aliasing."""
    nb = dtype_bytes(dtype)
    est = MemoryEstimate(budget=hw.hbm_bytes)
    p = model.param_count()
    # ~1.5% of params (norm scales, biases, router, A/dt vectors) do not shard
    # over the model axis; they still shard over data under FSDP.
    non_shardable = max(0.015 * p, 2 * model.d_model * model.num_layers)
    shardable = p - non_shardable

    mp = mesh.model_parallelism if (plan.tensor_parallel or plan.expert_parallel) else 1
    dp_div = mesh.data_parallelism if plan.params_over_data else 1

    params_dev = (shardable / (mp * dp_div) + non_shardable / dp_div) * nb
    est.per_device["params"] = params_dev

    dp = mesh.data_parallelism if plan.batch_axes else 1

    if shape.kind == "train":
        est.per_device["grads"] = params_dev
        est.per_device["opt_state"] = (
            params_dev / nb * _opt_bytes_per_param(train.optimizer, plan.opt_state_dtype)
        )
        est.per_device["activations"] = _train_activation_bytes(model, shape, plan, dp, mp, nb)
    elif shape.kind == "prefill":
        est.per_device["activations"] = _prefill_activation_bytes(model, shape, plan, dp, mp, nb)
    else:  # decode
        if cache_pages and cache_page_size:
            est.per_device["kv_cache"] = _cache_paged_bytes(
                model, shape, plan, mesh, nb, cache_pages, cache_page_size,
                max(1, cache_pool_arenas))
        else:
            est.per_device["kv_cache"] = (max(1, cache_pool_arenas)
                                          * _cache_bytes(model, shape, plan, mesh, nb))
        if not donate_cache:
            # un-donated tick: the step's cache output is a fresh buffer
            # the size of one full arena (paged output stacks allocate at
            # capacity regardless of page commitment), live next to the
            # input copy until the arena re-adopts it
            est.per_device["kv_double_buffer"] = _cache_bytes(
                model, shape, plan, mesh, nb)
        est.per_device["activations"] = _decode_activation_bytes(model, shape, dp, mp, nb)

    est.per_device["workspace"] = 0.08 * sum(est.per_device.values())
    return est


# ---------------------------------------------------------------------------
# per-kind activation estimates
# ---------------------------------------------------------------------------


def _layer_working_cols(model: ModelConfig, mp: int, variant: str) -> float:
    """Per-token working-set width (columns) of one layer's live tensors,
    assuming flash attention (no S^2 score materialization)."""
    d = model.d_model
    cols = 4.0 * d  # residual stream, norm output, block in/out
    pat = model.layer_pattern()
    # use the widest layer kind present (worst case)
    widths = []
    for kind in set(pat):
        if kind == "a":
            qkv = model.num_heads * model.head_dim + 2 * model.num_kv_heads * model.head_dim
            ffn = 3 * model.d_ff
            moe_expand = 0.0
            if model.num_experts:
                # top-k routed expert activations per token (model-sharded)
                ffn = 3 * model.d_ff * model.experts_per_token + model.num_experts
                # dispatch expansion: k copies of each token's d_model row in
                # the (tokens*k, d) gather buffers — NOT model-sharded, and
                # several live at once through fwd+bwd (x4)
                moe_expand = 4.0 * model.experts_per_token * d
            widths.append((qkv + ffn) / mp + 2 * model.num_heads * model.head_dim / mp
                          + moe_expand)
        elif kind == "s":
            widths.append((2 * model.d_inner + 2 * model.ssm_state + model.ssm_num_heads) / mp + model.d_inner / mp)
        elif kind == "r":
            w = model.lru_width or d
            widths.append(4.0 * w / mp)
    return cols + (max(widths) if widths else 0.0)


def _train_activation_bytes(
    model: ModelConfig, shape: InputShape, plan: PlanConfig, dp: int, mp: int,
    nb: int = ACT_BYTES,
) -> float:
    b_dev = max(1, shape.global_batch // dp)
    b_micro = max(1, b_dev // plan.microbatches)
    s = shape.seq_len
    tok = b_micro * s
    if plan.remat:
        # scan carries one residual-stream checkpoint per layer + one layer's
        # recomputation working set + logits chunk
        ckpt_div = mp if plan.seq_shard_checkpoints else 1
        saved = model.num_layers * tok * model.d_model * nb / ckpt_div
        working = tok * _layer_working_cols(model, mp, plan.attention_variant) * nb
    else:
        saved = model.num_layers * tok * _layer_working_cols(model, mp, plan.attention_variant) * nb
        working = 0.0
    # loss computed over vocab shard (vocab is model-sharded under TP)
    logits = tok * (model.vocab_size / mp) * nb
    if model.is_encdec:
        enc_tok = b_micro * model.encoder_seq
        saved += model.encoder_layers * enc_tok * model.d_model * nb
    return saved + working + logits


def _prefill_activation_bytes(
    model: ModelConfig, shape: InputShape, plan: PlanConfig, dp: int, mp: int,
    nb: int = ACT_BYTES,
) -> float:
    b_dev = max(1, shape.global_batch // dp)
    # context parallelism: seq dim itself sharded (KV all-gathered per layer)
    sp = mp if plan.seq_axes else 1
    tok = b_dev * shape.seq_len // sp
    # forward-only: a few live layer boundaries + one working set + the
    # KV cache being produced
    live = 3 * tok * model.d_model * nb
    working = tok * _layer_working_cols(model, mp, plan.attention_variant) * nb
    kv = _cache_dense_bytes(model, shape.seq_len, b_dev, nb) / (
        mp if (plan.tensor_parallel or plan.seq_axes) else 1)
    if plan.seq_axes:
        # one layer's all-gathered K/V working copy
        working += b_dev * shape.seq_len * 2 * model.num_kv_heads * model.head_dim * nb
    logits = b_dev * max(1, model.vocab_size // mp) * nb  # last-token logits
    return live + working + kv + logits


def _decode_activation_bytes(model: ModelConfig, shape: InputShape, dp: int, mp: int,
                             nb: int = ACT_BYTES) -> float:
    b_dev = max(1, shape.global_batch // dp)
    per_tok = _layer_working_cols(model, mp, "full") + model.vocab_size / mp
    return b_dev * per_tok * nb * 4  # x4: double-buffering + fudge


# ---------------------------------------------------------------------------
# KV / recurrent-state cache
# ---------------------------------------------------------------------------


def _cache_dense_bytes(model: ModelConfig, seq: int, batch: int,
                       nb: int = ACT_BYTES) -> float:
    """Un-sharded cache bytes for one full attention stack: the attention
    K/V slots plus the sequence-O(1) recurrent/cross state — the same two
    terms the paged estimate sizes, so dense and paged statistics can never
    drift apart."""
    return (batch * _cache_eff_seq(model, seq) * _kv_slot_bytes(model, nb)
            + _cache_recurrent_bytes(model, batch, nb))


def _cache_eff_seq(model: ModelConfig, seq: int) -> int:
    """Cache slots per attention row for a ``seq`` context (window-aware)."""
    if model.window_size:
        return min(seq, model.window_size)
    if model.serve_window and seq > 262_144:
        return min(seq, model.serve_window)
    return seq


def _kv_slot_bytes(model: ModelConfig, nb: int = ACT_BYTES) -> float:
    """Bytes of one K/V cache slot across every attention layer."""
    kv_width = 2 * model.num_kv_heads * model.head_dim
    return model.layer_pattern().count("a") * kv_width * nb


def _cache_recurrent_bytes(model: ModelConfig, batch: int,
                           nb: int = ACT_BYTES) -> float:
    """Per-arena bytes of the sequence-O(1) cache entries (SSD state, conv
    tails, RG-LRU state, enc-dec cross K/V) — the part paging cannot touch."""
    total = 0.0
    for kind in model.layer_pattern():
        if kind == "s":
            st = model.ssm_num_heads * model.ssm_head_dim * model.ssm_state
            conv = model.ssm_conv_width * (model.d_inner + 2 * model.ssm_state)
            total += batch * (st + conv) * nb
        elif kind == "r":
            w = model.lru_width or model.d_model
            total += batch * w * 4  # RG-LRU state kept fp32 regardless
    if model.is_encdec:
        kv_width = 2 * model.num_kv_heads * model.head_dim
        total += model.num_layers * batch * model.encoder_seq * kv_width * nb
    return total


def cache_page_count(model: ModelConfig, seq: int, batch: int,
                     page: int) -> int:
    """Physical pages one (batch, seq) paged arena provisions:
    ``batch * ceil(eff_seq / page)`` (0 for families with no attention)."""
    if page <= 0 or model.layer_pattern().count("a") == 0:
        return 0
    return batch * -(-_cache_eff_seq(model, seq) // page)


def _cache_divisors(model: ModelConfig, shape: InputShape, plan: PlanConfig,
                    mesh: MeshConfig):
    batch_div = 1
    for ax, sz in zip(mesh.axis_names, mesh.shape):
        if ax in plan.cache_batch_axes:
            batch_div *= sz
    batch_div = min(batch_div, shape.global_batch)
    div = 1
    if plan.cache_heads_over_model:
        div *= mesh.model_parallelism
    for ax, sz in zip(mesh.axis_names, mesh.shape):
        if ax in plan.cache_seq_axes:
            div *= sz
    return batch_div, div


def _cache_bytes(model: ModelConfig, shape: InputShape, plan: PlanConfig, mesh: MeshConfig,
                 nb: int = ACT_BYTES) -> float:
    batch_div, div = _cache_divisors(model, shape, plan, mesh)
    b = max(1, shape.global_batch // batch_div)
    return _cache_dense_bytes(model, shape.seq_len, b, nb) / div


def _cache_paged_bytes(model: ModelConfig, shape: InputShape, plan: PlanConfig,
                       mesh: MeshConfig, nb: int, pages: int, page: int,
                       arenas: int) -> float:
    """Worst-case per-chip bytes of a block-granular cache pool provisioned
    with ``pages`` physical pages (across all arenas) plus ``arenas`` worth
    of per-row recurrent state. Shards like the dense cache estimate."""
    batch_div, div = _cache_divisors(model, shape, plan, mesh)
    b = max(1, shape.global_batch // batch_div)
    page_frac = b / max(1, shape.global_batch)   # pages follow the batch shard
    attn = pages * page_frac * page * _kv_slot_bytes(model, nb)
    rec = arenas * _cache_recurrent_bytes(model, b, nb)
    return (attn + rec) / div
