"""Block-granular paged KV arenas (PR 4): BlockAllocator lifecycle, paged
decode logits-equivalence per family (attention / SSD / hybrid, including
prompts on page boundaries and rotating-window wraps across pages), page
inheritance and exhaustion backpressure, page-granular planner statistics —
plus the bugfix sweep (scheduler zero-flag on recycled arenas, requeue
fairness, ceil-based nearest-rank percentiles, loud row-alloc invariants)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SINGLE_DEVICE_MESH, InputShape, TrainConfig, TPU_V5E
from repro.configs import get_config
from repro.core.memory import cache_page_count, estimate_memory
from repro.core.plan_cache import BucketPolicy
from repro.core.planner import compile_plan
from repro.models.model import Model, build_model
from repro.runtime.kv_cache import BlockAllocator, KVCachePool
from repro.runtime.metrics import LatencyStats
from repro.runtime.scheduler import (ContinuousBatchingScheduler,
                                     RequestQueue, simulate_arrivals)
from repro.runtime.serve_loop import PlanServer, ServeRequest

KEY = jax.random.PRNGKey(0)
CFG = get_config("yi-6b-smoke")


# ---------------------------------------------------------------------------
# BlockAllocator
# ---------------------------------------------------------------------------


def test_block_allocator_lifecycle():
    a = BlockAllocator(4)
    assert a.available == 4
    p = a.alloc(2)
    assert p == [0, 1] and a.available == 2
    assert a.reserve(2) and a.available == 0
    assert a.alloc(1) is None                    # reservations block tenants
    got = a.alloc(1, from_reserve=True)          # but reserved draws succeed
    assert got == [2] and a.reserved == 1
    a.free(p)
    assert a.free_count == 3 and a.available == 2   # 1 still reserved
    with pytest.raises(ValueError):
        a.free([0])                              # double free


def test_block_allocator_reserve_refused_beyond_capacity():
    a = BlockAllocator(2)
    assert not a.reserve(3)
    assert a.reserve(2) and a.alloc(1) is None


# ---------------------------------------------------------------------------
# paged decode == dense decode, per family
# ---------------------------------------------------------------------------


def _paged_equiv(cfg, lengths, seq, page, steps=4):
    """Decode the same handoff through a paged arena and a dense cache and
    require identical logits at every step."""
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(KEY)
    b = len(lengths)
    width = max(lengths)
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, width), 0,
                              cfg.vocab_size)
    lengths_a = jnp.asarray(lengths, jnp.int32)
    logits, dense = model.prefill(params, toks, lengths=lengths_a,
                                  cache_len=seq)
    pool = KVCachePool(model, page_size=page)
    arena = pool.acquire(b, seq)
    rows = pool.alloc_rows(arena, b)
    for r, ln in zip(rows, lengths):
        pool.admit_row(arena, r, prompt=ln, span=ln + steps + 1)
    pool.write_rows(arena, rows, dense)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    pos = lengths_a
    pcache = arena.cache
    for step in range(steps):
        for r, p in zip(rows, np.asarray(pos)):
            pool.ensure_decode_slots(arena, [r], int(p))
        lg_p, pcache = model.decode_step(params, pcache, tok, pos,
                                         tables=arena.tables, page=page,
                                         seq_len=seq)
        lg_d, dense = model.decode_step(params, dense, tok, pos)
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"step {step}")
        tok = jnp.argmax(lg_d[:, -1:], axis=-1).astype(jnp.int32)
        pos = pos + 1
    return pool, arena


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-1.3b", "recurrentgemma-2b"])
def test_paged_decode_matches_dense_per_family(arch):
    cfg = get_config(arch + "-smoke")
    if arch == "recurrentgemma-2b":
        cfg = cfg.replace(block_pattern="ra")  # include a real attn layer
    _paged_equiv(cfg, [12, 9], seq=64, page=16)


def test_paged_prompt_exactly_on_page_boundary():
    """A prompt of exactly page-size tokens: the handoff fills page 0 to
    the brim and the first decode write lands on a freshly granted page."""
    pool, arena = _paged_equiv(CFG, [16, 32], seq=64, page=16, steps=3)
    # admission covers prompt+1: a boundary prompt leases the extra page
    # its first decode write needs (2 pages for 16 slots+1, 3 for 32+1),
    # one page more per row than the prompt alone occupies
    assert pool.metrics.pages_leased == sum(
        -(-(ln + 1) // 16) for ln in (16, 32))
    assert pool.metrics.pages_leased == sum(
        -(-ln // 16) for ln in (16, 32)) + 2


def test_paged_rotating_window_wraps_across_pages():
    """Rotating-window decode past the window: writes wrap to low logical
    slots, whose pages were granted earlier — the paged gather must read
    back the same rotated layout the dense path keeps."""
    cfg = get_config("recurrentgemma-2b-smoke").replace(
        block_pattern="ra", window_size=8)
    _paged_equiv(cfg, [5, 3], seq=32, page=4, steps=12)


def test_paged_prompt_longer_than_window():
    cfg = get_config("recurrentgemma-2b-smoke").replace(block_pattern="ra")
    # window_size=32: prompts 45/38 land pre-rotated across pages
    _paged_equiv(cfg, [45, 38], seq=64, page=16, steps=3)


def test_paged_pool_live_bytes_are_page_exact():
    model = build_model(CFG, dtype=jnp.float32)
    pool = KVCachePool(model, page_size=16)
    arena = pool.acquire(4, 256)
    assert pool.live_bytes() == 0.0
    rows = pool.alloc_rows(arena, 2)
    for r in rows:
        pool.admit_row(arena, r, prompt=20, span=40)
    # committed = leased + reserved pages = ceil(40/16) per row
    assert pool.live_bytes() == pytest.approx(
        2 * pool.member_bytes(256, 1, 40))
    assert pool.live_bytes() < arena.nbytes / 4   # way below bucket slack
    pool.free_rows(arena, rows)
    assert pool.live_bytes() == 0.0
    assert pool.metrics.pages_freed > 0


def test_paged_joiner_inherits_freed_pages():
    """Pages (and the row) a completed member freed are re-leased to the
    next tenant — at the pool level the physical page ids round-trip."""
    model = build_model(CFG, dtype=jnp.float32)
    pool = KVCachePool(model, page_size=16)
    arena = pool.acquire(2, 128)
    [r0] = pool.alloc_rows(arena, 1)
    pool.admit_row(arena, r0, prompt=30, span=40)
    first_pages = list(arena._row_pages[r0])
    pool.free_rows(arena, [r0])
    [r1] = pool.alloc_rows(arena, 1)
    pool.admit_row(arena, r1, prompt=30, span=40)
    assert set(arena._row_pages[r1]) & set(first_pages)


def test_scheduler_mid_decode_joiner_inherits_freed_capacity():
    """End-to-end: a rider joins the row/pages a completed member freed
    mid-decode, and its tokens still condition on its own prompt."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    sched = ContinuousBatchingScheduler(srv, max_group_batch=8)
    late = ServeRequest(1, 92, 3)                 # joins the freed row
    arrivals = [(0.0, ServeRequest(7, 100, 12)),
                (0.0, ServeRequest(1, 90, 2)),    # rides, finishes fast
                (0.0, late)]
    results = sched.run(arrivals)
    assert len(results) == 3
    assert sched.metrics.joins == 1
    joiner = next(r for r in results if r["rid"] == late.rid)
    assert joiner["joined_at_step"] >= 1
    seq = [1] * 92
    expect = []
    for _ in range(3):
        logits, _ = srv.model.apply(srv.params, jnp.asarray([seq]))
        t = int(jnp.argmax(logits[0, -1]))
        expect.append(t)
        seq.append(t)
    assert joiner["tokens"][0].tolist() == expect
    assert srv.pool.metrics.pages_freed > 0


def test_page_exhaustion_backpressures_join_but_group_ticks():
    """A byte budget with room for the head group but not a joiner: the
    join is denied (pages_denied), the in-flight group keeps decoding, and
    the queued request is served after the drain — nothing deadlocks."""
    probe = KVCachePool(build_model(CFG, dtype=jnp.float32), page_size=64)
    head_bytes = probe.member_bytes(128, 3, 110)
    budget = head_bytes * 1.1                     # < head + a 2-page joiner
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16,
                     pool_max_bytes=budget)
    sched = ContinuousBatchingScheduler(srv, max_group_batch=8)
    # the tail arrives once the head group is in flight (the head's first
    # tick compiles plans, so the virtual clock is far past 0.05 by then):
    # it can only enter via a mid-decode join — which the budget denies
    head_req = ServeRequest(3, 100, 8)   # bucket (4, 128), 1 free row
    tail_req = ServeRequest(1, 90, 2)    # same bucket, denied pages
    arrivals = [(0.00, head_req), (0.05, tail_req)]
    results = sched.run(arrivals)
    assert len(results) == 2
    assert sched.metrics.joins == 0
    assert srv.pool.metrics.pages_denied >= 1
    tail = next(r for r in results if r["rid"] == tail_req.rid)
    head = next(r for r in results if r["rid"] == head_req.rid)
    # the tail waited out the head's whole decode; the head started at once
    assert tail["queue_s"] > head["exec_s"] * 0.5
    assert head["queue_s"] < 0.01


# ---------------------------------------------------------------------------
# planner: page-granular cache statistics
# ---------------------------------------------------------------------------


def test_cache_page_count():
    assert cache_page_count(CFG, 256, 4, 64) == 4 * 4
    assert cache_page_count(CFG, 250, 4, 64) == 4 * 4   # rounds up
    assert cache_page_count(CFG, 256, 4, 0) == 0
    ssm = get_config("mamba2-1.3b-smoke")
    assert cache_page_count(ssm, 256, 4, 64) == 0       # no attention


def test_estimate_memory_page_granular_statistic():
    shape = InputShape("t", 256, 2, "decode")
    plan = compile_plan(CFG, shape, SINGLE_DEVICE_MESH).config
    dense = estimate_memory(CFG, shape, SINGLE_DEVICE_MESH, plan,
                            TrainConfig(), TPU_V5E, dtype="float32",
                            cache_pool_arenas=2)
    pages = 2 * cache_page_count(CFG, 256, 2, 64)
    paged = estimate_memory(CFG, shape, SINGLE_DEVICE_MESH, plan,
                            TrainConfig(), TPU_V5E, dtype="float32",
                            cache_pool_arenas=2, cache_pages=pages,
                            cache_page_size=64)
    # 256 divides into 64-slot pages exactly: same worst case, page-shaped
    assert paged.per_device["kv_cache"] == pytest.approx(
        dense.per_device["kv_cache"])
    half = estimate_memory(CFG, shape, SINGLE_DEVICE_MESH, plan,
                           TrainConfig(), TPU_V5E, dtype="float32",
                           cache_pool_arenas=2, cache_pages=pages // 2,
                           cache_page_size=64)
    assert half.per_device["kv_cache"] == pytest.approx(
        dense.per_device["kv_cache"] / 2)


def test_plan_server_page_statistic_never_under_observed():
    """The compile-time paged statistic covers the pool's physical page
    capacity, so a stream that stays within its provisioned arenas never
    burns a corrective recompile on page accounting."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    for b, c in [(1, 40), (2, 100), (1, 90), (2, 100), (1, 200)]:
        out = srv.handle(ServeRequest(b, c, 2))
        assert not out["recompiled"], out["recompile_reasons"]
    assert srv.metrics.recompiles == 0


# ---------------------------------------------------------------------------
# bugfix: recycled-arena zeroing for no-handoff tenants (scheduler path)
# ---------------------------------------------------------------------------


def test_scheduler_recycled_arena_zeroed_for_no_handoff_family(monkeypatch):
    """Regression: the scheduler's group formation (now the engine's
    ``_form_group``) leased recycled arenas without the ``zero=`` flag the
    sequential path passes — a second no-handoff group (``pkv is None`` ⇒
    rows decode from an assumed-zero cache) inherited the previous
    tenant's recurrent state. Recycle an arena between two no-handoff
    groups and require tokens identical to a fresh-cache run. SSD state is
    carried additively, so any leak changes the logits."""
    cfg = get_config("mamba2-1.3b-smoke")
    monkeypatch.setattr(Model, "supports_handoff", property(lambda s: False))

    def run_group(srv):
        sched = ContinuousBatchingScheduler(srv, max_group_batch=4)
        return sched.run(simulate_arrivals([ServeRequest(1, 8, 4)]))

    srv = PlanServer(cfg, dtype=jnp.float32, capacity=16)
    run_group(srv)                       # first tenant dirties the arena
    assert srv.pool.metrics.arenas_created == 1
    second = run_group(srv)              # recycled arena, same bucket
    assert srv.pool.metrics.arenas_reused >= 1
    fresh = run_group(PlanServer(cfg, dtype=jnp.float32, capacity=16))
    assert second[0]["tokens"].tolist() == fresh[0]["tokens"].tolist()


# ---------------------------------------------------------------------------
# bugfix: requeue_front reinserts by arrival order (queue fairness)
# ---------------------------------------------------------------------------


def test_requeue_front_merges_by_arrival_order():
    """A refused group is head + same-bucket riders popped from deep in the
    queue; reinserting it wholesale at the front jumped the riders ahead of
    older other-bucket requests."""
    q = RequestQueue(BucketPolicy(min_batch=1, min_seq=16))
    a1 = q.admit(ServeRequest(1, 100, 8), 0.00)   # bucket 128
    b1 = q.admit(ServeRequest(1, 40, 8), 0.01)    # bucket 64
    a2 = q.admit(ServeRequest(1, 90, 8), 0.02)    # bucket 128 (rider)
    group = q.next_group()
    assert [m.rid for m in group] == [a1.rid, a2.rid]
    q.requeue_front(group)
    assert [m.rid for m in q.pending] == [a1.rid, b1.rid, a2.rid]


def test_interleaved_buckets_refusals_stay_head_of_line_fair():
    """End-to-end: under a one-arena budget, a refused 128-bucket group's
    rider must not leapfrog an older 64-bucket request. After a mid-decode
    join steals the refused group's head, the older other-bucket request is
    next in line — with the old wholesale requeue the rider was."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16, pool_max_arenas=1)
    sched = ContinuousBatchingScheduler(srv, max_group_batch=8,
                                        join_mid_decode=True)
    reqs = [
        ServeRequest(7, 100, 24),   # H1: leases the only arena
        ServeRequest(1, 104, 4),    # H2: rides H1's group, frees a row
        ServeRequest(1, 108, 4),    # A4: joins H2's freed row later
        ServeRequest(1, 40, 2),     # B1: bucket 64, OLDER than A2
        ServeRequest(2, 112, 4),    # A2: bucket 128 rider
    ]
    arrivals = [(0.001 * i, r) for i, r in enumerate(reqs)]
    results = sched.run(arrivals)
    assert len(results) == 5
    # A4 (and possibly H2, timing-dependent) absorbed mid-decode: the
    # refused [A4, A2] group lost its head to a join, leaving A2 and the
    # older B1 adjacent in the queue — where the old requeue had swapped them
    assert sched.metrics.joins >= 1
    order = [r["rid"] for r in results]
    # B1 arrived before A2: after the arena drains it must form its group
    # first — the old requeue served A2 ahead of it
    assert order.index(reqs[3].rid) < order.index(reqs[4].rid)
    b1 = next(r for r in results if r["rid"] == reqs[3].rid)
    a2 = next(r for r in results if r["rid"] == reqs[4].rid)
    assert b1["queue_s"] <= a2["queue_s"]


# ---------------------------------------------------------------------------
# bugfix: ceil-based nearest-rank percentiles
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank_never_picks_lower_sample():
    ls = LatencyStats(samples=list(range(1, 14)))   # n=13
    # old int(round(0.95 * 12)) == 11 -> 12: one sample below true rank
    assert ls.percentile(95) == 13
    assert ls.percentile(50) == 7
    ls12 = LatencyStats(samples=list(range(1, 13)))  # n=12
    # old round picked index 10 (11); nearest rank is ceil(11.4) = 12th
    assert ls12.percentile(95) == 12
    assert LatencyStats().percentile(95) == 0.0
    one = LatencyStats(samples=[3.0])
    assert one.percentile(50) == one.percentile(95) == 3.0


# ---------------------------------------------------------------------------
# bugfix: loud invariant on row allocation
# ---------------------------------------------------------------------------


def test_alloc_rows_invariant_raises_with_context():
    # the one admission helper every serving path goes through fails
    # loudly (with context) when upstream accounting is out of sync
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    arena = srv.pool.acquire(1, 64, force=True)
    with pytest.raises(RuntimeError, match="row invariant.*2 rows.*1 free"):
        srv.pool.admit_request_rows(arena, 2, prompt=40, span=42,
                                    where="_try_joins")
