"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module never touches jax device state — required because the
dry-run re-initializes jax with 512 placeholder host devices.
"""

from __future__ import annotations

import jax

from repro.config import MULTI_POD_MESH, SINGLE_POD_MESH, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_cfg_for(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH


def make_local_mesh():
    """Whatever devices exist locally (smoke tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
