import os
import subprocess
import sys

import pytest

# Tests run single-device (the dry-run owns the 512-device setup; see
# src/repro/launch/dryrun.py). Multi-device behaviours are tested through
# subprocesses that set XLA_FLAGS before importing jax.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True)
def _fresh_legacy_kwarg_warnings():
    """fold_legacy_kwargs warns once per process per call site; reset the
    registry before every test so pytest.warns assertions hold regardless
    of test order (imported lazily: multidev subprocess helpers must not
    force jax in before they set XLA_FLAGS)."""
    from repro.runtime.engine_config import reset_legacy_kwarg_warnings
    reset_legacy_kwarg_warnings()
    yield

MULTIDEV_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import sys
sys.path.insert(0, {src!r})
"""


def multidev_script(body: str, n: int = 8) -> str:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    return MULTIDEV_PRELUDE.format(n=n, src=os.path.abspath(src)) + body


def run_multidev(body: str, n: int = 8, timeout: int = 300) -> str:
    r = subprocess.run(
        [sys.executable, "-c", multidev_script(body, n)],
        capture_output=True, text=True, timeout=timeout,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout
