"""End-to-end training driver: a transformer LM trained for a few hundred
steps through the full production path (plan compiler -> sharded train
step -> metrics -> checkpoint).

Default is a ~5M-param model that converges visibly in minutes on this
2-core CPU container; ``--size 100m`` builds a ~100M-param model (same
path; budget multiple hours on CPU, minutes on a real TPU slice).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.config import InputShape, MeshConfig, ModelConfig, TrainConfig
from repro.core.planner import compile_plan
from repro.data import make_batch
from repro.models.model import build_model
from repro.runtime.metrics import StepTimer, format_metrics
from repro.runtime.train_loop import init_opt_state, make_train_step

SIZES = {
    "5m": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
               head_dim=64, d_ff=768, vocab_size=512),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=2304, vocab_size=32000),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=list(SIZES), default="5m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--checkpoint", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(name=f"lm-{args.size}", family="dense",
                      tie_embeddings=False, **SIZES[args.size])
    model = build_model(cfg, dtype=jnp.float32)
    print(f"params: {model.param_count() / 1e6:.1f}M")

    mesh_cfg = MeshConfig(shape=(len(jax.devices()),), axis_names=("data",))
    shape = InputShape("lm", args.seq, args.batch, "train")
    train = TrainConfig(optimizer="adam", learning_rate=args.lr)
    plan = compile_plan(cfg, shape, mesh_cfg, train)
    print(plan.explain())

    params = model.init_params(jax.random.PRNGKey(0))
    opt = init_opt_state("adam", params, plan.config)
    step_fn = jax.jit(make_train_step(model, plan.config, mesh_cfg, train))

    timer = StepTimer(model=cfg, shape=shape, mesh=mesh_cfg)
    losses = []
    for i in range(args.steps):
        batch = make_batch(cfg, shape, step=i, dtype=jnp.float32)
        timer.start()
        params, opt, metrics = step_fn(params, opt, batch, jnp.int32(i))
        rec = timer.stop(i, metrics)
        losses.append(rec["loss"])
        if i % 20 == 0 or i == args.steps - 1:
            print(format_metrics(rec), flush=True)

    save_checkpoint(args.checkpoint, params, step=args.steps)
    restored, step = load_checkpoint(args.checkpoint, params)
    assert step == args.steps
    print(f"checkpoint roundtrip OK at step {step}")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0] * 0.9, "loss should drop noticeably"
    print("OK")


if __name__ == "__main__":
    main()
