"""Logical-axis -> mesh-axis sharding rules.

Every tensor in the system (params, activations, KV caches, optimizer state)
carries a tuple of *logical axis names* (one per dim). The plan decides which
logical axes map onto which mesh axes; this module turns that decision into
concrete ``PartitionSpec``/``NamedSharding`` objects.

This is the pjit-era analogue of SystemML's "blocked matrix" physical layout
decision: the compiler, not the model author, owns the layout.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import MeshConfig
from repro.core.strategies import PlanConfig

# Logical axes eligible for the "model" (tensor-parallel) mesh axis, in
# priority order. Only one logical axis per tensor maps to "model".
MODEL_AXIS_PRIORITY = (
    "experts",
    "q_heads",
    "heads",
    "kv_heads",
    "ffn",
    "vocab",
    "ssm_heads",
    "ssm_inner",
    "lru",
    "embed_out",   # output-projection embed dim (row-parallel)
)

# Logical axes eligible for FSDP (data-axes) sharding, largest-first is
# resolved dynamically; these are merely *allowed*.
FSDP_AXES = (
    "embed",
    "embed_out",
    "ffn",
    "vocab",
    "q_heads",
    "heads",
    "kv_heads",
    "ssm_inner",
    "ssm_heads",
    "lru",
    "experts",
)

# Axes that must never shard (scan-stacked layer dim, small vectors).
NEVER_SHARD = ("layers", "head_dim", "ssm_state", "conv", "scalar", "window")


def _axis_size(mesh: MeshConfig, names: Sequence[str]) -> int:
    n = 1
    for nm, sz in zip(mesh.axis_names, mesh.shape):
        if nm in names:
            n *= sz
    return n


def spec_for(
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    plan: PlanConfig,
    mesh: MeshConfig,
    kind: str = "param",
) -> P:
    """Compute the PartitionSpec for one tensor.

    kind: "param" | "act" | "cache" | "opt"
    """
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} vs logical axes {axes}")
    assignment: list = [None] * len(shape)
    used_mesh_axes: set = set()


    def assign(i, mesh_axes):
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        mesh_axes = tuple(a for a in mesh_axes if a not in used_mesh_axes and a in mesh.axis_names)
        if not mesh_axes:
            return False
        div = _axis_size(mesh, mesh_axes)
        if div <= 1 or shape[i] % div != 0:
            return False
        assignment[i] = mesh_axes[0] if len(mesh_axes) == 1 else tuple(mesh_axes)
        used_mesh_axes.update(mesh_axes)
        return True

    # 1. batch axis
    for i, ax in enumerate(axes):
        if ax == "batch":
            baxes = plan.cache_batch_axes if kind == "cache" else plan.batch_axes
            if baxes and shape[i] % _axis_size(mesh, baxes) == 0:
                assign(i, baxes)

    # 1b. context parallelism: activation seq dim (prefill)
    if kind == "act":
        for i, ax in enumerate(axes):
            if ax == "seq" and plan.seq_axes:
                assign(i, plan.seq_axes)

    # 2. cache sequence sharding (decode long-context)
    if kind == "cache":
        for i, ax in enumerate(axes):
            if ax == "seq" and plan.cache_seq_axes:
                assign(i, plan.cache_seq_axes)
        for i, ax in enumerate(axes):
            if ax in ("kv_heads", "heads", "ssm_heads") and plan.cache_heads_over_model:
                assign(i, "model")

    # 3. tensor / expert parallel over "model"
    if kind in ("param", "opt") and (plan.tensor_parallel or plan.expert_parallel):
        allowed = MODEL_AXIS_PRIORITY if plan.tensor_parallel else ("experts",)
        for cand in allowed:
            done = False
            for i, ax in enumerate(axes):
                if ax == cand and assignment[i] is None and assign(i, "model"):
                    done = True
                    break
            if done:
                break

    # 4. FSDP over the data axes: largest remaining eligible dim
    if kind in ("param", "opt") and plan.params_over_data:
        daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        cands = [
            (shape[i], i)
            for i, ax in enumerate(axes)
            if ax in FSDP_AXES and assignment[i] is None
        ]
        for _, i in sorted(cands, reverse=True):
            if assign(i, daxes):
                break

    # 5. activations: shard the feature dims that TP shards (GSPMD would
    #    propagate this anyway; being explicit avoids resharding wobble)
    if kind == "act" and plan.tensor_parallel:
        for cand in MODEL_AXIS_PRIORITY:
            done = False
            for i, ax in enumerate(axes):
                if ax == cand and assignment[i] is None and assign(i, "model"):
                    done = True
                    break
            if done:
                break

    return P(*assignment)


def named_sharding(
    mesh: Mesh,
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    plan: PlanConfig,
    mesh_cfg: MeshConfig,
    kind: str = "param",
) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, axes, plan, mesh_cfg, kind))


def tree_specs(shapes_tree, axes_tree, plan: PlanConfig, mesh_cfg: MeshConfig, kind: str = "param"):
    """Map spec_for over a pytree of ShapeDtypeStructs + matching axes tree."""
    # shapes_tree's leaves (ShapeDtypeStruct/Array) define the structure;
    # axes_tree is flattened *up to* those leaf positions, so its tuple
    # leaves arrive intact.
    return jax.tree.map(
        lambda s, a: spec_for(tuple(s.shape), tuple(a), plan, mesh_cfg, kind),
        shapes_tree,
        axes_tree,
    )
