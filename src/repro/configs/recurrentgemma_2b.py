"""recurrentgemma-2b [hybrid] — 26L, d_model=2560, 10H (GQA kv=1 / MQA),
d_ff=7680, vocab=256000. RG-LRU + local attention, pattern 1 attn : 2 LRU.
[arXiv:2402.19427]
"""

from repro.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        block_pattern="rra",       # 2 recurrent : 1 local-attention
        window_size=2048,
        lru_width=2560,
        tie_embeddings=True,
        citation="arXiv:2402.19427",
    )
