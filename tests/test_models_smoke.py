"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward + one train step + one decode step
on CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import InputShape, TrainConfig, SINGLE_DEVICE_MESH
from repro.configs import ARCH_IDS, get_config
from repro.core.planner import compile_plan
from repro.data import make_batch
from repro.models import blocks as B_
from repro.models.model import build_model
from repro.runtime.train_loop import init_opt_state, make_train_step

KEY = jax.random.PRNGKey(0)
SMOKE_SHAPE = InputShape("smoke", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(KEY)
    batch = make_batch(cfg, SMOKE_SHAPE, dtype=jnp.float32)

    logits, aux = model.apply(params, batch["tokens"], extra=batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    plan = compile_plan(cfg, SMOKE_SHAPE, SINGLE_DEVICE_MESH)
    train = TrainConfig(optimizer="adam", learning_rate=1e-3)
    step = make_train_step(model, plan.config, SINGLE_DEVICE_MESH, train)
    opt = init_opt_state("adam", params, plan.config)
    new_params, _, metrics = step(params, opt, batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(new_params[k] - params[k]))) > 0
        for k in params
    )
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(KEY)
    b = 2
    cache = model.init_cache(b, 64)
    tok = jnp.ones((b, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok, jnp.int32(5))
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    # cache was updated somewhere
    changed = any(
        float(jnp.max(jnp.abs(cache2[k].astype(jnp.float32)
                              - cache[k].astype(jnp.float32)))) > 0
        for k in cache
    )
    assert changed


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-1.3b", "recurrentgemma-2b",
                                  "qwen3-moe-235b-a22b", "internvl2-2b"])
def test_decode_matches_full_forward(arch):
    """Incremental decode with cache == full forward (the correctness
    contract for all serving shapes)."""
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    extra = {}
    if cfg.frontend == "vision":
        # decode equivalence tested on text-only stream for the VLM
        extra = {}
    full, _ = model.apply(params, toks, extra=extra)
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_rotating_window_decode_matches_windowed_forward():
    """Sliding-window serving variant (DESIGN §5): decoding with a rotating
    cache of size W equals a full forward under a width-W attention mask."""
    cfg = get_config("yi-6b-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(KEY)
    B, S, W = 1, 20, 8
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = model.apply(params, toks, window_override=W)

    # build a rotating cache by hand: cache seq = W
    ent = {}
    n = cfg.num_layers
    for name, (shape, axes) in B_.attn_cache_spec(cfg, B, W, jnp.float32).items():
        ent["l." + name] = jnp.zeros((n, *shape), jnp.float32)
    cache = ent
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t), window_override=W)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_param_count_analytic_close_to_actual():
    """ModelConfig.param_count (drives the memory estimator) tracks the
    real parameter tree within 10% for the full-size configs."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        actual = model.param_count()
        analytic = cfg.param_count()
        ratio = analytic / actual
        assert 0.9 < ratio < 1.15, (arch, analytic, actual, ratio)


def test_whisper_cross_cache_decode_matches_full_forward():
    """Enc-dec serving: encoder run once, cross K/V cached, incremental
    decoder equals the full teacher-forced forward."""
    cfg = get_config("whisper-medium-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(KEY)
    B, S = 2, 10
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    frames = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
    full, _ = model.apply(params, toks, extra={"frames": frames})

    cache = model.init_cache(B, S)
    cache.update(model.build_cross_cache(params, frames))
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)
