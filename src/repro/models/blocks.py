"""Transformer / SSM / RG-LRU block definitions.

Each block kind provides:
  ``*_params(cfg)``        -> {name: (shape, axes, init)} per-layer specs
  ``*_apply(cfg, p, x, ...)``   full-sequence forward (train / prefill)
  ``*_decode(cfg, p, x, cache, pos)`` one-token forward + cache update

Param layout is logical-axis annotated (see core.sharding); the planner
decides the physical sharding.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import attention as ATT
from repro.models.common import (NULL_CTX, ShardCtx, causal_conv1d, rms_norm,
                                 rope, swiglu)


# ===========================================================================
# dense / MoE attention block
# ===========================================================================


def attn_block_params(cfg: ModelConfig, cross: bool = False) -> Dict:
    d, hq, kv, hd, f = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                        cfg.head_dim, cfg.d_ff)
    p = {
        "ln1": ((d,), (None,), "ones"),
        "wq": ((d, hq, hd), ("embed", "q_heads", "head_dim"), "normal"),
        "wk": ((d, kv, hd), ("embed", "kv_heads", "head_dim"), "normal"),
        "wv": ((d, kv, hd), ("embed", "kv_heads", "head_dim"), "normal"),
        "wo": ((hq, hd, d), ("q_heads", "head_dim", "embed_out"), "normal"),
        "ln2": ((d,), (None,), "ones"),
    }
    if cross:
        p.update({
            "xln": ((d,), (None,), "ones"),
            "xwq": ((d, hq, hd), ("embed", "q_heads", "head_dim"), "normal"),
            "xwk": ((d, kv, hd), ("embed", "kv_heads", "head_dim"), "normal"),
            "xwv": ((d, kv, hd), ("embed", "kv_heads", "head_dim"), "normal"),
            "xwo": ((hq, hd, d), ("q_heads", "head_dim", "embed_out"), "normal"),
        })
    if cfg.num_experts:
        e = cfg.num_experts
        p.update({
            "router": ((d, e), ("embed", None), "normal"),
            "e_wg": ((e, d, f), ("experts", "embed", "ffn"), "normal"),
            "e_wu": ((e, d, f), ("experts", "embed", "ffn"), "normal"),
            "e_wd": ((e, f, d), ("experts", "ffn", "embed_out"), "normal"),
        })
    elif cfg.family == "audio":
        # whisper-style GELU MLP
        p.update({
            "wi": ((d, f), ("embed", "ffn"), "normal"),
            "wo_mlp": ((f, d), ("ffn", "embed_out"), "normal"),
        })
    else:
        p.update({
            "wg": ((d, f), ("embed", "ffn"), "normal"),
            "wu": ((d, f), ("embed", "ffn"), "normal"),
            "wd": ((f, d), ("ffn", "embed_out"), "normal"),
        })
    return p


def _qkv(cfg, p, x, positions, prefix="", ctx: ShardCtx = NULL_CTX,
         expand: bool = True):
    """Returns ``(q, k, v, (k_kv, v_kv))`` — the last pair is the rope'd
    K/V in kv-head form (pre-GQA-expansion, pre-constraint): exactly what a
    decode cache row stores, so the prefill path can hand its K/V off."""
    q = jnp.einsum("bsd,dhk->bshk", x, p[prefix + "wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p[prefix + "wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p[prefix + "wv"])
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    kv_form = (k, v)
    if expand and cfg.q_per_kv > 1:
        # GQA: expand K/V to the full head count. Under tensor parallelism
        # the expanded heads shard over "model", so each chip materializes
        # only its slice — no memory cost, and it keeps attention einsums
        # reshape-free (GSPMD shards merged/reshaped dims poorly).
        k = jnp.repeat(k, cfg.q_per_kv, axis=2)
        v = jnp.repeat(v, cfg.q_per_kv, axis=2)
    # pin layouts. Three regimes:
    #  * context-parallel (plan.seq_axes): Q seq-sharded, K/V gathered
    #  * heads divisible by the model axis: head-sharded attention (TP)
    #  * heads NOT divisible (phi3's 40, recurrentgemma's 10): keep the
    #    attention region *sequence*-sharded (SP attention) and gather K/V
    #    — otherwise every chip replicates the full attention working set
    qspec = ("batch", "seq", "q_heads", "head_dim")
    cp = ctx.plan is not None and bool(ctx.plan.seq_axes)
    sp = _sp_attention(cfg, ctx)
    if sp and not cp:
        # SP attention: Q seq-sharded over "model", K/V gathered
        q = ctx.constrain_seq_model(q)
        k = ctx.constrain(k, ("batch", None, None, None))
        v = ctx.constrain(v, ("batch", None, None, None))
        return q, k, v, kv_form
    kvspec = ("batch", None, None, None) if cp else qspec
    q = ctx.constrain(q, qspec)
    k = ctx.constrain(k, kvspec)
    v = ctx.constrain(v, kvspec)
    return q, k, v, kv_form


def _heads_shardable(cfg, ctx: ShardCtx) -> bool:
    if ctx.plan is None or ctx.mesh_cfg is None or not ctx.plan.tensor_parallel:
        return False
    return cfg.num_heads % ctx.mesh_cfg.model_parallelism == 0


def _sp_attention(cfg, ctx: ShardCtx) -> bool:
    """Sequence-parallel attention region: TP is on but heads don't divide
    the model axis, and residuals are seq-sharded."""
    return (ctx.plan is not None and ctx.plan.seq_shard_checkpoints
            and not _heads_shardable(cfg, ctx))


def _ffn(cfg, p, x, ctx: ShardCtx):
    if cfg.num_experts:
        return moe_ffn(cfg, p, x, ctx)
    if cfg.family == "audio":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"]).astype(jnp.float32))
        return jnp.einsum("bsf,fd->bsd", h.astype(x.dtype), p["wo_mlp"]), 0.0
    return swiglu(x, p["wg"], p["wu"], p["wd"]), 0.0


def attn_block_apply(
    cfg: ModelConfig, p: Dict, x: jnp.ndarray, positions: jnp.ndarray,
    *, causal: bool = True, window: int = 0, ctx: ShardCtx = NULL_CTX,
    enc_out: Optional[jnp.ndarray] = None, want_kv: bool = False,
) -> Tuple:
    """Returns (x_out, aux_loss), or with ``want_kv`` the 3-tuple
    (x_out, aux_loss, {"k", "v"}) — K/V in kv-head cache-row form
    ``(B, S, Kv, Dh)`` for the prefill→decode handoff."""
    h = rms_norm(x, p["ln1"])
    if not _sp_attention(cfg, ctx):
        h = ctx.seq_gather(h)
    q, k, v, (kr, vr) = _qkv(cfg, p, h, positions, ctx=ctx)
    o = ATT.attention(q, k, v, causal=causal, window=window)
    if _sp_attention(cfg, ctx) and not (ctx.plan and ctx.plan.seq_axes):
        o = ctx.constrain_seq_model(o)
    else:
        o = ctx.constrain(o, ("batch", "seq", "q_heads", "head_dim"))
    x = x + ctx.ckpt_constrain(jnp.einsum("bshk,hkd->bsd", o, p["wo"]))
    if enc_out is not None:  # cross attention (enc-dec decoder)
        h = rms_norm(x, p["xln"])
        qx = jnp.einsum("bsd,dhk->bshk", h, p["xwq"])
        kx = jnp.einsum("bsd,dhk->bshk", enc_out, p["xwk"])
        vx = jnp.einsum("bsd,dhk->bshk", enc_out, p["xwv"])
        if cfg.q_per_kv > 1:
            kx = jnp.repeat(kx, cfg.q_per_kv, axis=2)
            vx = jnp.repeat(vx, cfg.q_per_kv, axis=2)
        ox = ATT.attention(qx, kx, vx, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", ox, p["xwo"])
    h = ctx.seq_gather(rms_norm(x, p["ln2"]))
    f, aux = _ffn(cfg, p, h, ctx)
    out = x + ctx.ckpt_constrain(f)
    if want_kv:
        return out, aux, {"k": kr, "v": vr}
    return out, aux


def attn_block_decode(
    cfg: ModelConfig, p: Dict, x: jnp.ndarray, cache: Dict, pos: jnp.ndarray,
    *, window: int = 0, ctx: ShardCtx = NULL_CTX,
    enc_out_kv: Optional[Tuple] = None,
    tables: Optional[jnp.ndarray] = None, page: int = 0, sc: int = 0,
    decode_kernel: str = "gather",
) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, 1, D). cache: {"k": (B, Sc, Kv, Dh), "v": ...} (kv-head form;
    expansion to full heads happens at the attention einsum). ``pos`` is a
    scalar (whole batch at one depth) or a (B,) vector (rows at different
    generation depths — the row-addressable cache-pool decode shape).

    With ``tables``/``page``/``sc`` the cache is block-granular paged:
    k/v are flat ``(n_slots, Kv, Dh)`` slot stacks shared by all rows, and
    the write/read go through each row's page table (physical slot =
    ``table[i // page] * page + i % page``). ``decode_kernel`` is the
    plan-chosen physical operator for the paged read side: "paged" fuses
    the table indirection into the attention op (kernels/paged_attention),
    "gather" materializes the gathered view, "ref" runs the jnp oracle."""
    h = rms_norm(x, p["ln1"])
    rope_pos = pos[None] if pos.ndim == 0 else pos[:, None]
    q, k, v, _ = _qkv(cfg, p, h, rope_pos, ctx=ctx, expand=False)
    if tables is not None:
        kc, vc = ATT.paged_cache_write(cache["k"], cache["v"], k, v, pos,
                                       tables, page, sc, window=window)
        if decode_kernel == "paged":
            # committed-slot mask == decode validity mask for both dense
            # and rotating rows (see kernels/paged_attention.py), so the
            # fused op needs pos and sc but not the window
            o = kops.paged_attention(q, kc, vc, tables, pos, page=page, sc=sc)
        elif decode_kernel == "ref":
            o = kref.paged_decode_ref(q, kc, vc, tables, pos, page=page,
                                      sc=sc, window=window)
        else:
            ke, ve = ATT.paged_gather_kv(kc, vc, tables, page, sc, pos=pos)
            if cfg.q_per_kv > 1:
                ke = jnp.repeat(ke, cfg.q_per_kv, axis=2)
                ve = jnp.repeat(ve, cfg.q_per_kv, axis=2)
            o = ATT.decode_attention(q, ke, ve, pos, window=window)
    else:
        kc, vc = ATT.cache_write(cache["k"], cache["v"], k, v, pos,
                                 window=window)
        ke, ve = kc, vc
        if cfg.q_per_kv > 1:
            ke = jnp.repeat(ke, cfg.q_per_kv, axis=2)
            ve = jnp.repeat(ve, cfg.q_per_kv, axis=2)
        o = ATT.decode_attention(q, ke, ve, pos, window=window)
    x = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    cache = dict(cache, k=kc, v=vc)
    if enc_out_kv is not None:
        h = rms_norm(x, p["xln"])
        qx = jnp.einsum("bsd,dhk->bshk", h, p["xwq"])
        kx, vx = enc_out_kv
        if cfg.q_per_kv > 1:
            kx = jnp.repeat(kx, cfg.q_per_kv, axis=2)
            vx = jnp.repeat(vx, cfg.q_per_kv, axis=2)
        ox = ATT.attention(qx, kx, vx, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", ox, p["xwo"])
    h = rms_norm(x, p["ln2"])
    f, _ = _ffn(cfg, p, h, ctx)
    return x + f, cache


def attn_cache_spec(cfg: ModelConfig, batch: int, seq: int, dtype) -> Dict:
    """Per-layer cache specs + logical axes."""
    kvshape = (batch, seq, cfg.num_kv_heads, cfg.head_dim)
    axes = ("batch", "seq", "kv_heads", "head_dim")
    return {
        "k": (kvshape, axes),
        "v": (kvshape, axes),
    }


# ===========================================================================
# MoE FFN — sort-based grouped dispatch (static shapes, EP-shardable)
# ===========================================================================


def moe_ffn(cfg: ModelConfig, p: Dict, x: jnp.ndarray, ctx: ShardCtx):
    """x: (B, S, D) -> (B, S, D), aux load-balance loss.

    Grouped routing (the MaxText/GShard pattern): tokens are split into G
    groups aligned with the data shards; within each group they are routed
    top-k, sorted by expert and packed into a static (G, E, C, D) buffer
    (capacity-dropped). Pack/unpack scatters stay *local to a group* so
    GSPMD partitions them along the batch axis; the expert einsum against
    E-sharded weights is where the all-to-all materializes — visible in the
    dry-run HLO under EXPERT_PARALLEL.
    """
    b, s, d = x.shape
    e, kk = cfg.num_experts, cfg.experts_per_token
    t = b * s
    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, kk)                      # (t, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # Switch-style load-balance aux loss
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], e), axis=0)
    prob_mean = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * prob_mean)

    # group count: one group per data shard (1 when unplanned/local)
    g_cnt = 1
    if ctx.mesh_cfg is not None and ctx.plan is not None and ctx.plan.batch_axes:
        g_cnt = ctx.mesh_cfg.data_parallelism
    while t % g_cnt != 0:
        g_cnt //= 2
    tg = t // g_cnt
    cap = int(tg * kk * cfg.moe_capacity_factor / e) + 1  # lint: allow-tracer-host-sync (static shape math)
    cap = max(8, -(-cap // 8) * 8)

    tables = jax.vmap(lambda fe: _routing_tables(fe, e, cap, kk))(
        idx.reshape(g_cnt, tg * kk))

    xg = xf.reshape(g_cnt, tg, d)
    wj = gates.reshape(g_cnt, tg * kk).astype(x.dtype)
    buf = jax.vmap(lambda a, t: _moe_dispatch(kk, a, t))(xg, tables)
    buf = buf.reshape(g_cnt, e, cap, d)
    buf = ctx.constrain(buf, ("batch", "experts", None, None))

    gm = jnp.einsum("gecd,edf->gecf", buf, p["e_wg"])
    um = jnp.einsum("gecd,edf->gecf", buf, p["e_wu"])
    hsil = jax.nn.silu(gm.astype(jnp.float32)).astype(x.dtype) * um
    out_buf = jnp.einsum("gecf,efd->gecd", hsil, p["e_wd"])
    out_buf = ctx.constrain(out_buf, ("batch", "experts", None, None))

    y = jax.vmap(lambda o, w, t: _moe_combine(kk, o, w, t))(
        out_buf.reshape(g_cnt, e * cap, d), wj, tables)
    return y.reshape(b, s, d), aux


def _routing_tables(flat_e: jnp.ndarray, e: int, cap: int, kk: int):
    """Gather-only routing tables for one group.

    flat_e: (tg*k,) expert assignment per (token, k) pair ("j" index).
    Returns (j_of_slot, s_valid, slot_of_j, j_valid) — both directions of
    the token<->slot permutation, so dispatch/combine and their VJPs are
    all expressible as gathers (no scatter: XLA:CPU's scatter expander
    would otherwise materialize dense index tensors).
    """
    tgk = flat_e.shape[0]
    order = jnp.argsort(flat_e)                 # sorted position -> j
    inv = jnp.argsort(order)                    # j -> sorted position
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e + 1))
    # slot -> j
    slot_ids = jnp.arange(e * cap)
    s_e, s_c = slot_ids // cap, slot_ids % cap
    spos = starts[s_e] + s_c
    s_valid = spos < starts[s_e + 1]
    j_of_slot = order[jnp.clip(spos, 0, tgk - 1)]
    # j -> slot
    pe = sorted_e[inv]                          # = flat_e
    pos_in_e = inv - starts[pe]
    slot_of_j = pe * cap + jnp.minimum(pos_in_e, cap - 1)
    j_valid = pos_in_e < cap
    return j_of_slot, s_valid, slot_of_j, j_valid



@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _moe_dispatch(kk, xg, tables):
    j_of_slot, s_valid, _, _ = tables
    return xg[j_of_slot // kk] * s_valid[:, None].astype(xg.dtype)


def _moe_dispatch_fwd(kk, xg, tables):
    return _moe_dispatch(kk, xg, tables), (tables, xg.shape)


def _moe_dispatch_bwd(kk, res, d_buf):
    (j_of_slot, s_valid, slot_of_j, j_valid), xshape = res
    vals = d_buf[slot_of_j] * j_valid[:, None].astype(d_buf.dtype)
    dx = vals.reshape(xshape[0], kk, xshape[1]).sum(axis=1).astype(d_buf.dtype)
    return dx, None


_moe_dispatch.defvjp(_moe_dispatch_fwd, _moe_dispatch_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _moe_combine(kk, out_flat, wj, tables):
    _, _, slot_of_j, j_valid = tables
    tg = wj.shape[0] // kk
    vals = out_flat[slot_of_j] * (wj * j_valid.astype(wj.dtype))[:, None]
    return vals.reshape(tg, kk, out_flat.shape[1]).sum(axis=1)


def _moe_combine_fwd(kk, out_flat, wj, tables):
    return _moe_combine(kk, out_flat, wj, tables), (out_flat, wj, tables)


def _moe_combine_bwd(kk, res, dy):
    out_flat, wj, tables = res
    j_of_slot, s_valid, slot_of_j, j_valid = tables
    # d_out[slot] = dy[token(slot)] * w[j(slot)]
    dyj = dy[j_of_slot // kk]
    wslot = wj[j_of_slot] * s_valid.astype(wj.dtype)
    d_out = (dyj * wslot[:, None]).astype(out_flat.dtype)
    # d_w[j] = <out[slot(j)], dy[token(j)]>
    dy_rep = jnp.repeat(dy, kk, axis=0)  # j-order tokens
    d_w = jnp.sum(out_flat[slot_of_j] * dy_rep, axis=-1) * j_valid.astype(wj.dtype)
    return d_out, d_w.astype(wj.dtype), None


_moe_combine.defvjp(_moe_combine_fwd, _moe_combine_bwd)


# ===========================================================================
# Mamba-2 SSD block
# ===========================================================================


def ssd_block_params(cfg: ModelConfig) -> Dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads
    wc = cfg.ssm_conv_width
    return {
        "ln": ((d,), (None,), "ones"),
        "wz": ((d, di), ("embed", "ssm_inner"), "normal"),
        "wx": ((d, di), ("embed", "ssm_inner"), "normal"),
        "wb": ((d, n), ("embed", None), "normal"),
        "wc": ((d, n), ("embed", None), "normal"),
        "wdt": ((d, h), ("embed", "ssm_heads"), "normal"),
        "dt_bias": ((h,), (None,), "zeros"),
        "conv_x": ((wc, di), ("conv", "ssm_inner"), "normal"),
        "conv_b": ((wc, n), ("conv", None), "normal"),
        "conv_c": ((wc, n), ("conv", None), "normal"),
        "a_log": ((h,), (None,), "ssm_a"),
        "d_skip": ((h,), (None,), "ones"),
        "gate_ln": ((di,), (None,), "ones"),
        "w_out": ((di, d), ("ssm_inner", "embed_out"), "normal"),
    }


def _ssd_pre(cfg, p, h):
    z = jnp.einsum("bsd,de->bse", h, p["wz"])
    xin = jnp.einsum("bsd,de->bse", h, p["wx"])
    bm = jnp.einsum("bsd,dn->bsn", h, p["wb"])
    cm = jnp.einsum("bsd,dn->bsn", h, p["wc"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, p["wdt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return z, xin, bm, cm, dt


def _conv_tail(x_raw: jnp.ndarray, wd: int, lengths: jnp.ndarray) -> jnp.ndarray:
    """Decode conv state after a prefill of per-row length T: the last
    ``wd - 1`` *raw pre-conv* inputs before position T (zero-padded below
    position 0). x_raw: (B, S, C); lengths: (B,); returns (B, wd-1, C)."""
    b, s, c = x_raw.shape
    pad = jnp.zeros((b, wd - 1, c), x_raw.dtype)
    xp = jnp.concatenate([pad, x_raw], axis=1)      # index j ↔ position j-(wd-1)
    idx = lengths[:, None] + jnp.arange(wd - 1)[None, :]    # positions T-wd+1..T-1
    return jnp.take_along_axis(xp, idx[:, :, None], axis=1)


def ssd_block_apply(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                    positions=None, *, ctx: ShardCtx = NULL_CTX,
                    lengths: Optional[jnp.ndarray] = None,
                    want_cache: bool = False, **_):
    """Returns (x_out, aux), or with ``want_cache`` the 3-tuple
    (x_out, aux, cache) where cache is the decode state after a per-row
    prompt of ``lengths`` tokens: {"state", "conv_x", "conv_b", "conv_c"}
    exactly as :func:`ssd_block_decode` consumes them."""
    b, s, d = x.shape
    h = ctx.seq_gather(rms_norm(x, p["ln"]))
    z, xin_raw, bm_raw, cm_raw, dt = _ssd_pre(cfg, p, h)
    xin_f = jax.nn.silu(causal_conv1d(xin_raw, p["conv_x"]).astype(jnp.float32))
    bm_f = jax.nn.silu(causal_conv1d(bm_raw, p["conv_b"]).astype(jnp.float32))
    cm_f = jax.nn.silu(causal_conv1d(cm_raw, p["conv_c"]).astype(jnp.float32))
    xin, bm, cm = (t.astype(x.dtype) for t in (xin_f, bm_f, cm_f))
    nh, hd = cfg.ssm_num_heads, cfg.ssm_head_dim
    xh = xin.reshape(b, s, nh, hd)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y = kops.ssd(xh, dt, a, bm, cm, p["d_skip"].astype(jnp.float32))
    y = y.reshape(b, s, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["gate_ln"])
    out = x + ctx.ckpt_constrain(jnp.einsum("bse,ed->bsd", y, p["w_out"]))
    if not want_cache:
        return out, 0.0
    # Final SSM state at per-row prompt length T, in closed form:
    #   state_T = Σ_{t<T} exp(Σ_{u=t+1..T-1} dt_u·a) · dt_t · x_t ⊗ b_t
    # via log-space prefix sums — no (B,S,H,P,N) per-position states held.
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    xh_f = xin_f.reshape(b, s, nh, hd)
    logdecay = dt * a[None, None, :]                       # (B,S,H), <= 0
    cum = jnp.cumsum(logdecay, axis=1)
    cum_t = jnp.take_along_axis(cum, (lengths - 1)[:, None, None], axis=1)
    tmask = (jnp.arange(s)[None, :] < lengths[:, None])
    w = jnp.exp(jnp.minimum(cum_t - cum, 0.0)) * tmask[..., None]
    state = jnp.einsum("bsh,bshp,bsn->bhpn", w * dt, xh_f, bm_f)
    wc = cfg.ssm_conv_width
    cache = {
        "state": state,
        "conv_x": _conv_tail(xin_raw, wc, lengths),
        "conv_b": _conv_tail(bm_raw, wc, lengths),
        "conv_c": _conv_tail(cm_raw, wc, lengths),
    }
    return out, 0.0, cache


def ssd_block_decode(cfg: ModelConfig, p: Dict, x: jnp.ndarray, cache: Dict,
                     pos, *, ctx: ShardCtx = NULL_CTX, **_):
    """cache: {"state": (B,H,P,N) f32, "conv_x": (B,W-1,Di),
    "conv_b"/"conv_c": (B,W-1,N)}."""
    b = x.shape[0]
    h = rms_norm(x, p["ln"])
    z, xin, bm, cm, dt = _ssd_pre(cfg, p, h)
    xin, cx = causal_conv1d(xin, p["conv_x"], state=cache["conv_x"])
    bm, cb = causal_conv1d(bm, p["conv_b"], state=cache["conv_b"])
    cm, cc = causal_conv1d(cm, p["conv_c"], state=cache["conv_c"])
    xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)
    bm = jax.nn.silu(bm.astype(jnp.float32))[:, 0]       # (B, N) f32
    cm = jax.nn.silu(cm.astype(jnp.float32))[:, 0]
    nh, hd = cfg.ssm_num_heads, cfg.ssm_head_dim
    xh = xin.reshape(b, nh, hd).astype(jnp.float32)      # (B, H, P)
    dtv = dt[:, 0]                                       # (B, H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dtv * a[None, :])                    # (B, H)
    upd = (dtv[..., None] * xh)[..., None] * bm[:, None, None, :]
    state = decay[..., None, None] * cache["state"] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, cm)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["gate_ln"])
    out = x + jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, dict(cache, state=state, conv_x=cx, conv_b=cb, conv_c=cc)


def ssd_cache_spec(cfg: ModelConfig, batch: int, dtype) -> Dict:
    wc = cfg.ssm_conv_width
    return {
        "state": ((batch, cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state),
                  ("batch", "ssm_heads", None, "ssm_state"), jnp.float32),
        "conv_x": ((batch, wc - 1, cfg.d_inner), ("batch", None, "ssm_inner"), dtype),
        "conv_b": ((batch, wc - 1, cfg.ssm_state), ("batch", None, None), dtype),
        "conv_c": ((batch, wc - 1, cfg.ssm_state), ("batch", None, None), dtype),
    }


# ===========================================================================
# RG-LRU (recurrentgemma) block
# ===========================================================================

LRU_C = 8.0


def rglru_block_params(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "ln": ((d,), (None,), "ones"),
        "wx": ((d, w), ("embed", "lru"), "normal"),
        "wy": ((d, w), ("embed", "lru"), "normal"),
        "conv": ((4, w), ("conv", "lru"), "normal"),
        "w_r": ((w, w), (None, "lru"), "normal"),
        "w_i": ((w, w), (None, "lru"), "normal"),
        "b_r": ((w,), (None,), "zeros"),
        "b_i": ((w,), (None,), "zeros"),
        "a_log": ((w,), (None,), "ssm_a"),
        "w_out": ((w, d), ("lru", "embed_out"), "normal"),
    }


def _lru_gates(p, xb):
    r = jax.nn.sigmoid(
        (jnp.einsum("bsw,wv->bsv", xb, p["w_r"]) + p["b_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid(
        (jnp.einsum("bsw,wv->bsv", xb, p["w_i"]) + p["b_i"]).astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(p["a_log"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9))
    return a, beta * i


def rglru_block_apply(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                      positions=None, *, ctx: ShardCtx = NULL_CTX,
                      lengths: Optional[jnp.ndarray] = None,
                      want_cache: bool = False, **_):
    """Returns (x_out, aux), or with ``want_cache`` the 3-tuple
    (x_out, aux, cache): {"h", "conv"} — the recurrent state after a
    per-row prompt of ``lengths`` tokens, as :func:`rglru_block_decode`
    consumes it (handoff)."""
    h = ctx.seq_gather(rms_norm(x, p["ln"]))
    xb_raw = jnp.einsum("bsd,dw->bsw", h, p["wx"])
    yb = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, p["wy"]).astype(jnp.float32))
    xb = causal_conv1d(xb_raw, p["conv"])
    a, gate = _lru_gates(p, xb)
    bt = gate * xb.astype(jnp.float32)
    # h_t = a_t * h_{t-1} + b_t  — associative scan (TPU-parallel recurrence)
    def combine(lhs, rhs):
        return (rhs[0] * lhs[0], rhs[0] * lhs[1] + rhs[1])
    _, hseq = lax.associative_scan(combine, (a, bt), axis=1)
    y = (hseq * yb).astype(x.dtype)
    out = x + ctx.ckpt_constrain(jnp.einsum("bsw,wd->bsd", y, p["w_out"]))
    if not want_cache:
        return out, 0.0
    if lengths is None:
        lengths = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    h_state = jnp.take_along_axis(hseq, (lengths - 1)[:, None, None], axis=1)[:, 0]
    wd = p["conv"].shape[0]
    cache = {"h": h_state, "conv": _conv_tail(xb_raw, wd, lengths)}
    return out, 0.0, cache


def rglru_block_decode(cfg: ModelConfig, p: Dict, x: jnp.ndarray, cache: Dict,
                       pos, *, ctx: ShardCtx = NULL_CTX, **_):
    """cache: {"h": (B, W) f32, "conv": (B, 3, W)}."""
    hn = rms_norm(x, p["ln"])
    xb = jnp.einsum("bsd,dw->bsw", hn, p["wx"])
    yb = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", hn, p["wy"]).astype(jnp.float32))
    xb, conv_state = causal_conv1d(xb, p["conv"], state=cache["conv"])
    a, gate = _lru_gates(p, xb)
    hstate = a[:, 0] * cache["h"] + (gate * xb.astype(jnp.float32))[:, 0]
    y = (hstate[:, None, :] * yb).astype(x.dtype)
    out = x + jnp.einsum("bsw,wd->bsd", y, p["w_out"])
    return out, dict(cache, h=hstate, conv=conv_state)


def rglru_cache_spec(cfg: ModelConfig, batch: int, dtype) -> Dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": ((batch, w), ("batch", "lru"), jnp.float32),
        "conv": ((batch, 3, w), ("batch", None, "lru"), dtype),
    }
