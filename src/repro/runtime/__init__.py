from repro.runtime.train_loop import (init_opt_state, make_train_step,
                                      opt_state_specs, train_shardings,
                                      batch_specs)
from repro.runtime.serve_loop import (cache_shardings, greedy_decode,
                                      make_decode_step, make_prefill)
from repro.runtime.metrics import StepTimer, format_metrics

__all__ = ["make_train_step", "init_opt_state", "opt_state_specs",
           "train_shardings", "batch_specs", "make_decode_step",
           "make_prefill", "cache_shardings", "greedy_decode", "StepTimer",
           "format_metrics"]
