"""Keras2Plan — the Keras2DML/Caffe2DML analogue (paper §2).

Accepts a declarative layer spec (the Keras ``Sequential`` role), generates
the equivalent *DML-like script text* (inspectable, mirrors the paper's
generated-DML fidelity), and compiles train/score functions through the
plan compiler:

* ``train_algo="minibatch"``  — a for-loop over batches (the paper's
  generated minibatch script; single-node plan when everything fits)
* ``train_algo="batch"``      — full-batch steps (forces the distributed
  data-parallel plan when the data outgrows one device)
* ``test_algo="allreduce"``   — parfor task-parallel row-partitioned scoring

The sklearn-style ``fit(X, Y)`` / ``predict(X)`` entry points accept NumPy
arrays, matching the paper's "accepts NumPy arrays, SciPy matrices, or
Pandas DataFrames" interface (matrices only — frames are out of scope).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.parfor import parfor
from repro.core.sparsity import characteristics, select_format
from repro.nn.module import Sequential


def generate_dml(spec: List[dict], meta: Dict, optimizer: str, lr: float,
                 batch_size: int) -> str:
    """Generate the DML script a Keras2DML user would get (paper §2)."""
    lines = []
    kinds = sorted({s["kind"] for s in spec})
    for k in kinds:
        lines.append(f'source("nn/layers/{k}.dml") as {k}')
    lines.append(f'source("nn/optim/{optimizer}.dml") as {optimizer}')
    lines.append("")
    lines.append("train = function(matrix[double] X, matrix[double] Y) {")
    lines.append(f"  lr = {lr}; batch_size = {batch_size}")
    lines.append("  num_iter = nrow(X) / batch_size")
    for i, s in enumerate(spec):
        if s["kind"] == "affine":
            lines.append(f"  [W{i}, b{i}] = affine::init(D{i}, {s['units']})")
        elif s["kind"] == "conv2d":
            lines.append(
                f"  [W{i}, b{i}] = conv2d::init({s['filters']}, C{i}, "
                f"{s['kernel']}, {s['kernel']})")
    lines.append("  for (i in 1:num_iter) {")
    lines.append("    beg = (i-1)*batch_size + 1; end = beg + batch_size")
    lines.append("    X_batch = X[beg:end,]; y_batch = Y[beg:end,]")
    lines.append("    # forward")
    prev = "X_batch"
    for i, s in enumerate(spec):
        k = s["kind"]
        arg = f"{prev}, W{i}, b{i}" if k in ("affine", "conv2d") else prev
        lines.append(f"    out{i} = {k}::forward({arg})")
        prev = f"out{i}"
    lines.append("    # backward")
    lines.append(f"    dprobs = cross_entropy_loss::backward({prev}, y_batch)")
    grad = "dprobs"
    for i in reversed(range(len(spec))):
        k = spec[i]["kind"]
        if k in ("affine", "conv2d"):
            lines.append(
                f"    [d{i}, dW{i}, db{i}] = {k}::backward({grad}, ...)")
            lines.append(f"    W{i} = {optimizer}::update(W{i}, dW{i}, lr)")
            lines.append(f"    b{i} = {optimizer}::update(b{i}, db{i}, lr)")
        else:
            lines.append(f"    d{i} = {k}::backward({grad}, ...)")
        grad = f"d{i}"
    lines.append("  }")
    lines.append("}")
    return "\n".join(lines)


class Keras2Plan:
    """sklearn/MLPipeline-style estimator over the repro.nn runtime."""

    def __init__(self, spec: List[dict], meta: Dict, *,
                 optimizer: str = "sgd", lr: float = 0.01,
                 batch_size: int = 32, epochs: int = 1,
                 train_algo: str = "minibatch", test_algo: str = "allreduce",
                 mesh=None, seed: int = 0):
        if train_algo not in ("minibatch", "batch"):
            raise ValueError(train_algo)
        if test_algo not in ("allreduce", "serial"):
            raise ValueError(test_algo)
        self.spec, self.meta = spec, meta
        self.optimizer, self.lr = optimizer, lr
        self.batch_size, self.epochs = batch_size, epochs
        self.train_algo, self.test_algo = train_algo, test_algo
        self.mesh = mesh
        self.seed = seed
        self.module = Sequential(spec, meta)
        self.params = None
        self.opt_state = None
        self.dml_script = generate_dml(spec, meta, optimizer, lr, batch_size)
        self.history: List[float] = []
        self.format_decisions: Dict[str, str] = {}

    # ------------------------------------------------------------------
    def set(self, **kw) -> "Keras2Plan":
        for k, v in kw.items():
            if not hasattr(self, k):
                raise AttributeError(k)
            setattr(self, k, v)
        return self

    # ------------------------------------------------------------------
    def fit(self, X: np.ndarray, Y: np.ndarray) -> "Keras2Plan":
        X = np.asarray(X, np.float32)
        Y = np.asarray(Y, np.float32)
        # SystemML's format decision on the input matrix
        self.format_decisions["X"] = select_format(characteristics(X))
        key = jax.random.PRNGKey(self.seed)
        self.params = self.module.init(key)
        self.opt_state = self.module.init_opt_state(self.optimizer, self.params)
        step = self.module.make_train_step(self.optimizer, self.lr)
        n = X.shape[0]
        bs = n if self.train_algo == "batch" else self.batch_size
        t = 0
        for _ in range(self.epochs):
            for beg in range(0, n - bs + 1, bs):
                xb = jnp.asarray(X[beg:beg + bs])
                yb = jnp.asarray(Y[beg:beg + bs])
                t += 1
                self.params, self.opt_state, loss = step(
                    self.params, self.opt_state, xb, yb,
                    jax.random.PRNGKey(t), t=t)
                self.history.append(float(loss))
        return self

    # ------------------------------------------------------------------
    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        assert self.params is not None, "fit first"
        X = jnp.asarray(np.asarray(X, np.float32))
        if self.test_algo == "allreduce" and self.mesh is not None:
            out, plan = parfor(lambda rows: self.module.predict(self.params, rows),
                               X, mesh=self.mesh)
            self._last_score_plan = plan
            return np.asarray(out)
        self._last_score_plan = "serial"
        return np.asarray(self.module.predict(self.params, X))

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.argmax(self.predict_proba(X), axis=1)

    def score(self, X: np.ndarray, Y: np.ndarray) -> float:
        yhat = self.predict(X)
        y = np.argmax(np.asarray(Y), axis=1) if np.asarray(Y).ndim == 2 else np.asarray(Y)
        return float(np.mean(yhat == y))
