"""Serving runtime: prefill + batched decode under a plan.

The decode step is the paper's "low-latency scoring" end of the
"ranging from low-latency scoring to large-scale training" claim; batched
request scoring uses the parfor engine (``test_algo="allreduce"``).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import MeshConfig
from repro.core.sharding import spec_for, tree_specs
from repro.core.strategies import PlanConfig
from repro.models.common import ShardCtx


def make_decode_step(model, plan: PlanConfig, mesh_cfg: MeshConfig):
    ctx = ShardCtx(plan, mesh_cfg)

    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, ctx)

    return decode_step


def make_prefill(model, plan: PlanConfig, mesh_cfg: MeshConfig):
    ctx = ShardCtx(plan, mesh_cfg)

    def prefill(params, batch):
        extra = {k: v for k, v in batch.items() if k != "tokens"}
        return model.prefill(params, batch["tokens"], extra=extra, ctx=ctx)

    return prefill


def cache_shardings(model, batch: int, seq_len: int, plan: PlanConfig,
                    mesh_cfg: MeshConfig, mesh):
    specs, axes = model.cache_specs(batch, seq_len)
    parts = tree_specs(specs, axes, plan, mesh_cfg, "cache")
    shards = jax.tree.map(lambda sp: NamedSharding(mesh, sp), parts,
                          is_leaf=lambda x: isinstance(x, P))
    return specs, parts, shards


def greedy_decode(model, params, cache, first_token, start_pos, num_tokens,
                  decode_step=None):
    """Greedy generation loop (example/driver use)."""
    step = decode_step or (lambda p, c, t, q: model.decode_step(p, c, t, q))
    toks = first_token
    out = []
    pos = start_pos
    for _ in range(num_tokens):
        logits, cache = step(params, cache, toks, jnp.int32(pos))
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(toks)
        pos += 1
    return jnp.concatenate(out, axis=1), cache
