"""Paper claim (§3 Sparse Operations): sparsity-aware operator selection
"reduces the number of floating point operations and improves memory
efficiency". Benchmarked as: wall time + estimated FLOPs/bytes of the
auto-selected operator vs the dense operator across input densities."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparsity as S


def _time(fn, *args, reps=5):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(m=1024, k=1024, n=256):
    rows = []
    b = jnp.asarray(np.random.default_rng(1).standard_normal((k, n)), jnp.float32)
    dense_mm = jax.jit(lambda a, b: a @ b)
    for density in (0.005, 0.02, 0.05, 0.1, 0.2, 0.4, 0.8):
        rng = np.random.default_rng(0)
        a_np = rng.standard_normal((m, k)) * (rng.random((m, k)) < density)
        a = jnp.asarray(a_np, jnp.float32)
        mc = S.characteristics(a)
        op = S.select_matmul_operator(mc, S.MatrixCharacteristics(k, n, -1))
        us_dense = _time(dense_mm, a, b)
        if op.startswith("matmul_sparse"):
            csr = S.to_csr(a)
            spmm_j = jax.jit(S.spmm)
            us_sel = _time(spmm_j, csr, b)
        else:
            us_sel = us_dense
        flops_sel = S.sparse_flops_matmul(mc, S.MatrixCharacteristics(k, n, -1))
        flops_dense = 2 * m * k * n
        bytes_sel = min(mc.sparse_bytes(), mc.dense_bytes())
        rows.append(
            f"operator_selection_d{density},{us_sel:.1f},"
            f"op={op};flops_ratio={flops_sel / flops_dense:.3f};"
            f"bytes_ratio={bytes_sel / mc.dense_bytes():.3f};dense_us={us_dense:.1f}"
        )
    return rows
