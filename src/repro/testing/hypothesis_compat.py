"""Deterministic fallback for ``hypothesis`` in minimal environments.

The property tests in ``tests/`` are written against the real hypothesis
API. Some CI/sandbox images pin only the runtime deps (jax + pytest), so
this module provides a tiny drop-in subset: when hypothesis is installed
it is re-exported unchanged; otherwise ``@given`` runs each test against a
fixed number of seeded pseudo-random samples. This trades shrinking and
example databases for zero extra dependencies — the invariants still get
exercised across a spread of inputs.

Usage (in a test module)::

    try:
        import hypothesis.strategies as st
        from hypothesis import given, settings
    except ImportError:
        from repro.testing.hypothesis_compat import given, settings, st
"""

from __future__ import annotations

import random
from typing import Any, Callable, List, Sequence

try:  # real hypothesis wins whenever it is available
    import hypothesis.strategies as st  # type: ignore  # noqa: F401
    from hypothesis import given, settings  # type: ignore  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A sampler: ``draw(rng)`` returns one example."""

        def __init__(self, draw: Callable[[random.Random], Any]):
            self._draw = draw

        def draw(self, rng: random.Random) -> Any:
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value: int = 0, max_value: int = 1 << 16) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(seq: Sequence) -> _Strategy:
            items: List = list(seq)
            return _Strategy(lambda rng: items[rng.randrange(len(items))])

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def tuples(*strats: _Strategy) -> _Strategy:
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strats))

        @staticmethod
        def lists(elem: _Strategy, min_size: int = 0, max_size: int = 8) -> _Strategy:
            def draw(rng: random.Random):
                n = rng.randint(min_size, max_size)
                return [elem.draw(rng) for _ in range(n)]

            return _Strategy(draw)

    st = _St()

    def settings(max_examples: int = 20, **_ignored) -> Callable:
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*arg_strats: _Strategy, **kw_strats: _Strategy) -> Callable:
        def deco(fn):
            # No functools.wraps: pytest would read the wrapped signature
            # and treat the strategy parameters as fixtures.
            def wrapper():
                # read at call time: @settings may sit above OR below @given
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                rng = random.Random(0)
                for _ in range(n):
                    args = tuple(s.draw(rng) for s in arg_strats)
                    kwargs = {k: s.draw(rng) for k, s in kw_strats.items()}
                    fn(*args, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
