import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing harness.

For a chosen (arch x shape) pair, lower+compile a *series* of plan variants
(paper-faithful baseline -> planner default -> manual hypotheses) and record
the three roofline terms for each, so EXPERIMENTS.md §Perf can show the
hypothesis -> change -> before -> after chain.

    PYTHONPATH=src python -m repro.launch.perf_iterate --pair llama_train
"""

import argparse
import json

from repro.config import INPUT_SHAPES, TPU_V5E, TrainConfig
from repro.configs import get_config
from repro.core.cost import analytic_cost
from repro.core.memory import estimate_memory
from repro.core.planner import compile_plan
from repro.core.strategies import ExecutionPlan
from repro.launch.dryrun import lower_combo
from repro.launch.mesh import mesh_cfg_for

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "experiments", "perf")


def variants_llama_train():
    """llama3-405b x train_4k: worst memory/roofline pair."""
    arch, shape = "llama3-405b", "train_4k"
    cfg = get_config(arch)
    mesh_cfg = mesh_cfg_for()
    base_plan = compile_plan(cfg, INPUT_SHAPES[shape], mesh_cfg).config
    out = [
        ("paper_faithful_dp", dict(force_strategy="data_parallel")),
        ("planner_default", dict()),
        ("micro8", dict(plan_override_cfg=base_plan.replace(microbatches=8))),
        ("micro32", dict(plan_override_cfg=base_plan.replace(microbatches=32))),
        ("no_seq_ckpt", dict(plan_override_cfg=base_plan.replace(
            seq_shard_checkpoints=False))),
        ("fp32_opt", dict(plan_override_cfg=base_plan.replace(
            opt_state_dtype="float32"))),
    ]
    return arch, shape, out


def variants_qwen3_train():
    """qwen3-moe x train_4k: most collective-bound (EP all-to-all)."""
    arch, shape = "qwen3-moe-235b-a22b", "train_4k"
    cfg = get_config(arch)
    mesh_cfg = mesh_cfg_for()
    base = compile_plan(cfg, INPUT_SHAPES[shape], mesh_cfg).config
    return arch, shape, [
        ("paper_faithful_dp", dict(force_strategy="data_parallel")),
        ("planner_default", dict()),
        ("no_expert_parallel", dict(plan_override_cfg=base.replace(
            expert_parallel=False))),
        ("micro4", dict(plan_override_cfg=base.replace(microbatches=4))),
        ("micro8", dict(plan_override_cfg=base.replace(microbatches=8))),
    ]


def variants_yi_prefill():
    """yi-6b x prefill_32k: the paper's batch-scoring scenario."""
    arch, shape = "yi-6b", "prefill_32k"
    cfg = get_config(arch)
    mesh_cfg = mesh_cfg_for()
    base = compile_plan(cfg, INPUT_SHAPES[shape], mesh_cfg).config
    return arch, shape, [
        ("paper_faithful_dp", dict(force_strategy="data_parallel")),
        ("planner_default", dict()),
        ("context_parallel", dict(plan_override_cfg=base.replace(
            seq_axes=("model",)))),
        ("no_tensor_parallel", dict(plan_override_cfg=base.replace(
            tensor_parallel=False))),
    ]


PAIRS = {
    "llama_train": variants_llama_train,
    "qwen3_train": variants_qwen3_train,
    "yi_prefill": variants_yi_prefill,
}


def run_pair(name: str):
    arch, shape, variants = PAIRS[name]()
    os.makedirs(OUT, exist_ok=True)
    results = []
    for label, kw in variants:
        plan_override = None
        if "plan_override_cfg" in kw:
            cfg = get_config(arch)
            shp = INPUT_SHAPES[shape]
            mesh_cfg = mesh_cfg_for()
            pcfg = kw["plan_override_cfg"]
            plan_override = ExecutionPlan(
                model=cfg, shape=shp, mesh=mesh_cfg, config=pcfg,
                memory=estimate_memory(cfg, shp, mesh_cfg, pcfg, TrainConfig(), TPU_V5E),
                cost=analytic_cost(cfg, shp, mesh_cfg, pcfg, TPU_V5E),
            )
        try:
            rec, _, _ = lower_combo(
                arch, shape,
                force_strategy=kw.get("force_strategy"),
                plan_override=plan_override)
            rf, mem = rec["roofline"], rec["memory"]
            row = {
                "label": label,
                "compute_s": rf["compute_s"],
                "memory_s": rf["memory_s"],
                "collective_s": rf["collective_s"],
                "dominant": rf["dominant"],
                "step_lower_bound_s": rf["step_time_lower_bound_s"],
                "useful_flops": rf["useful_flops_ratio"],
                "peak_gib": mem["peak_estimate_bytes"] / 2**30,
                "collectives_gib": {k: v / 2**30 for k, v in
                                    rec["hlo_cost"]["collectives"].items()},
            }
        except Exception as e:  # noqa: BLE001
            row = {"label": label, "error": f"{type(e).__name__}: {e}"}
        results.append(row)
        print(json.dumps(row), flush=True)
    with open(os.path.join(OUT, f"{name}.json"), "w") as f:
        json.dump({"arch": arch, "shape": shape, "results": results}, f, indent=1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=list(PAIRS) + ["all"], default="all")
    args = ap.parse_args()
    pairs = list(PAIRS) if args.pair == "all" else [args.pair]
    for p in pairs:
        print(f"== {p}")
        run_pair(p)


if __name__ == "__main__":
    main()
