"""Plan compiler behaviour + property tests (hypothesis).

The invariants mirror SystemML's optimizer contracts: never pick a plan
whose worst-case estimate exceeds the budget if a fitting plan exists;
escalate monotonically with model size; single-device -> single-node plan.
"""

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # minimal images: seeded deterministic fallback
    from repro.testing.hypothesis_compat import given, settings, st

from repro.config import (INPUT_SHAPES, SINGLE_DEVICE_MESH, SINGLE_POD_MESH,
                          MULTI_POD_MESH, TPU_V5E, HardwareSpec, TrainConfig)
from repro.configs import ARCH_IDS, get_config
from repro.core.memory import estimate_memory
from repro.core.planner import PlanCompiler, compile_plan
from repro.core.sharding import spec_for
from repro.core.strategies import PlanConfig, Strategy


def test_single_device_gets_local_plan():
    cfg = get_config("yi-6b-smoke")
    plan = compile_plan(cfg, INPUT_SHAPES["train_4k"], SINGLE_DEVICE_MESH)
    assert plan.config.strategy == Strategy.LOCAL


def test_small_model_stays_data_parallel():
    """Paper-faithful behaviour: when replicated weights fit, SystemML's
    data-parallel plan is chosen (cheapest in the lattice)."""
    cfg = get_config("whisper-medium")
    plan = compile_plan(cfg, INPUT_SHAPES["long_500k"], SINGLE_POD_MESH)
    assert plan.config.strategy in (Strategy.DATA_PARALLEL, Strategy.DP_TP)


def test_huge_model_escalates():
    cfg = get_config("llama3-405b")
    plan = compile_plan(cfg, INPUT_SHAPES["train_4k"], SINGLE_POD_MESH)
    assert plan.config.strategy == Strategy.FSDP_TP
    assert plan.config.params_over_data
    assert plan.config.opt_state_dtype == "bfloat16"  # plan-chosen compression


def test_force_strategy():
    cfg = get_config("llama3-405b")
    t = TrainConfig(force_strategy="data_parallel")
    plan = compile_plan(cfg, INPUT_SHAPES["train_4k"], SINGLE_POD_MESH, t)
    assert plan.config.strategy == Strategy.DATA_PARALLEL


def test_moe_gets_expert_parallel():
    cfg = get_config("qwen3-moe-235b-a22b")
    plan = compile_plan(cfg, INPUT_SHAPES["train_4k"], SINGLE_POD_MESH)
    assert plan.config.expert_parallel


def test_long_context_gets_window_variant():
    cfg = get_config("yi-6b")
    plan = compile_plan(cfg, INPUT_SHAPES["long_500k"], SINGLE_POD_MESH)
    assert plan.config.attention_variant == "window"


def test_ssm_has_no_attention_variant():
    cfg = get_config("mamba2-1.3b")
    plan = compile_plan(cfg, INPUT_SHAPES["long_500k"], SINGLE_POD_MESH)
    assert plan.config.attention_variant == "none"


def test_multi_pod_batch_axes_include_pod():
    cfg = get_config("granite-8b")
    plan = compile_plan(cfg, INPUT_SHAPES["train_4k"], MULTI_POD_MESH)
    assert "pod" in plan.config.batch_axes


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_every_combo_produces_a_plan(arch, shape):
    cfg = get_config(arch)
    plan = compile_plan(cfg, INPUT_SHAPES[shape], SINGLE_POD_MESH)
    assert plan.memory is not None and plan.cost is not None
    assert plan.explain()  # EXPLAIN renders


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------


@given(budget_gib=st.integers(min_value=4, max_value=256))
@settings(max_examples=20, deadline=None)
def test_bigger_budget_never_picks_more_distributed_plan(budget_gib):
    """Monotonicity: growing the memory budget can only move the chosen
    strategy *earlier* in the lattice (SystemML: more driver memory ->
    more single-node plans)."""
    cfg = get_config("phi3-medium-14b")
    shape = INPUT_SHAPES["train_4k"]
    hw_small = HardwareSpec(hbm_bytes=budget_gib * 1024**3)
    hw_big = HardwareSpec(hbm_bytes=2 * budget_gib * 1024**3)
    p_small = PlanCompiler(hw_small).compile(cfg, shape, SINGLE_POD_MESH)
    p_big = PlanCompiler(hw_big).compile(cfg, shape, SINGLE_POD_MESH)
    assert p_big.config.strategy.order <= p_small.config.strategy.order


@given(st.sampled_from(ARCH_IDS), st.sampled_from(list(INPUT_SHAPES)))
@settings(max_examples=40, deadline=None)
def test_memory_estimate_positive_and_fsdp_smaller(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = SINGLE_POD_MESH
    t = TrainConfig()
    dp = PlanConfig(strategy=Strategy.DATA_PARALLEL, batch_axes=("data",))
    fsdp = dp.replace(strategy=Strategy.FSDP_TP, tensor_parallel=True,
                      params_over_data=True,
                      expert_parallel=cfg.num_experts > 0)
    m_dp = estimate_memory(cfg, shape, mesh, dp, t, TPU_V5E)
    m_fsdp = estimate_memory(cfg, shape, mesh, fsdp, t, TPU_V5E)
    assert m_dp.total > 0 and m_fsdp.total > 0
    assert m_fsdp.per_device["params"] < m_dp.per_device["params"]


@given(
    shape=st.tuples(st.sampled_from([16, 64, 128, 4096]),
                    st.sampled_from([16, 32, 4096, 51865])),
    tp=st.booleans(), fsdp=st.booleans(),
)
@settings(max_examples=50, deadline=None)
def test_spec_for_valid(shape, tp, fsdp):
    """Sharding rules never assign one mesh axis twice and never produce a
    non-divisible split."""
    plan = PlanConfig(strategy=Strategy.DP_TP, batch_axes=("data",),
                      tensor_parallel=tp, params_over_data=fsdp)
    spec = spec_for(shape, ("ffn", "embed"), plan, SINGLE_POD_MESH, "param")
    used = []
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for ax in axes:
            assert ax not in used, spec
            used.append(ax)
        size = 1
        for ax in axes:
            size *= dict(zip(SINGLE_POD_MESH.axis_names, SINGLE_POD_MESH.shape))[ax]
        assert shape[i] % size == 0, (shape, spec)
