"""Call-graph-weighted cost extraction from compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
while-loop body (our layer scan) is not multiplied by its trip count, so raw
numbers under-count a 126-layer model by ~126x. This parser rebuilds the
call graph (ENTRY -> fusions / while bodies / to_apply reducers), reads each
while's ``known_trip_count`` from its backend_config, and accumulates:

* flops           — 2*M*N*K for dot/convolution (operand shapes resolved
                    through the per-computation symbol table), 1/elem for
                    everything else
* hbm_bytes       — operand + result bytes of every *top-level* op in
                    unfused computations (fusion internals are VMEM-only)
* collective_bytes— per collective kind from result shapes:
                    all-gather: result; all-reduce: 2x result;
                    reduce-scatter: result x group; all-to-all /
                    collective-permute: result
  (per-chip traffic; the compiled module is already per-chip SPMD)

All values are **per chip per step**.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_TYPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\s*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# ops counted as HBM traffic (fusion-boundary model of a TPU schedule)
_HBM_OPS = frozenset({
    "dot", "convolution", "custom-call", "fusion", "dynamic-slice",
    "dynamic-update-slice", "reduce", "reduce-window", "scatter", "gather",
    "transpose", "concatenate", "slice", "select-and-scatter", "sort",
    "cholesky", "triangular-solve", "fft", "pad", "reverse",
} | set(COLLECTIVE_OPS))


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _TYPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # text after the opening paren (operands + attrs)


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> type str


@dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    collective_count: int = 0

    def __iadd__(self, o: "HloCost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v
        self.collective_count += o.collective_count
        return self

    def scaled(self, m: float) -> "HloCost":
        return HloCost(self.flops * m, self.hbm_bytes * m,
                       self.collective_bytes * m,
                       {k: v * m for k, v in self.collectives.items()},
                       int(self.collective_count * m))

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "collective_count": self.collective_count,
        }


def parse_hlo(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    entry_name = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and "{" in line:
                cur = _Computation(m.group(1))
                if line.startswith("ENTRY"):
                    entry_name = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        cur.ops.append(_Op(name, type_str.strip(), opcode, rest))
        cur.symbols[name] = type_str.strip()
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _dot_flops(op: _Op, comp: _Computation) -> float:
    out_dims = _shape_dims(op.type_str) or []
    out_elems = math.prod(out_dims) if out_dims else 1
    # contraction size from lhs operand shape + lhs_contracting_dims
    ops = _OPERAND_RE.findall(op.rest.split(", lhs_contracting_dims")[0])
    k = 1
    mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if ops and mcd and ops[0] in comp.symbols:
        lhs_dims = _shape_dims(comp.symbols[ops[0]])
        if lhs_dims is not None and mcd.group(1):
            for ci in mcd.group(1).split(","):
                ci = int(ci)
                if ci < len(lhs_dims):
                    k *= lhs_dims[ci]
    return 2.0 * out_elems * k


def _conv_flops(op: _Op, comp: _Computation) -> float:
    out_elems = math.prod(_shape_dims(op.type_str) or [1])
    ops = _OPERAND_RE.findall(op.rest)
    k = 1
    if len(ops) >= 2 and ops[1] in comp.symbols:
        rhs = _shape_dims(comp.symbols[ops[1]]) or [1]
        # OIHW-ish: everything but the output-feature dim contracts
        k = max(1, math.prod(rhs) // max(1, max(rhs)))
    return 2.0 * out_elems * k


def _collective_bytes(op: _Op) -> float:
    b = _shape_bytes(op.type_str)
    g = 1
    mg = _GROUPS_RE.search(op.rest)
    if mg:
        g = int(mg.group(2))
    if op.opcode == "all-reduce":
        return 2.0 * b * (g - 1) / max(1, g)
    if op.opcode == "reduce-scatter":
        return float(b * g)
    if op.opcode == "all-gather":
        return float(b)
    return float(b)  # all-to-all, collective-permute


def analyze(text: str) -> HloCost:
    comps = parse_hlo(text)
    memo: Dict[str, HloCost] = {}

    def cost_of(name: str, fused: bool) -> HloCost:
        key = f"{name}|{fused}"
        if key in memo:
            return memo[key]
        memo[key] = HloCost()  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        total = HloCost()
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                mb, mc = _BODY_RE.search(op.rest), _COND_RE.search(op.rest)
                trip = 1
                mt = _TRIP_RE.search(op.rest)
                if mt:
                    trip = int(mt.group(1))
                elif mc:
                    trip = _cond_trip_count(comps.get(mc.group(1))) or 1
                inner = HloCost()
                if mb:
                    inner += cost_of(mb.group(1), False)
                if mc:
                    inner += cost_of(mc.group(1), False)
                total += inner.scaled(trip)
                continue
            if oc in ("fusion", "call", "async-start"):
                mcalls = _CALLS_RE.search(op.rest) or _APPLY_RE.search(op.rest)
                if mcalls:
                    total += cost_of(mcalls.group(1), True)
                if not fused:
                    total.hbm_bytes += _op_io_bytes(op, comp)
                continue
            if oc == "conditional":
                for branch in re.findall(r"%([\w\.\-]+)", op.rest):
                    if branch in comps:
                        total += cost_of(branch, False)
                continue
            if oc in COLLECTIVE_OPS:
                cb = _collective_bytes(op)
                total.collective_bytes += cb
                total.collectives[oc] = total.collectives.get(oc, 0.0) + cb
                total.collective_count += 1
                if not fused:
                    total.hbm_bytes += _op_io_bytes(op, comp)
                continue
            if oc == "dot":
                total.flops += _dot_flops(op, comp)
            elif oc == "convolution":
                total.flops += _conv_flops(op, comp)
            elif oc == "custom-call" and "matmul" in op.rest:
                total.flops += _dot_flops(op, comp)
            elif oc not in ("parameter", "constant", "get-tuple-element",
                            "tuple", "bitcast", "partition-id", "replica-id",
                            "after-all", "iota", "convert", "copy"):
                total.flops += math.prod(_shape_dims(op.type_str) or [1])
            # HBM traffic: only ops that exist at fusion boundaries on TPU.
            # XLA:CPU's bf16->f32 `convert`/`copy` scaffolding is excluded —
            # on TPU those run natively in bf16 inside fusions.
            if not fused and oc in _HBM_OPS:
                total.hbm_bytes += _op_io_bytes(op, comp)
        memo[key] = total
        return total

    return cost_of("__entry__", False)


_CONST_RE = re.compile(r"constant\((\d+)\)")


def _cond_trip_count(comp: Optional[_Computation]) -> Optional[int]:
    """Fallback trip count: the largest integer constant in the loop's
    condition computation (induction variables start at 0 with step 1 in
    XLA-lowered scans)."""
    if comp is None:
        return None
    best = None
    for op in comp.ops:
        if op.opcode != "constant":
            continue
        m = _CONST_RE.search(op.type_str + " constant(" + op.rest)
        if m:
            v = int(m.group(1))
            if best is None or v > best:
                best = v
    return best


def _op_io_bytes(op: _Op, comp: _Computation) -> float:
    b = float(_shape_bytes(op.type_str))
    attr_cut = op.rest
    for marker in ("metadata=", "backend_config=", "calls=", "to_apply=",
                   "condition=", "body="):
        idx = attr_cut.find(marker)
        if idx >= 0:
            attr_cut = attr_cut[:idx]
    for operand in _OPERAND_RE.findall(attr_cut):
        if operand in comp.symbols:
            b += _shape_bytes(comp.symbols[operand])
    return b
