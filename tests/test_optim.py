"""The paper's six optimizers: convergence on a quadratic + slot counts +
plan-chosen state compression."""

import jax.numpy as jnp
import pytest

from repro.nn.optim import (OPTIMIZERS, OPTIMIZER_SLOTS, clip_by_global_norm,
                            get_optimizer, tree_init, tree_update)

LRS = {"sgd": 0.1, "sgd_momentum": 0.05, "sgd_nesterov": 0.05,
       "adagrad": 0.5, "rmsprop": 0.05, "adam": 0.2}


def test_paper_six_optimizers_present():
    assert set(OPTIMIZERS) == {"sgd", "sgd_momentum", "sgd_nesterov",
                               "adagrad", "rmsprop", "adam"}


@pytest.mark.parametrize("name", list(OPTIMIZERS))
def test_optimizer_converges_on_quadratic(name):
    opt = get_optimizer(name)
    target = jnp.array([1.0, -2.0, 3.0])
    p = jnp.zeros(3)
    state = opt.init(p)
    for t in range(1, 200):
        g = p - target
        p, state = opt.update(p, g, state, lr=LRS[name], t=t)
    assert float(jnp.max(jnp.abs(p - target))) < 0.05, (name, p)


@pytest.mark.parametrize("name", list(OPTIMIZERS))
def test_slot_counts(name):
    opt = get_optimizer(name)
    p = jnp.zeros((4, 4))
    assert len(opt.init(p)) == OPTIMIZER_SLOTS[name] == opt.slots


def test_bf16_state_compression():
    """Plan-chosen opt-state dtype (DESIGN §4): states live in bf16 but
    updates still converge."""
    opt = get_optimizer("adam")
    target = jnp.array([1.0, -2.0, 3.0])
    p = jnp.zeros(3)
    state = opt.init(p, dtype=jnp.bfloat16)
    assert all(s.dtype == jnp.bfloat16 for s in state)
    for t in range(1, 300):
        g = p - target
        p, state = opt.update(p, g, state, lr=0.1, t=t)
        assert all(s.dtype == jnp.bfloat16 for s in state)
    assert float(jnp.max(jnp.abs(p - target))) < 0.1


def test_tree_update_dict():
    params = {"a": jnp.ones(3), "b": jnp.zeros((2, 2))}
    grads = {"a": jnp.ones(3), "b": jnp.ones((2, 2))}
    state = tree_init("sgd_momentum", params)
    new_p, new_s = tree_update("sgd_momentum", params, grads, state, lr=0.1)
    assert new_p["a"].shape == (3,)
    assert float(new_p["a"][0]) < 1.0


def test_clip_by_global_norm():
    tree = {"a": jnp.full(4, 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4
    assert float(norm) == pytest.approx(20.0)
