"""Runtime sanitizer: per-tick structural assertions over the live stack.

The lint and plan-audit passes are static; this pass is the dynamic
counterpart — a from-scratch recount of the invariants the pool, engine,
and router maintain incrementally. Every check walks raw structures
(``_row_pages``, the allocator free set, member row lists) and rebuilds
the derived quantity (``live_bytes``, page counts, handle liveness)
independently, so drift in the incremental bookkeeping — the PR-4
recycled-arena leak class — fails the tick it happens instead of
surfacing ticks later as a corrupted decode.

Enabled with ``EngineConfig(sanitize=True)`` (or ``serve.py --sanitize``):
:class:`~repro.runtime.engine.ServingEngine` and
:class:`~repro.runtime.router.EngineRouter` then run :func:`check_engine`
/ :func:`check_router` at the end of every tick and after every
cancel/withdraw, raising :class:`SanitizeError` on the first violating
tick. The checks are pure-Python dict/set walks over host-side metadata —
no device sync — so the whole test suite can run sanitized.

This module deliberately imports nothing from ``repro.runtime`` (the
engine imports *it*); every check duck-types its subject.
"""

from __future__ import annotations

from typing import Iterable, List

from repro.analysis import Finding


class SanitizeError(AssertionError):
    """One or more sanitizer invariants failed this tick."""

    def __init__(self, findings: Iterable[Finding]):
        self.findings = list(findings)
        lines = "\n".join(f"  {f}" for f in self.findings)
        super().__init__(f"sanitizer: {len(self.findings)} violation(s)\n"
                         f"{lines}")


def _f(rule: str, where: str, detail: str, **data) -> Finding:
    return Finding(rule=rule, where=where, detail=detail, data=data)


# ---------------------------------------------------------------------------
# arena / pool
# ---------------------------------------------------------------------------


def check_arena(arena, where: str) -> List[Finding]:
    """Structural invariants of one :class:`CacheArena`: row free-list
    sanity, page-lease disjointness, allocator/page-table agreement."""
    out: List[Finding] = []
    free = list(arena._free)
    if len(free) != len(set(free)):
        out.append(_f("row-double-free", where,
                      f"duplicate rows in free list {sorted(free)}"))
    bad = [r for r in free if not 0 <= r < arena.batch]
    if bad:
        out.append(_f("row-range", where, f"free rows out of range {bad}"))
    leased_rows = set(range(arena.batch)) - set(free)

    if not (arena.page and arena.n_pages):
        return out

    alloc = arena.allocator
    seen = {}
    for row, pages in arena._row_pages.items():
        if row not in leased_rows:
            out.append(_f("page-orphan", where,
                          f"row {row} holds {len(pages)} page(s) but is "
                          f"on the free list"))
        for p in pages:
            if not 0 <= p < arena.n_pages:
                out.append(_f("page-range", where,
                              f"row {row} holds out-of-range page {p}"))
            elif p in seen:
                out.append(_f("page-double-lease", where,
                              f"page {p} leased to rows {seen[p]} and "
                              f"{row}"))
            elif p in alloc._free_set:
                out.append(_f("page-double-lease", where,
                              f"page {p} leased to row {row} but also on "
                              f"the allocator free list"))
            seen[p] = row

    # conservation: every physical page is either free or leased once
    n_accounted = len(alloc._free_set) + sum(
        len(p) for p in arena._row_pages.values())
    if n_accounted != arena.n_pages:
        out.append(_f("page-leak", where,
                      f"{arena.n_pages} pages, {n_accounted} accounted "
                      f"(free {len(alloc._free_set)} + leased "
                      f"{n_accounted - len(alloc._free_set)})"))
    res = sum(arena._row_reserved.values())
    if alloc.reserved != res:
        out.append(_f("reserve-drift", where,
                      f"allocator reserves {alloc.reserved} page(s), rows "
                      f"reserve {res}"))
    if alloc.reserved > len(alloc._free_set):
        out.append(_f("reserve-overcommit", where,
                      f"{alloc.reserved} reserved > "
                      f"{len(alloc._free_set)} free"))
    for name, keys in (("_row_reserved", arena._row_reserved),
                       ("_row_slots", arena._row_slots)):
        stray = set(keys) - set(arena._row_pages)
        if stray:
            out.append(_f("page-orphan", where,
                          f"{name} tracks rows {sorted(stray)} with no "
                          f"page lease"))

    # page-table agreement: leased pages appear in the row's table prefix,
    # everything past the lease is the unallocated sentinel
    for row in range(arena.batch):
        tab = arena._tables_np[row]
        pages = arena._row_pages.get(row, [])
        want = list(pages) + [arena.n_pages] * (arena.max_pages - len(pages))
        if list(tab) != want:
            out.append(_f("table-drift", where,
                          f"row {row} table {list(tab)} != leased pages "
                          f"{pages} + sentinel"))
    return out


def recount_live_bytes(pool) -> float:
    """``KVCachePool.live_bytes`` rebuilt from raw structures: committed
    pages (leased + reserved) plus leased rows' per-row state for paged
    arenas, the full arena footprint otherwise."""
    total = 0.0
    for a in pool._leased:
        if a.page:
            # page-mode accounting also covers arenas with zero paged
            # entries (pure-recurrent families): all row state, no pages
            pages = sum(len(p) for p in a._row_pages.values())
            pages += sum(a._row_reserved.values())
            total += pages * a.page_nbytes
            total += (a.batch - len(a._free)) * a.row_nbytes
        else:
            total += a.nbytes
    return total


def check_pool(pool, where: str = "pool") -> List[Finding]:
    """Pool-level invariants: every arena's structure, lease/free-list
    disjointness, and ``live_bytes()`` vs. a from-scratch recount."""
    out: List[Finding] = []
    for i, a in enumerate(pool._leased):
        out.extend(check_arena(a, f"{where}.leased[{i}]"))
    for i, a in enumerate(pool._pooled):
        aw = f"{where}.pooled[{i}]"
        out.extend(check_arena(a, aw))
        if a.rows_used:
            out.append(_f("arena-leak", aw,
                          f"pooled arena still has {a.rows_used} leased "
                          f"row(s)"))
        if a.page and a.n_pages and a._row_pages:
            out.append(_f("page-leak", aw,
                          f"pooled arena still holds "
                          f"{sum(len(p) for p in a._row_pages.values())} "
                          f"page(s)"))
    both = set(id(a) for a in pool._leased) & set(id(a) for a in pool._pooled)
    if both:
        out.append(_f("arena-double-lease", where,
                      f"{len(both)} arena(s) both leased and pooled"))
    live = pool.live_bytes()
    recount = recount_live_bytes(pool)
    if abs(live - recount) > max(1.0, 1e-6 * max(live, recount)):
        out.append(_f("live-bytes-drift", where,
                      f"live_bytes()={live:.0f} but recount={recount:.0f}",
                      live=live, recount=recount))
    if pool.max_bytes and live - pool.max_bytes > 1.0:
        out.append(_f("byte-budget-breach", where,
                      f"live {live:.0f} > budget {pool.max_bytes:.0f}"))
    return out


# ---------------------------------------------------------------------------
# engine / router
# ---------------------------------------------------------------------------


def check_engine(engine, where: str = "engine") -> List[Finding]:
    """Engine-level invariants on top of the pool checks: group rows match
    live members exactly, and the handle map tracks in-flight work only
    (no leaked handles after retire, no untracked live requests)."""
    out = check_pool(engine.server.pool, where=f"{where}.pool")
    queued = {qr.rid for qr in engine.queue.pending}
    member_rids = set()
    live_rids = set(queued)
    for gi, g in enumerate(engine.active):
        gw = f"{where}.active[{gi}]"
        rows: dict = {}
        for m in g.members:
            member_rids.add(m.qr.rid)
            if m.done:
                continue
            live_rids.add(m.qr.rid)
            for r in m.rows:
                if r in rows:
                    out.append(_f("row-double-lease", gw,
                                  f"row {r} held by rids {rows[r]} and "
                                  f"{m.qr.rid}"))
                rows[r] = m.qr.rid
        leased = set(range(g.arena.batch)) - set(g.arena._free)
        if set(rows) != leased:
            out.append(_f("row-lease-drift", gw,
                          f"members hold rows {sorted(rows)} but arena "
                          f"leases {sorted(leased)}"))
    for rid in engine.handles:
        if rid not in queued and rid not in member_rids:
            out.append(_f("handle-leak", where,
                          f"handle for rid {rid} is neither queued nor in "
                          f"an active group"))
    for rid in sorted(live_rids):
        if rid not in engine.handles:
            out.append(_f("handle-missing", where,
                          f"live rid {rid} has no tracked handle"))
    if (engine._events.maxlen is not None
            and len(engine._events) > engine._events.maxlen):
        out.append(_f("event-buffer-leak", where,
                      f"{len(engine._events)} events exceed the "
                      f"{engine._events.maxlen} cap"))
    return out


def check_router(router, where: str = "router") -> List[Finding]:
    """Fleet-level invariants: every replica's engine, plus router-handle
    placement (a live handle points at exactly one non-draining-or-live
    replica engine that still tracks it)."""
    out: List[Finding] = []
    for r in router.replicas:
        out.extend(check_engine(r.engine, where=f"{where}.replica[{r.idx}]"))
    for rid, h in router.handles.items():
        if h.rid != rid:
            out.append(_f("handle-leak", where,
                          f"handle keyed {rid} carries rid {h.rid}"))
        if h.done:
            out.append(_f("handle-leak", where,
                          f"finished rid {rid} still tracked (terminal "
                          f"event not forwarded?)"))
            continue
        if h.inner is None or h.replica is None:
            out.append(_f("handle-missing", where,
                          f"live rid {rid} has no replica placement"))
            continue
        eng = h.replica.engine
        queued = {qr.rid for qr in eng.queue.pending}
        members = {m.qr.rid for g in eng.active for m in g.members}
        if rid not in queued and rid not in members:
            out.append(_f("handle-missing", where,
                          f"rid {rid} placed on replica {h.replica.idx} "
                          f"but that engine does not hold it"))
    if (router._events.maxlen is not None
            and len(router._events) > router._events.maxlen):
        out.append(_f("event-buffer-leak", where,
                      f"{len(router._events)} events exceed the "
                      f"{router._events.maxlen} cap"))
    return out


# ---------------------------------------------------------------------------
# entry points the runtime calls
# ---------------------------------------------------------------------------


def assert_engine(engine) -> None:
    """Raise :class:`SanitizeError` if any engine invariant fails."""
    found = check_engine(engine)
    if found:
        raise SanitizeError(found)


def assert_router(router) -> None:
    """Raise :class:`SanitizeError` if any fleet invariant fails."""
    found = check_router(router)
    if found:
        raise SanitizeError(found)
