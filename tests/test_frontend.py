"""Keras2Plan frontend (paper §2): DML script generation, fit/predict,
train_algo variants, sparsity-aware input format decision."""

import numpy as np
import pytest

from repro.configs.lenet import make_spec as lenet_spec
from repro.configs.softmax_classifier import make_spec as softmax_spec
from repro.data import SyntheticClassification
from repro.frontend import Keras2Plan, generate_dml


def _fit_softmax(train_algo="minibatch", density=1.0, epochs=3):
    spec, meta = softmax_spec(num_features=20, num_classes=4)
    data = SyntheticClassification(20, 4, density=density)
    x, y = data.batch(512)
    est = Keras2Plan(spec, meta, optimizer="sgd", lr=0.5, batch_size=64,
                     epochs=epochs, train_algo=train_algo)
    est.fit(x, y)
    return est, x, y


def test_dml_script_generation():
    spec, meta = softmax_spec(20, 4)
    script = generate_dml(spec, meta, "sgd", 0.01, 32)
    # the structural elements of the paper's §2 generated script
    assert 'source("nn/layers/affine.dml") as affine' in script
    assert 'source("nn/optim/sgd.dml") as sgd' in script
    assert "for (i in 1:num_iter)" in script
    assert "affine::forward" in script
    assert "sgd::update" in script
    assert "cross_entropy_loss::backward" in script


def test_fit_reduces_loss_and_predicts():
    est, x, y = _fit_softmax()
    assert est.history[-1] < est.history[0] * 0.7
    acc = est.score(x, y)
    assert acc > 0.6, acc
    probs = est.predict_proba(x[:10])
    np.testing.assert_allclose(probs.sum(axis=1), 1.0, rtol=1e-4)


def test_batch_algo_runs():
    est, x, y = _fit_softmax(train_algo="batch", epochs=30)
    assert est.history[-1] < est.history[0]


def test_sparse_input_format_decision():
    est, _, _ = _fit_softmax(density=0.05)
    assert est.format_decisions["X"] == "sparse"
    est2, _, _ = _fit_softmax(density=1.0)
    assert est2.format_decisions["X"] == "dense"


def test_invalid_algo_rejected():
    spec, meta = softmax_spec(4, 2)
    with pytest.raises(ValueError):
        Keras2Plan(spec, meta, train_algo="nope")


def test_lenet_compiles_and_trains_one_epoch():
    spec, meta = lenet_spec(input_shape=(1, 8, 8), num_classes=4)
    est = Keras2Plan(spec, meta, optimizer="sgd_momentum", lr=0.02,
                     batch_size=16, epochs=1)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 64)).astype(np.float32)
    y = np.eye(4, dtype=np.float32)[rng.integers(0, 4, 64)]
    est.fit(x, y)
    assert np.isfinite(est.history).all()
    assert est.predict(x[:5]).shape == (5,)
