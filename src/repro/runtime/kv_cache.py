"""Row-addressable KV-cache pool for the serving path.

The decode KV cache is the serving path's single largest memory object, yet
the seed treated it as a per-group throwaway blob: every group called
``model.init_cache`` itself, prefill state was discarded, and the planner
never saw the bytes. This module gives the cache a single owner:

- :class:`CacheArena` — one bucket-shaped cache pytree (exactly what
  ``model.init_cache(batch_bucket, seq_bucket)`` builds) whose *batch rows*
  are individually leasable. Rows at different generation depths coexist in
  one arena because the decode step takes a per-row position vector.
- :class:`KVCachePool` — owns every arena: leases them to request groups,
  recycles fully-freed arenas (no reallocation), scatters prefill-produced
  cache rows into leased arenas (the prefill→decode handoff write), and
  accounts live bytes for the planner. A leased arena's free rows are where
  the scheduler lands mid-decode joins.

The pool's live bytes feed :class:`~repro.core.strategies.RuntimeStats`
(``cache_pool_bytes``): when the pool outgrows the plan's compile-time
cache statistic, dynamic recompilation triggers exactly like an
activation-watermark breach (``core.plan_cache.recompile_reasons``).

Budgets (``max_arenas`` / ``max_bytes``) bound the pool the way an HBM
reservation would: ``acquire`` refuses new arenas beyond the budget (the
scheduler then queues the group — or joins its requests into free rows of
in-flight arenas instead, which is the whole point).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PoolMetrics:
    """Pool-level accounting surfaced through ``scheduler_summary``."""

    arenas_created: int = 0
    arenas_reused: int = 0      # leases served from the free pool
    arenas_denied: int = 0      # acquire refused by budget
    arenas_evicted: int = 0     # free arenas dropped (LRU cap / budget)
    rows_leased: int = 0
    rows_reused: int = 0        # leased rows whose arena had a prior tenant
    handoff_writes: int = 0     # prefill→decode row scatters
    peak_bytes: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "arenas_created": self.arenas_created,
            "arenas_reused": self.arenas_reused,
            "arenas_denied": self.arenas_denied,
            "arenas_evicted": self.arenas_evicted,
            "rows_leased": self.rows_leased,
            "rows_reused": self.rows_reused,
            "handoff_writes": self.handoff_writes,
            "peak_bytes": self.peak_bytes,
        }


class CacheArena:
    """One bucket-shaped cache whose batch rows are individually leasable.

    ``cache`` is the live pytree threaded through the jitted decode step;
    the pool replaces it wholesale on handoff writes. Row bookkeeping
    (which rows are leased) is host-side — the device arrays never need to
    know, because free rows are simply masked out by their position vector
    and their outputs ignored.
    """

    def __init__(self, batch: int, seq: int, cache: Dict[str, Any],
                 nbytes: float):
        self.batch = batch
        self.seq = seq
        self.cache = cache
        self.nbytes = nbytes
        self.generation = 0              # completed leases of this arena
        self._free: List[int] = list(range(batch))

    @property
    def rows_free(self) -> int:
        return len(self._free)

    @property
    def rows_used(self) -> int:
        return self.batch - len(self._free)

    def alloc_rows(self, n: int) -> Optional[List[int]]:
        """Lease ``n`` rows (lowest-index first); None if not enough free."""
        if n > len(self._free):
            return None
        self._free.sort()
        rows, self._free = self._free[:n], self._free[n:]
        return rows

    def free_rows(self, rows: Sequence[int]) -> None:
        for r in rows:
            if r in self._free:
                raise ValueError(f"row {r} double-freed")
            self._free.append(r)


class KVCachePool:
    """Single owner of decode-cache construction for a serving session.

    ``max_arenas`` / ``max_bytes`` (0 = unbounded) cap the pool;
    ``acquire(..., force=True)`` overrides the cap so a scheduler with no
    in-flight work can always make progress. Fully-freed arenas are kept
    for recycling up to ``max_free`` buckets (LRU-evicted beyond that, and
    evicted early whenever their bytes stand between a new lease and the
    budget) — retired shape buckets cannot pin HBM forever.
    """

    def __init__(self, model, *, max_arenas: int = 0, max_bytes: float = 0.0,
                 max_free: int = 4):
        self.model = model
        self.max_arenas = max_arenas
        self.max_bytes = max_bytes
        self.max_free = max(1, max_free)
        self.metrics = PoolMetrics()
        self._leased: List[CacheArena] = []
        # LRU order: least-recently released first (eviction order)
        self._pooled: List[CacheArena] = []

    # -- sizing ------------------------------------------------------------
    def arena_bytes(self, batch: int, seq: int) -> float:
        """Exact bytes of one (batch, seq) arena, from the model's cache
        entry specs (no array materialization)."""
        total = 0.0
        for shape, _axes, dt in self.model.cache_entries(batch, seq).values():
            total += math.prod(shape) * np.dtype(dt).itemsize
        return total

    def live_bytes(self) -> float:
        """Bytes currently leased to request groups."""
        return sum(a.nbytes for a in self._leased)

    def total_bytes(self) -> float:
        """Leased plus pooled-free bytes (what the pool actually holds)."""
        return self.live_bytes() + sum(a.nbytes for a in self._pooled)

    @property
    def arena_count(self) -> int:
        return len(self._leased) + len(self._pooled)

    def occupancy(self) -> float:
        """Fraction of leased-arena rows holding live requests."""
        total = sum(a.batch for a in self._leased)
        used = sum(a.rows_used for a in self._leased)
        return used / total if total else 0.0

    # -- lease lifecycle ---------------------------------------------------
    def _evict_free(self, count: int = 1) -> int:
        """Drop up to ``count`` least-recently-released free arenas (their
        device buffers go with them). Returns how many were evicted."""
        n = min(count, len(self._pooled))
        if n:
            del self._pooled[:n]
            self.metrics.arenas_evicted += n
        return n

    def _budget_blocks(self, nbytes: float) -> bool:
        if self.max_arenas and self.arena_count >= self.max_arenas:
            return True
        if self.max_bytes and self.total_bytes() + nbytes > self.max_bytes:
            return True
        return False

    def can_acquire(self, batch: int, seq: int) -> bool:
        if any((a.batch, a.seq) == (batch, seq) for a in self._pooled):
            return True
        nbytes = self.arena_bytes(batch, seq)
        if not self._budget_blocks(nbytes):
            return True
        # free arenas of other buckets are evictable — only *leased* memory
        # can genuinely refuse a lease
        if self.max_arenas and len(self._leased) >= self.max_arenas:
            return False
        if self.max_bytes and self.live_bytes() + nbytes > self.max_bytes:
            return False
        return True

    def acquire(self, batch: int, seq: int, *, zero: bool = False,
                force: bool = False) -> Optional[CacheArena]:
        """Lease a (batch, seq) arena. A fully-freed arena of the same
        bucket is recycled without reallocation; otherwise a fresh one is
        built — evicting idle free arenas first if they stand between the
        lease and the budget (None when still refused and not ``force``).
        ``zero``: clear recycled state, for tenants that decode from a zero
        cache instead of overwriting their rows via a handoff write."""
        arena = next((a for a in self._pooled
                      if (a.batch, a.seq) == (batch, seq)), None)
        if arena is not None:
            self._pooled.remove(arena)
            if zero:
                arena.cache = jax.tree.map(jnp.zeros_like, arena.cache)
            self.metrics.arenas_reused += 1
        else:
            nbytes = self.arena_bytes(batch, seq)
            while self._budget_blocks(nbytes) and self._evict_free():
                pass
            if not force and self._budget_blocks(nbytes):
                self.metrics.arenas_denied += 1
                return None
            arena = CacheArena(batch, seq, self.model.init_cache(batch, seq),
                               nbytes)
            self.metrics.arenas_created += 1
        self._leased.append(arena)
        self.metrics.peak_bytes = max(self.metrics.peak_bytes,
                                      self.total_bytes())
        return arena

    def alloc_rows(self, arena: CacheArena, n: int) -> Optional[List[int]]:
        rows = arena.alloc_rows(n)
        if rows is not None:
            self.metrics.rows_leased += n
            if arena.generation:
                self.metrics.rows_reused += n
        return rows

    def free_rows(self, arena: CacheArena, rows: Sequence[int]) -> None:
        arena.free_rows(rows)

    def release(self, arena: CacheArena) -> None:
        """Return a leased arena to the free pool (rows need not be freed
        individually first — a release ends the whole lease). The free pool
        is LRU-capped at ``max_free`` arenas."""
        self._leased.remove(arena)
        arena._free = list(range(arena.batch))
        arena.generation += 1
        self._pooled.append(arena)
        if len(self._pooled) > self.max_free:
            self._evict_free(len(self._pooled) - self.max_free)

    # -- the handoff write -------------------------------------------------
    def write_rows(self, arena: CacheArena, rows: Sequence[int],
                   cache: Dict[str, Any],
                   src_rows: Optional[Sequence[int]] = None) -> None:
        """Scatter ``cache`` rows (a prefill-populated cache at the same
        bucket shape) into ``rows`` of the arena — the prefill→decode
        handoff. Every cache leaf is layer-stacked ``(L, B, ...)``, so the
        batch row is axis 1. Rows are fully overwritten, which is why
        recycled arenas need no zeroing on this path."""
        rows_a = jnp.asarray(list(rows), jnp.int32)
        src_a = jnp.asarray(list(src_rows) if src_rows is not None
                            else list(range(len(rows_a))), jnp.int32)
        if set(cache) != set(arena.cache):
            raise ValueError(
                f"cache keys {sorted(cache)} != arena keys {sorted(arena.cache)}")
        arena.cache = {
            k: v.at[:, rows_a].set(
                jnp.take(cache[k], src_a, axis=1).astype(v.dtype))
            for k, v in arena.cache.items()
        }
        self.metrics.handoff_writes += 1
