"""Plan-cache benchmark: steady-state per-request serving latency with the
plan cache on vs. off over a mixed-shape request stream.

The cache-off path is the seed behaviour (one planner walk + one fresh XLA
trace per request); the cache-on path amortizes both across the stream via
shape-bucketed LRU plan caching (``repro.core.plan_cache``). Acceptance
target: >= 5x lower steady-state per-request latency with the cache on.

    PYTHONPATH=src python benchmarks/bench_plan_cache.py [--smoke]

Prints ``name,us_per_call,derived`` CSV rows (harness contract), writes the
full result set to ``BENCH_plan_cache.json`` (the perf-trajectory artifact
CI uploads), and exits non-zero if the cached path errors, so CI smoke runs
catch rot.
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys

from repro.configs import get_config
from repro.runtime.engine_config import EngineConfig
from repro.runtime.serve_loop import ServeRequest

try:
    from benchmarks.bench_meta import scenario_meta
except ImportError:  # run as a script from the benchmarks/ directory
    from bench_meta import scenario_meta


RESULTS_JSON = "BENCH_plan_cache.json"


def _stream(smoke: bool):
    # mixed (batch, context) shapes: several buckets, revisited repeatedly
    if smoke:
        return [(1, 40), (2, 100), (1, 40), (2, 100), (1, 200), (2, 100)], 2
    return [(1, 40), (2, 100), (4, 60), (1, 200), (2, 100), (1, 40),
            (4, 60), (2, 250), (1, 200), (2, 100)], 3


def _measure(smoke: bool, arch: str):
    """Returns (rows, speedup): the CSV rows plus the numeric on/off ratio
    so the CI gate doesn't re-parse its own formatting."""
    cfg = get_config(arch)
    shapes, repeats = _stream(smoke)
    new_tokens = 2 if smoke else 4
    rows = []

    # --- cache ON: warm pass settles compiles/recompiles, then measure ---
    srv = EngineConfig(cache_capacity=16).build_server(cfg)
    for b, c in sorted(set(shapes)):  # warm each bucket (compile + trace)
        srv.handle(ServeRequest(b, c, new_tokens))
        srv.handle(ServeRequest(b, c, new_tokens))  # settle recompilation
    on_lat = [srv.handle(ServeRequest(b, c, new_tokens))["latency_s"]
              for _ in range(repeats) for b, c in shapes]
    on_us = statistics.mean(on_lat) * 1e6
    m = srv.metrics
    rows.append(
        f"plan_cache_on,{on_us:.0f},"
        f"hits={m.hits};misses={m.misses};evictions={m.evictions};"
        f"recompiles={m.recompiles};hit_rate={m.hit_rate:.2f}")

    # --- cache OFF: every request pays planner walk + fresh trace ---------
    off_repeats = 1 if smoke else 2
    srv_off = EngineConfig(enable_cache=False).build_server(cfg)
    off_lat = [srv_off.handle(ServeRequest(b, c, new_tokens))["latency_s"]
               for _ in range(off_repeats) for b, c in shapes]
    off_us = statistics.mean(off_lat) * 1e6
    rows.append(f"plan_cache_off,{off_us:.0f},compiles={srv_off.metrics.compiles}")

    speedup = off_us / on_us if on_us else 0.0
    rows.append(f"plan_cache_speedup,{on_us:.0f},x={speedup:.1f};target=5.0")
    return rows, speedup


def run(smoke: bool = False, arch: str = "yi-6b-smoke"):
    """Harness entry point (benchmarks/run.py contract): CSV rows only."""
    return _measure(smoke, arch)[0]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream for CI (seconds, not minutes)")
    ap.add_argument("--arch", default="yi-6b-smoke")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    rows, speedup = _measure(args.smoke, args.arch)
    for row in rows:
        print(row, flush=True)
    ok = speedup >= 5.0
    with open(RESULTS_JSON, "w") as f:
        json.dump({
            "bench": "plan_cache", "smoke": args.smoke, "arch": args.arch,
            "meta": scenario_meta(args.arch),
            "rows": rows, "ok": ok,
            "gates": {"cached_speedup": {"value": speedup, "target": 5.0}},
        }, f, indent=2)
        f.write("\n")
    print(f"# results -> {RESULTS_JSON}", file=sys.stderr)
    if not ok:
        print(f"FAIL: plan-cache speedup {speedup:.1f}x < 5x target",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
