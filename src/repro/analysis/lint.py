"""Invariant linter: AST rules for the conventions the runtime relies on.

Every rule encodes an invariant a past PR fixed reactively and the stack
now maintains by convention; the linter turns each into a CI gate:

- ``init-cache-outside-pool`` — decode caches are built only by
  :class:`~repro.runtime.kv_cache.KVCachePool` (``model.init_cache`` /
  ``init_paged_cache`` anywhere else bypasses arena recycling and the
  byte budget — the PR-4 leak class).
- ``admission-outside-pool`` — row/page admission goes through
  ``KVCachePool.admit_request_rows``; direct ``alloc_rows`` /
  ``admit_row`` / ``ensure_slot`` calls skip the budget and reservation
  accounting.
- ``rid-mint`` — ``ServeRequest.rid`` is stamped once at construction;
  assigning ``.rid`` or touching ``_NEXT_RID`` elsewhere breaks handle
  identity across the engine/router (the PR-5 drift class).
- ``local-import`` — imports live at module top level; function-local
  imports hide layering cycles and re-resolve on the hot path. Waive the
  deliberate cycle-breakers with ``# lint: allow-local-import``.
- ``tracer-host-sync`` — tick-path modules (``models/``, ``kernels/``,
  ``serve_loop``) must not call ``.item()`` / ``float()`` / ``int()`` /
  ``np.asarray`` on values that are tracers inside the jitted step: each
  is a silent device sync (or a trace error) in the decode tick.
- ``plan-cache-mutation`` — :class:`~repro.core.plan_cache.PlanCache`
  owns its entry dict; reaching into ``._entries`` bypasses LRU metrics
  and capacity accounting.
- ``plan-axis-in-explain`` — every ``PlanConfig`` field except ``notes``
  is a plan axis and must be read by an ``explain_axes()`` / ``explain()``
  renderer in the same module: a plan decision EXPLAIN cannot surface is
  un-debuggable (the PR-10 cost auditor checks the rendered dict at
  runtime; this rule catches the dropped axis at lint time, before any
  plan is ever compiled).
- ``use-after-donation`` — decode steps donate their cache argument
  (positional 1) to XLA; in tick-path modules a cache reference passed
  to a ``.step_fn(...)`` call must not be read again before it is
  rebound or deleted — the donated buffer is deleted on-device, so a
  later read raises (or silently resurrects a stale copy under
  disabled checks). Host-side metadata probes (``.is_deleted()``) are
  the sanctioned exception; waive them with
  ``# lint: allow-use-after-donation``.

A finding on line N is suppressed by the marker ``# lint: allow-<rule>``
on that line. Run ``python -m repro.analysis.lint``; exit status is the
number-of-findings truth (0 = clean tree).
"""

from __future__ import annotations

import argparse
import ast
import json
import sys
from pathlib import Path
from typing import Callable, List, Optional, Sequence

from repro.analysis import Finding

REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_ROOTS = ("src/repro", "examples", "benchmarks")

# files allowed to call the guarded cache/admission/rid primitives: the
# modules that *define* them
CACHE_BLESSED = ("runtime/kv_cache.py", "models/model.py")
RID_BLESSED = ("runtime/serve_loop.py",)
PLAN_CACHE_BLESSED = ("core/plan_cache.py",)
TICK_PATH = ("models/", "kernels/", "serve_loop")
# modules that drive donating decode steps: the tick path plus the engine
# (the engine is deliberately NOT on TICK_PATH — its host-side bookkeeping
# legitimately calls .item()/int() between ticks — but its tick phase does
# hand cache references to donating step_fns)
DONATION_TICK_PATH = TICK_PATH + ("runtime/engine",)

ADMISSION_CALLS = ("alloc_rows", "admit_row", "ensure_slot")
HOST_SYNC_CALLS = ("asarray", "array")


def _blessed(path: str, suffixes: Sequence[str]) -> bool:
    norm = path.replace("\\", "/")
    return any(norm.endswith(s) for s in suffixes)


def _tick_path(path: str) -> bool:
    norm = path.replace("\\", "/")
    return any(t in norm for t in TICK_PATH)


def _donation_tick_path(path: str) -> bool:
    norm = path.replace("\\", "/")
    return any(t in norm for t in DONATION_TICK_PATH)


def _waived(src_lines: Sequence[str], lineno: int, rule: str) -> bool:
    if 1 <= lineno <= len(src_lines):
        return f"# lint: allow-{rule}" in src_lines[lineno - 1]
    return False


class _Ctx:
    """One file's parse: source lines, numpy aliases, finding sink."""

    def __init__(self, path: str, src: str):
        self.path = path
        self.lines = src.splitlines()
        self.tree = ast.parse(src)
        self.findings: List[Finding] = []
        self.np_aliases = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "numpy":
                        self.np_aliases.add(a.asname or "numpy")

    def report(self, rule: str, node: ast.AST, detail: str) -> None:
        lineno = getattr(node, "lineno", 0)
        if _waived(self.lines, lineno, rule):
            return
        self.findings.append(Finding(rule=rule,
                                     where=f"{self.path}:{lineno}",
                                     detail=detail))


Rule = Callable[[_Ctx], None]
LINT_RULES: List[Rule] = []


def rule(fn: Rule) -> Rule:
    LINT_RULES.append(fn)
    return fn


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


@rule
def local_import(ctx: _Ctx) -> None:
    """Imports belong at module scope (TYPE_CHECKING blocks are module
    scope too); a function body import is a hidden cycle or hot-path
    re-resolution."""
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for inner in ast.walk(node):
            if isinstance(inner, (ast.Import, ast.ImportFrom)):
                names = ", ".join(a.name for a in inner.names)
                ctx.report("local-import", inner,
                           f"import of {names} inside {node.name}()")


@rule
def init_cache_outside_pool(ctx: _Ctx) -> None:
    if _blessed(ctx.path, CACHE_BLESSED):
        return
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("init_cache", "init_paged_cache")):
            ctx.report("init-cache-outside-pool", node,
                       f".{node.func.attr}() called outside KVCachePool; "
                       f"lease an arena (pool.acquire / "
                       f"admit_request_rows) instead")


@rule
def admission_outside_pool(ctx: _Ctx) -> None:
    if _blessed(ctx.path, CACHE_BLESSED):
        return
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ADMISSION_CALLS):
            ctx.report("admission-outside-pool", node,
                       f".{node.func.attr}() bypasses "
                       f"KVCachePool.admit_request_rows accounting")


@rule
def rid_mint(ctx: _Ctx) -> None:
    if _blessed(ctx.path, RID_BLESSED):
        return
    for node in ast.walk(ctx.tree):
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Attribute) and t.attr == "rid":
                ctx.report("rid-mint", node,
                           "assignment to .rid outside ServeRequest "
                           "construction")
        if isinstance(node, ast.Name) and node.id == "_NEXT_RID":
            ctx.report("rid-mint", node,
                       "_NEXT_RID touched outside serve_loop")


@rule
def tracer_host_sync(ctx: _Ctx) -> None:
    if not _tick_path(ctx.path):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr == "item":
            ctx.report("tracer-host-sync", node,
                       ".item() forces a device sync in the tick path")
        elif (isinstance(fn, ast.Name) and fn.id in ("float", "int")
                and len(node.args) == 1
                and not isinstance(node.args[0], ast.Constant)):
            ctx.report("tracer-host-sync", node,
                       f"{fn.id}() on a possible tracer in the tick path")
        elif (isinstance(fn, ast.Attribute)
                and fn.attr in HOST_SYNC_CALLS
                and isinstance(fn.value, ast.Name)
                and fn.value.id in ctx.np_aliases):
            ctx.report("tracer-host-sync", node,
                       f"{fn.value.id}.{fn.attr}() materializes to host "
                       f"in the tick path")


def _expr_text(node: ast.AST) -> Optional[str]:
    """Stable source text for a trackable reference (name / attribute /
    subscript chains). Returns None for expressions with no rebindable
    identity — a call result (``arena.relinquish()``) or a literal is
    consumed at the call site and cannot be read again by name."""
    if isinstance(node, (ast.Name, ast.Attribute, ast.Subscript)):
        return ast.unparse(node)
    return None


def _donating_calls(stmt: ast.stmt):
    """``.step_fn(...)`` calls inside one statement whose donated cache
    argument (positional 1) is a trackable reference."""
    for node in ast.walk(stmt):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "step_fn"
                and len(node.args) >= 2):
            text = _expr_text(node.args[1])
            if text is not None:
                yield node, text


def _rebinds(stmt: ast.stmt, text: str) -> bool:
    """Whether ``stmt`` rebinds or deletes the tracked reference — either
    the exact expression or its root name (rebinding ``cache`` kills the
    stale path even if ``cache['k']`` was what got donated)."""
    root = text.split(".")[0].split("[")[0]

    def _hit(t: ast.AST) -> bool:
        if isinstance(t, ast.Name) and t.id == root:
            return True
        if isinstance(t, (ast.Attribute, ast.Subscript)):
            return ast.unparse(t) == text
        if isinstance(t, (ast.Tuple, ast.List)):
            return any(_hit(e) for e in t.elts)
        return False

    if isinstance(stmt, ast.Assign):
        return any(_hit(t) for t in stmt.targets)
    if isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        return _hit(stmt.target)
    if isinstance(stmt, ast.Delete):
        return any(_hit(t) for t in stmt.targets)
    return False


def _reads(stmt: ast.stmt, text: str) -> Optional[ast.AST]:
    """First Load of the tracked reference inside ``stmt``, if any."""
    for node in ast.walk(stmt):
        if (isinstance(node, (ast.Name, ast.Attribute, ast.Subscript))
                and isinstance(getattr(node, "ctx", None), ast.Load)
                and ast.unparse(node) == text):
            return node
    return None


@rule
def use_after_donation(ctx: _Ctx) -> None:
    """A cache reference handed to a donating ``.step_fn(...)`` call must
    not be read again before rebinding: XLA deleted the buffer in place.
    The scan is linear — statements after the donating call in its block,
    then the statements after each enclosing block (so a donation inside
    an ``if`` branch is still tracked through the join point)."""
    if not _donation_tick_path(ctx.path):
        return

    def scan_block(stmts: List[ast.stmt],
                   following: List[ast.stmt]) -> None:
        compound = (ast.If, ast.For, ast.AsyncFor, ast.While, ast.With,
                    ast.AsyncWith, ast.Try, ast.FunctionDef,
                    ast.AsyncFunctionDef, ast.ClassDef)
        for i, stmt in enumerate(stmts):
            rest = stmts[i + 1:] + following
            # compound statements defer call detection to the recursion
            # below (their bodies re-scan with the right continuation);
            # detecting here too would double-report through ast.walk
            for call, text in ([] if isinstance(stmt, compound)
                               else _donating_calls(stmt)):
                # the call statement's own assignment target rebinding the
                # reference (cache = entry.step_fn(params, cache, ...)) is
                # the sanctioned in-place idiom
                if _rebinds(stmt, text):
                    continue
                for later in rest:
                    hit = _reads(later, text)
                    if hit is not None:
                        ctx.report(
                            "use-after-donation", hit,
                            f"{text!r} was donated to .step_fn() on line "
                            f"{call.lineno} and is read again before "
                            f"rebinding — the buffer is deleted on-device")
                        break
                    if _rebinds(later, text):
                        break
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue   # nested defs get their own walk entry
            for field in ("body", "orelse", "finalbody"):
                child = getattr(stmt, field, None)
                if (isinstance(child, list) and child
                        and isinstance(child[0], ast.stmt)):
                    scan_block(child, rest)
            for handler in getattr(stmt, "handlers", []) or []:
                scan_block(handler.body, rest)

    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan_block(node.body, [])


@rule
def plan_axis_in_explain(ctx: _Ctx) -> None:
    """Each PlanConfig axis must be rendered by explain_axes()/explain().

    Scoped to modules that define ``class PlanConfig``. Axes are the
    annotated fields minus ``notes`` (mirroring
    ``repro.core.strategies.PLAN_AXES``); a field counts as rendered when
    any ``explain_axes`` / ``explain`` function in the module reads it as
    an attribute."""
    plan_cls = next(
        (n for n in ast.walk(ctx.tree)
         if isinstance(n, ast.ClassDef) and n.name == "PlanConfig"), None)
    if plan_cls is None:
        return
    fields = [s for s in plan_cls.body
              if isinstance(s, ast.AnnAssign)
              and isinstance(s.target, ast.Name)
              and s.target.id != "notes"]
    renderers = [n for n in ast.walk(ctx.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                 and n.name in ("explain_axes", "explain")]
    if not renderers:
        ctx.report("plan-axis-in-explain", plan_cls,
                   "module defines PlanConfig but no explain_axes()/"
                   "explain() renderer; plan decisions have no EXPLAIN "
                   "surface")
        return
    rendered = {node.attr for fn in renderers
                for node in ast.walk(fn) if isinstance(node, ast.Attribute)}
    for f in fields:
        if f.target.id not in rendered:
            ctx.report("plan-axis-in-explain", f,
                       f"PlanConfig field {f.target.id!r} is a plan axis "
                       f"but is never read by explain_axes()/explain() — "
                       f"the decision cannot be surfaced by EXPLAIN")


@rule
def plan_cache_mutation(ctx: _Ctx) -> None:
    if _blessed(ctx.path, PLAN_CACHE_BLESSED):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute) and node.attr == "_entries":
            ctx.report("plan-cache-mutation", node,
                       "PlanCache._entries reached from outside; use the "
                       "cache API (get/get_or_compile/invalidate)")


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------


def lint_source(src: str, path: str = "<memory>") -> List[Finding]:
    """Run every rule over one source string (the self-test surface)."""
    ctx = _Ctx(path, src)
    for r in LINT_RULES:
        r(ctx)
    return ctx.findings


def lint_paths(roots: Sequence[str],
               repo_root: Optional[Path] = None) -> List[Finding]:
    repo_root = repo_root or REPO_ROOT
    findings: List[Finding] = []
    for root in roots:
        base = repo_root / root
        if not base.exists():
            continue
        files = [base] if base.is_file() else sorted(base.rglob("*.py"))
        for f in files:
            rel = f.relative_to(repo_root).as_posix()
            try:
                src = f.read_text()
            except (OSError, UnicodeDecodeError) as e:
                findings.append(Finding(rule="unreadable", where=rel,
                                        detail=str(e)))
                continue
            try:
                findings.extend(lint_source(src, rel))
            except SyntaxError as e:
                findings.append(Finding(rule="syntax-error", where=rel,
                                        detail=str(e)))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="project invariant linter (repro.analysis)")
    ap.add_argument("roots", nargs="*", default=list(DEFAULT_ROOTS),
                    help="paths (relative to repo root) to scan")
    ap.add_argument("--json", metavar="PATH",
                    help="also write findings as JSON")
    args = ap.parse_args(argv)
    findings = lint_paths(args.roots or DEFAULT_ROOTS)
    for f in findings:
        print(f)
    if args.json:
        Path(args.json).write_text(json.dumps(
            [{"rule": f.rule, "where": f.where, "detail": f.detail}
             for f in findings], indent=2))
    print(f"lint: {len(findings)} finding(s) over {len(LINT_RULES)} rules "
          f"in {', '.join(args.roots or DEFAULT_ROOTS)}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
