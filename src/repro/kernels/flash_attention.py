"""Flash attention Pallas kernel (GQA + causal + sliding window).

TPU adaptation of the attention hot-spot: online-softmax tiling so the
(Sq x Sk) score matrix never leaves VMEM. Blocks are MXU-aligned; the
kv-block loop is the minor (sequential) grid axis, carrying the running
max / denominator / accumulator in VMEM scratch.

Used for: dense-arch training & prefill, the sliding-window serving variant
(``long_500k`` on full-attention archs, DESIGN §5), and recurrentgemma's
local-attention blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

BQ, BK = 128, 128


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, n_kv: int, bq: int, bk: int, causal: bool, window: int, q_offset: int,
    scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    qpos = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0]
    ).astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        lsum = l_ref[...]
        safe = jnp.where(lsum == 0.0, 1.0, lsum)
        o_ref[0, ...] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,   # (B, Hq, Sq, D)
    k: jnp.ndarray,   # (B, Hkv, Sk, D)
    v: jnp.ndarray,   # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = -1,   # -1 -> Sk - Sq (standard causal alignment)
    bq: int = BQ,
    bk: int = BK,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    if q_offset < 0:
        q_offset = sk - sq
    bq = min(bq, _pow2_floor(sq))
    bk = min(bk, _pow2_floor(sk))
    sqp, skp = _pad(sq, bq), _pad(sk, bk)
    if sqp != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - sq), (0, 0)))
    if skp != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
        # padded keys masked out via positions > any qpos under causal; for
        # non-causal we mask explicitly by window over positions; to be safe
        # the wrapper only allows padding with causal=True or window>0.
        if not causal and window == 0:
            raise ValueError("Sk must be tile-aligned for full bidirectional attention")
    # fold GQA groups into the batch*head grid axis: kv head = bh // g
    qr = q.reshape(b * hq, sqp, d)
    n_kv = skp // bk

    grid = (b * hq, sqp // bq, n_kv)
    kernel = functools.partial(
        _flash_kernel, n_kv=n_kv, bq=bq, bk=bk, causal=causal,
        window=window, q_offset=q_offset, scale=1.0 / (d ** 0.5),
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, k.reshape(b * hkv, skp, d), v.reshape(b * hkv, skp, d))
    return out.reshape(b, hq, sqp, d)[:, :, :sq, :]


def _pow2_floor(x: int) -> int:
    p = 1
    while p * 2 <= x:
        p *= 2
    return p


def _pad(x: int, b: int) -> int:
    return ((x + b - 1) // b) * b
