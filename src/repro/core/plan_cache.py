"""Plan cache + dynamic recompilation for the serving path.

SystemML's compiler is not one-shot: compiled plans carry worst-case
*compile-time statistics* (sizes, sparsity), and the runtime re-optimizes —
*dynamic recompilation* — whenever observed characteristics diverge from
them. This module is the serving-side analogue for our JAX plan compiler.
Mechanism-by-mechanism mapping:

====================================  =====================================
SystemML                              here
====================================  =====================================
plan memoization per operator DAG     :class:`PlanCache`, LRU over
                                      (arch, mesh, dtype, shape-bucket) keys
compile-time statistics               ``ExecutionPlan.memory`` — the worst-
                                      case estimate from ``core.memory``
runtime statistics                    :class:`~repro.core.strategies.RuntimeStats`
                                      (observed shape + live-bytes watermark)
dynamic recompilation                 :meth:`PlanCache.refresh` →
                                      :meth:`PlanCompiler.recompile` when a
                                      request breaches the estimate margin
                                      or outgrows its compiled shape
unknown-size handling via             power-of-two shape buckets
conservative worst-case plans         (:func:`bucket_pow2`): one compiled
                                      plan serves a whole shape family
====================================  =====================================

Without this, every new (batch, context) pair entering ``launch/serve.py``
pays a full planner walk plus a fresh XLA trace; with it, steady-state
requests are pure cache hits. Counters (hits / misses / evictions /
compiles / recompiles) are surfaced through ``repro.runtime.metrics``.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

from repro.config import MeshConfig, ModelConfig, InputShape, TrainConfig
from repro.core.strategies import ExecutionPlan, RuntimeStats


# ---------------------------------------------------------------------------
# shape bucketing
# ---------------------------------------------------------------------------


def bucket_pow2(n: int, minimum: int = 1) -> int:
    """Round ``n`` up to the next power of two, at least ``minimum``."""
    n = max(int(n), minimum, 1)
    return 1 << (n - 1).bit_length()


@dataclass(frozen=True)
class BucketPolicy:
    """How incoming request shapes collapse onto cache keys. Small minimum
    buckets avoid one-plan-per-tiny-shape churn at the low end."""

    min_batch: int = 1
    min_seq: int = 16


@dataclass(frozen=True)
class PlanKey:
    """Cache key: one compiled plan per (arch, mesh, dtype, shape-bucket)."""

    arch: str
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    dtype: str
    kind: str                 # "decode" | "prefill" | "train"
    batch_bucket: int
    seq_bucket: int

    @classmethod
    def for_request(
        cls,
        model: ModelConfig,
        mesh: MeshConfig,
        dtype: str,
        shape: InputShape,
        policy: BucketPolicy = BucketPolicy(),
    ) -> "PlanKey":
        return cls(
            arch=model.name,
            mesh_shape=tuple(mesh.shape),
            mesh_axes=tuple(mesh.axis_names),
            dtype=dtype,
            kind=shape.kind,
            batch_bucket=bucket_pow2(shape.global_batch, policy.min_batch),
            seq_bucket=bucket_pow2(shape.seq_len, policy.min_seq),
        )

    def bucket_shape(self) -> InputShape:
        """The shape the bucket's plan is compiled for (covers every request
        that maps to this key)."""
        return InputShape(
            f"{self.kind}_b{self.batch_bucket}x{self.seq_bucket}",
            self.seq_bucket, self.batch_bucket, self.kind,
        )

    def rebucket(self, shape: InputShape,
                 policy: BucketPolicy = BucketPolicy()) -> "PlanKey":
        """Key for an observed shape that may have outgrown this bucket."""
        return dataclasses.replace(
            self,
            batch_bucket=bucket_pow2(shape.global_batch, policy.min_batch),
            seq_bucket=bucket_pow2(shape.seq_len, policy.min_seq),
        )


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


@dataclass
class PlanCacheMetrics:
    """Hit/miss/eviction/compile counters, surfaced via runtime.metrics."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    compiles: int = 0
    recompiles: int = 0
    compile_seconds: float = 0.0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "hits": self.hits, "misses": self.misses,
            "evictions": self.evictions, "compiles": self.compiles,
            "recompiles": self.recompiles, "hit_rate": self.hit_rate,
            "compile_seconds": self.compile_seconds,
        }


@dataclass
class CacheEntry:
    """One compiled plan + its runtime executable for a shape bucket."""

    key: PlanKey
    plan: ExecutionPlan
    step_fn: Any = None            # jitted executable for the bucket shape
    extras: Dict[str, Any] = field(default_factory=dict)
    hits: int = 0


def recompile_reasons(plan: ExecutionPlan, stats: RuntimeStats,
                      margin: float = 0.25) -> Tuple[str, ...]:
    """Why ``stats`` invalidates ``plan`` (empty tuple = still valid).

    Mirrors SystemML's recompilation predicate: observed characteristics
    exceed the compiled plan's shape, or the measured memory watermark
    exceeds the compile-time estimate by more than ``margin``.
    """
    reasons = []
    if (stats.shape.seq_len > plan.shape.seq_len
            or stats.shape.global_batch > plan.shape.global_batch):
        reasons.append(
            f"shape ({stats.shape.global_batch}x{stats.shape.seq_len}) exceeds "
            f"compiled bucket ({plan.shape.global_batch}x{plan.shape.seq_len})"
        )
    if plan.memory is not None and plan.memory.total > 0 and stats.watermark_bytes:
        limit = plan.memory.total * (1.0 + margin)
        if stats.watermark_bytes > limit:
            mib = 1024 ** 2
            reasons.append(
                f"memory watermark {stats.watermark_bytes / mib:.2f}MiB exceeds "
                f"estimate {plan.memory.total / mib:.2f}MiB by >{margin:.0%}"
            )
    # KV-cache pool breach: the pool's live bytes exceed the compile-time
    # cache statistic the plan was sized for — same predicate shape as the
    # watermark check, scoped to the cache tensor class. With paged arenas
    # both sides are block-granular: the statistic counts provisioned pages
    # (memory.cache_page_count) and the observation counts committed pages,
    # so bucket-shaped slack inside an arena can no longer trip this.
    if stats.cache_pool_bytes and plan.memory is not None:
        kv_est = plan.memory.per_device.get("kv_cache", 0.0)
        if kv_est > 0 and stats.cache_pool_bytes > kv_est * (1.0 + margin):
            mib = 1024 ** 2
            reasons.append(
                f"kv-cache pool {stats.cache_pool_bytes / mib:.2f}MiB exceeds "
                f"planned pool capacity {kv_est / mib:.2f}MiB by >{margin:.0%}"
            )
    return tuple(reasons)


class PlanCache:
    """LRU cache of compiled execution plans keyed by :class:`PlanKey`."""

    def __init__(self, capacity: int = 16,
                 metrics: Optional[PlanCacheMetrics] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.metrics = metrics if metrics is not None else PlanCacheMetrics()
        self._entries: "OrderedDict[PlanKey, CacheEntry]" = OrderedDict()

    # -- dict-ish surface --------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: PlanKey) -> bool:
        return key in self._entries

    def keys(self) -> Iterable[PlanKey]:
        """LRU order: least-recently used first."""
        return list(self._entries.keys())

    def clear(self) -> None:
        self._entries.clear()

    # -- core operations ---------------------------------------------------
    def get(self, key: PlanKey) -> Optional[CacheEntry]:
        entry = self._entries.get(key)
        if entry is None:
            self.metrics.misses += 1
            return None
        self._entries.move_to_end(key)
        entry.hits += 1
        self.metrics.hits += 1
        return entry

    def put(self, key: PlanKey, entry: CacheEntry) -> CacheEntry:
        if key in self._entries:
            self._entries.move_to_end(key)
        self._entries[key] = entry
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.metrics.evictions += 1
        return entry

    def get_or_compile(self, key: PlanKey,
                       compile_fn: Callable[[], CacheEntry]) -> CacheEntry:
        """Hit returns the cached entry; miss runs ``compile_fn`` and
        installs its result (counted as one compile)."""
        entry = self.get(key)
        if entry is None:
            entry = self.put(key, compile_fn())
            self.metrics.compiles += 1
        return entry

    # -- dynamic recompilation --------------------------------------------
    def refresh(
        self,
        key: PlanKey,
        stats: RuntimeStats,
        compiler,
        train: TrainConfig = TrainConfig(),
        margin: float = 0.25,
        build_step: Optional[Callable[[ExecutionPlan], Any]] = None,
        policy: BucketPolicy = BucketPolicy(),
    ) -> Tuple[Optional[CacheEntry], Tuple[str, ...]]:
        """Re-optimize ``key``'s plan if observed ``stats`` invalidate it.

        Returns ``(entry, reasons)``: the (possibly new) entry and the
        recompilation reasons (empty when the cached plan is still valid).
        The new plan is compiled with runtime-corrected statistics via
        :meth:`PlanCompiler.recompile`, so an identical follow-up request
        does **not** trigger a second recompilation — exactly SystemML's
        converge-after-one-recompile behaviour.
        """
        entry = self._entries.get(key)
        if entry is None:
            return None, ()
        reasons = recompile_reasons(entry.plan, stats, margin)
        if not reasons:
            return entry, ()
        new_key = key.rebucket(stats.shape, policy) if any(
            "exceeds compiled bucket" in r for r in reasons) else key
        if new_key != key:
            # grow to the *new bucket* shape so the recompiled plan covers
            # every request that will map to the new key, and drop the
            # invalidated entry — serving it again (or re-refreshing it)
            # would repeat the recompilation forever
            stats = dataclasses.replace(stats, shape=new_key.bucket_shape())
            del self._entries[key]
            existing = self._entries.get(new_key)
            if existing is not None:
                # the target bucket already holds a valid compiled (and
                # possibly traced) plan — reuse it, don't clobber it
                self._entries.move_to_end(new_key)
                return existing, reasons
        new_plan = compiler.recompile(entry.plan, stats, train)
        # same bucket + same layout decisions: only the statistics were
        # corrected, so the already-traced executable stays valid
        same_config = (new_key == key
                       and new_plan.config.replace(notes=())
                       == entry.plan.config.replace(notes=()))
        if same_config:
            step_fn = entry.step_fn
        else:
            step_fn = build_step(new_plan) if build_step else None
        new_entry = CacheEntry(key=new_key, plan=new_plan, step_fn=step_fn)
        self.put(new_key, new_entry)
        self.metrics.recompiles += 1
        return new_entry, reasons
