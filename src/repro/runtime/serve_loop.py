"""Serving runtime: prefill + batched decode under a plan.

The decode step is the paper's "low-latency scoring" end of the
"ranging from low-latency scoring to large-scale training" claim; batched
request scoring uses the parfor engine (``test_algo="allreduce"``).

:class:`PlanServer` is the dynamic-recompilation serving session: incoming
(batch, context) requests are rounded up to power-of-two shape buckets, the
plan + jitted decode step for each bucket lives in a :class:`PlanCache`,
and observed runtime statistics (live-bytes watermark, actual shape) feed
back into the compiler when they breach the plan's compile-time estimates.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from dataclasses import replace as dc_replace
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import InputShape, MeshConfig, ModelConfig, TPU_V5E, HardwareSpec
from repro.core.plan_cache import (BucketPolicy, CacheEntry, PlanCache,
                                   PlanKey)
from repro.core.planner import PlanCompiler
from repro.core.sharding import tree_specs
from repro.core.strategies import ExecutionPlan, PlanConfig, RuntimeStats
from repro.models.common import ShardCtx
from repro.models.model import build_model
from repro.runtime.engine import ServingEngine, WallClock
from repro.runtime.engine_config import (_UNSET, EngineConfig,
                                         fold_legacy_kwargs)
from repro.runtime.kv_cache import KVCachePool
from repro.runtime.metrics import LatencyStats, serve_summary


def make_decode_step(model, plan: PlanConfig, mesh_cfg: MeshConfig,
                     page: int = 0, seq_len: int = 0):
    """``page > 0`` builds the block-granular paged decode step: it takes a
    fifth argument — the (B, max_pages) page-table array — and the cache's
    attention K/V are flat per-arena slot stacks (``paged_cache_entries``).
    ``seq_len`` is the bucket context the arena is sized for (the flat
    layout no longer carries it). The physical decode-attention operator
    (paged Pallas kernel / jnp gather / ref oracle) is read off the plan:
    the compiler chose it per bucket, so the jitted step bakes it in."""
    ctx = ShardCtx(plan, mesh_cfg)
    kernel = plan.decode_kernel if plan.decode_kernel in ("paged", "ref") \
        else "gather"

    if page:
        # tables defaults to None for families with no paged entries
        # (pure-recurrent stacks): same step signature, dense semantics
        def decode_step(params, cache, tokens, pos, tables=None):
            return model.decode_step(params, cache, tokens, pos, ctx,
                                     tables=tables, page=page,
                                     seq_len=seq_len, decode_kernel=kernel)
    else:
        def decode_step(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos, ctx)

    return decode_step


def make_prefill(model, plan: PlanConfig, mesh_cfg: MeshConfig):
    ctx = ShardCtx(plan, mesh_cfg)

    def prefill(params, batch):
        extra = {k: v for k, v in batch.items()
                 if k not in ("tokens", "lengths")}
        return model.prefill(params, batch["tokens"], extra=extra, ctx=ctx,
                             lengths=batch.get("lengths"))

    return prefill


def cache_shardings(model, batch: int, seq_len: int, plan: PlanConfig,
                    mesh_cfg: MeshConfig, mesh):
    specs, axes = model.cache_specs(batch, seq_len)
    parts = tree_specs(specs, axes, plan, mesh_cfg, "cache")
    shards = jax.tree.map(lambda sp: NamedSharding(mesh, sp), parts,
                          is_leaf=lambda x: isinstance(x, P))
    return specs, parts, shards


def greedy_decode(model, params, cache, first_token, start_pos, num_tokens,
                  decode_step=None, tables=None):
    """Greedy generation loop (example/driver use). ``start_pos`` may be a
    scalar (whole batch at one depth) or a (B,) per-row position vector —
    rows handed off from prefill start at their own prompt length.
    ``tables``: page-table array for a paged decode step (the step then
    takes it as a fifth argument; rows must be page-admitted eagerly)."""
    step = decode_step or (lambda p, c, t, q: model.decode_step(p, c, t, q))
    toks = first_token
    out = []
    pos = jnp.asarray(start_pos, jnp.int32)
    for _ in range(num_tokens):
        if tables is not None:
            logits, cache = step(params, cache, toks, pos, tables)
        else:
            logits, cache = step(params, cache, toks, pos)
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(toks)
        pos = pos + 1
    if not out:
        return jnp.zeros((first_token.shape[0], 0), jnp.int32), cache
    return jnp.concatenate(out, axis=1), cache


# ===========================================================================
# PlanServer: shape-bucketed serving with plan cache + dynamic recompilation
# ===========================================================================


_NEXT_RID = itertools.count()


@dataclass(frozen=True)
class ServeRequest:
    """One decode request: ``batch`` sequences with ``context`` cache slots,
    generating up to ``new_tokens`` tokens greedily.

    ``rid`` is stamped at construction (process-wide monotone counter), so
    engine handles, scheduler results, and metrics all key on the same id —
    it is no longer minted at queue admission. Stop conditions end a
    request before ``new_tokens``: ``eos_id`` stops a row at its first
    end-of-sequence token, ``stop`` is a tuple of token-id sequences any of
    which terminates a row when its output ends with one (a request
    finishes when every row has stopped)."""

    batch: int
    context: int
    new_tokens: int = 8
    eos_id: Optional[int] = None
    stop: Tuple[Tuple[int, ...], ...] = ()
    rid: int = field(default_factory=lambda: next(_NEXT_RID))


def _tree_bytes(tree) -> float:
    return float(sum(x.nbytes for x in jax.tree.leaves(tree)  # lint: allow-tracer-host-sync (host-side sizing)
                     if hasattr(x, "nbytes")))


class PlanServer:
    """Serving session that amortizes plan compilation across requests.

    Request flow (mirrors SystemML's recompilation loop):

    1. the request shape rounds up to its power-of-two bucket
       (:class:`BucketPolicy`) and forms a :class:`PlanKey`;
    2. cache hit → reuse the bucket's compiled plan and jitted decode step;
       miss → one planner walk + one ``jax.jit`` trace, installed in the
       LRU cache;
    3. after execution, observed :class:`RuntimeStats` (live-bytes
       watermark, actual shape) are checked against the plan's compile-time
       estimates; a breach beyond ``recompile_margin`` re-enters the
       compiler with runtime-corrected statistics and installs the new
       plan — at most once per divergence, since the corrected estimate
       covers the observation.

    With ``enable_cache=False`` every request pays the full compile+trace
    path (the pre-cache behaviour, kept for A/B benchmarking).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        mesh_cfg: Optional[MeshConfig] = None,
        dtype=_UNSET,
        *,
        hw: HardwareSpec = TPU_V5E,
        config: Optional[EngineConfig] = None,
        enable_cache: bool = _UNSET,
        capacity: int = _UNSET,
        recompile_margin: float = _UNSET,
        policy: BucketPolicy = BucketPolicy(),
        seed: int = _UNSET,
        prefill: bool = _UNSET,
        pool_arenas: int = _UNSET,
        pool_max_arenas: int = _UNSET,
        pool_max_bytes: float = _UNSET,
        page_size: int = _UNSET,
    ):
        # one config surface (EngineConfig); the per-knob kwargs are the
        # deprecated shims, overlaid on top so existing call sites keep
        # their exact behaviour for one release
        self.config = fold_legacy_kwargs(
            config, "PlanServer",
            dtype=(np.dtype(dtype).name if dtype is not _UNSET else _UNSET),
            enable_cache=enable_cache, cache_capacity=capacity,
            recompile_margin=recompile_margin, seed=seed, prefill=prefill,
            pool_arenas=pool_arenas, pool_max_arenas=pool_max_arenas,
            pool_max_bytes=pool_max_bytes, page_size=page_size)
        c = self.config
        self.cfg = cfg
        self.mesh_cfg = mesh_cfg or MeshConfig(
            shape=(len(jax.devices()),), axis_names=("data",))
        self.dtype = c.jnp_dtype()
        self.dtype_name = c.dtype
        self.model = build_model(cfg, dtype=self.dtype)
        self.params = self.model.init_params(jax.random.PRNGKey(c.seed))
        self._params_bytes = _tree_bytes(self.params)
        # block-granular paged arenas (0 = row-granular PR-3 behaviour):
        # rows commit pages, not bucket-shaped sequence slack
        self.page_size = max(0, int(c.page_size))  # lint: allow-tracer-host-sync (config int)
        # compile-time cache statistics are sized for a pool provisioned
        # with ``pool_arenas`` concurrent bucket arenas; the pool's live
        # bytes are checked against them at observe() time
        self.pool_arenas = max(1, c.pool_arenas)
        self.compiler = PlanCompiler(hw, cache_pool_arenas=self.pool_arenas,
                                     cache_page_size=self.page_size,
                                     decode_kernel=c.decode_kernel,
                                     donate_cache=c.donate)
        self.pool = KVCachePool(self.model, max_arenas=c.pool_max_arenas,
                                max_bytes=c.pool_max_bytes,
                                page_size=self.page_size)
        self.cache = PlanCache(capacity=c.cache_capacity)
        self.metrics = self.cache.metrics
        self.latency = LatencyStats()
        self.enable_cache = c.enable_cache
        self.recompile_margin = c.recompile_margin
        self.policy = policy
        # prefill=True: handle() runs the cached-prefill prompt pass, hands
        # the populated cache rows to decode (no zero-cache restart), and
        # the prefill-produced first token opens the output; False keeps the
        # PR-1 decode-only request shape. The scheduler always prefills.
        self.prefill = c.prefill
        self._engine: Optional[ServingEngine] = None

    # ------------------------------------------------------------------
    def _build_step(self, plan: ExecutionPlan):
        if plan.shape.kind == "prefill":
            # nothing safe to donate: the prompt pass has no cache input
            # and params are shared by every plan
            return jax.jit(make_prefill(self.model, plan.config, self.mesh_cfg))
        step = make_decode_step(self.model, plan.config, self.mesh_cfg,
                                page=self.page_size,
                                seq_len=plan.shape.seq_len)
        if plan.config.donate_cache:
            # donate the cache pytree (positional arg 1): XLA aliases each
            # cache output onto its input buffer, so the slot stacks and
            # recurrent state update in place instead of double-buffering.
            # The engine relinquishes the arena's pytree for the step and
            # re-adopts the output (CacheArena.relinquish/adopt).
            return jax.jit(step, donate_argnums=(1,))
        return jax.jit(step)

    def _compile_entry(self, key: PlanKey) -> CacheEntry:
        t0 = time.perf_counter()
        plan = self.compiler.compile(self.cfg, key.bucket_shape(),
                                     self.mesh_cfg, dtype=self.dtype_name)
        entry = CacheEntry(key=key, plan=plan, step_fn=self._build_step(plan))
        self.metrics.compile_seconds += time.perf_counter() - t0
        return entry

    def _key_for(self, batch: int, context: int, kind: str) -> PlanKey:
        shape = InputShape(f"req_{batch}x{context}", context, batch, kind)
        return PlanKey.for_request(self.cfg, self.mesh_cfg, self.dtype_name,
                                   shape, self.policy)

    def _entry_for(self, key: PlanKey) -> CacheEntry:
        if self.enable_cache:
            return self.cache.get_or_compile(
                key, lambda: self._compile_entry(key))
        # pre-cache behaviour: full planner walk + fresh XLA trace
        self.metrics.misses += 1
        self.metrics.compiles += 1
        return self._compile_entry(key)

    def decode_entry(self, batch: int, context: int) -> CacheEntry:
        """Bucketed decode plan + jitted decode step (cache-backed)."""
        return self._entry_for(self._key_for(batch, context, "decode"))

    def prefill_entry(self, batch: int, context: int) -> CacheEntry:
        """Bucketed prefill plan + jitted prefill fn from the same cache.

        The prefill path shares the :class:`PlanCache` with decode —
        ``PlanKey.kind`` keeps the key spaces disjoint, so one server holds
        both plan families and the scheduler draws each from the cache."""
        return self._entry_for(self._key_for(batch, context, "prefill"))

    def run_prefill(self, entry: CacheEntry, tokens=None, lengths=None):
        """Execute a cached prefill plan at its bucket shape; returns
        ``(logits, cache)``: per-row last-prompt-position logits
        ``(batch_bucket, vocab)`` plus the populated decode cache (None for
        families without handoff). ``lengths`` is the per-row prompt length
        inside the padded bucket (default: the full bucket width)."""
        b, s = entry.key.batch_bucket, entry.key.seq_bucket
        if tokens is None:
            tokens = jnp.ones((b, s), jnp.int32)
        if lengths is None:
            lengths = jnp.full((b,), s, jnp.int32)
        logits, kv = entry.step_fn(
            self.params, {"tokens": tokens, "lengths": lengths})
        jax.block_until_ready(logits)
        return logits, kv

    def prefill_first_token(self, batch: int, context: int,
                            lengths=None) -> Tuple[Any, Any]:
        """Prompt pass through the cached prefill plan; returns the greedy
        first decode token per bucket row ``(batch_bucket, 1)`` *and* the
        populated decode cache for the handoff. Prefill and decode share
        the bucket policy, so the rows and cache slots line up with the
        decode bucket of the same request shape."""
        entry = self.prefill_entry(batch, context)
        logits, kv = self.run_prefill(entry, lengths=lengths)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None], kv

    # ------------------------------------------------------------------
    def observed_stats(self, entry: CacheEntry, shape: InputShape,
                       toks, double_buffer_bytes: float = 0.0
                       ) -> RuntimeStats:
        """Measured runtime statistics for one executed request: the live-
        bytes watermark per chip (params + the *whole* KV-cache pool +
        in-flight tokens) and the pool's own per-chip bytes. Each tensor
        class only divides across the chips the plan actually shards it
        over; replicated layouts hold a full copy per chip.

        ``double_buffer_bytes``: extra cache-class bytes observed live
        during the tick — the engine passes the group's arena footprint
        when the step did *not* consume its donated cache input (the
        un-donated step holds input + output copies simultaneously), so
        the watermark reflects what the device actually held."""
        cfgp = entry.plan.config
        mesh = self.mesh_cfg
        param_div = 1
        if cfgp.tensor_parallel or cfgp.expert_parallel:
            param_div *= mesh.model_parallelism
        if cfgp.params_over_data:
            param_div *= mesh.data_parallelism
        kv_div = 1
        for ax, sz in zip(mesh.axis_names, mesh.shape):
            if ax in cfgp.cache_batch_axes or ax in cfgp.cache_seq_axes:
                kv_div *= sz
        if cfgp.cache_heads_over_model:
            kv_div *= mesh.model_parallelism
        pool_bytes = self.pool.live_bytes()
        watermark = (self._params_bytes / param_div
                     + (pool_bytes + double_buffer_bytes + toks.nbytes)
                     / kv_div)
        return RuntimeStats(shape=shape, watermark_bytes=watermark,
                            cache_pool_bytes=pool_bytes / kv_div)

    def observe(self, key: PlanKey, stats: RuntimeStats
                ) -> Tuple[Optional[CacheEntry], Tuple[str, ...]]:
        """Feed observed runtime statistics back into the cache (dynamic
        recompilation). Compile time is billed only when ``refresh``
        actually re-entered the compiler — a rebucket that reuses an
        existing entry at the grown bucket compiles nothing and costs
        nothing."""
        if not self.enable_cache:
            return None, ()
        t_r = time.perf_counter()
        recompiles_before = self.metrics.recompiles
        refreshed, reasons = self.cache.refresh(
            key, stats, self.compiler, margin=self.recompile_margin,
            build_step=self._build_step, policy=self.policy)
        if self.metrics.recompiles > recompiles_before:
            self.metrics.compile_seconds += time.perf_counter() - t_r
        return refreshed, reasons

    def request_span(self, req: ServeRequest) -> int:
        """Context slots a request needs end-to-end: prompt plus every
        generated token. Bucketing on the span (not the bare context) is
        what keeps a context sitting exactly on a power-of-two boundary
        from overflowing its cache rows mid-decode."""
        return req.context + req.new_tokens

    # ------------------------------------------------------------------
    def handle(self, req: ServeRequest) -> Dict[str, Any]:
        """Serve one request synchronously; returns tokens + accounting.

        This is a thin submit-and-drain adapter over
        :class:`~repro.runtime.engine.ServingEngine` — the one request-
        lifecycle implementation — configured for the sequential shape:
        wall-clock time, no mid-decode joins, whole-span page commitment at
        admission. With ``prefill=True`` the prompt pass populates the
        request's cache rows (prefill→decode handoff): decode step 0
        consumes the prefill-produced token *at the prompt's position*,
        that token opens the output, and no token is recomputed against an
        empty cache. Stop conditions (``eos_id`` / ``stop``) and the
        engine's cancellation path apply here too.
        """
        if self._engine is None:
            # count_first: with a handoff the prefill token is output token
            # #1; enc-dec / modality frontends (and the decode-only PR-1
            # shape) emit exactly new_tokens decode outputs instead
            # sync_per_tick=False: nobody streams this request, so the
            # decode steps dispatch asynchronously (the pre-engine greedy
            # loop's behaviour) and one block at the end settles the work
            self._engine = ServingEngine(
                self,
                config=dc_replace(self.config, join_mid_decode=False),
                clock=WallClock(), prefill=self.prefill,
                count_first=self.prefill and self.model.supports_handoff,
                eager_pages=True, sync_per_tick=False)
        eng = self._engine
        t0 = time.perf_counter()
        handle = eng.submit(req)
        while handle.result is None and not eng.idle:
            eng.step()
        rec = handle.result
        jax.block_until_ready(rec["tokens"])
        # latency includes any in-request recompilation — that cost is the
        # mechanism under measurement, not overhead to hide
        latency = time.perf_counter() - t0
        self.latency.record(latency)
        out = {
            "tokens": rec["tokens"],
            "latency_s": latency,
            "bucket": rec["bucket"],
            "plan": rec["plan"],
            "recompiled": rec["recompiled"],
            "recompile_reasons": rec["recompile_reasons"],
            "watermark_bytes": rec["watermark_bytes"],
            "pool_bytes": rec["pool_bytes"],
            "finish_reason": rec["finish_reason"],
            "rid": req.rid,
        }
        eng.discard(handle)   # one-shot: don't accumulate engine records
        return out

    # ------------------------------------------------------------------
    def summary(self) -> str:
        return serve_summary(self.metrics, self.latency)
