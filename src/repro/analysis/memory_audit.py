"""Memory-lifetime auditor: certify in-place KV-cache donation per plan.

SystemML's planner trusts compile-time memory statistics; this pass checks
the statistics' central assumption — that the decode tick updates its KV
cache *in place* — against what XLA will actually execute. For every
decode cell of the smoke matrix (arch x dtype x bucket x both forced
physical operators) it builds the exact jitted step ``PlanServer`` would
install (same ``make_decode_step``, same ``donate_argnums``), lowers it
(StableHLO — no device execution), and reads the per-argument
input-output aliasing metadata (``tf.aliasing_output``) the donation
produced:

- every *large step input* is classified into a buffer class (``params``,
  ``attention-slot-stack``, ``recurrent-state``, ``page-table``,
  ``tokens`` / ``positions``) and marked **aliased-in-place** (XLA writes
  its output onto the input buffer) or **double-buffered** (a fresh
  output allocation coexists with the input);
- a **certified peak-live-bytes** figure is computed from those
  lifetimes: all inputs plus all outputs must coexist, minus the aliased
  pairs that share one buffer — the executable cannot do worse at the
  argument boundary, whatever it does in between;
- any plan whose KV cache (slot stacks *or* recurrent state) is not
  donated — or whose donation the lowering did not turn into aliasing —
  is flagged ``cache-not-donated``.

The report merges into ``ANALYSIS_report.json`` under a ``memory``
section (next to the plan auditor's cells), so one artifact carries both
the statistics sandwich and the aliasing certificate.

Run ``python -m repro.analysis.memory_audit --smoke``: audits the matrix,
runs the planted-violation self-test (a compiler forced to
``donate_cache=False`` must be flagged; the clean tree must not), and
exits non-zero on any finding or self-test miss.

Adding a buffer class: see ``analysis/README.md`` — classification is by
tree path in :func:`classify_leaves`, so a new step input only needs a
``(predicate, class name)`` entry there and a line in the README table.
"""

from __future__ import annotations

import argparse
import re
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import Finding
from repro.analysis.matrix import (PAGE_SIZE, POOL_ARENAS, REPORT_PATH,
                                   SMOKE_ARCHS, SMOKE_BUCKETS, SMOKE_DTYPES,
                                   matrix_meta, smoke_cells)
from repro.analysis.matrix import merge_report as _merge_report
from repro.config import InputShape, MeshConfig
from repro.configs import get_config
from repro.core.planner import PlanCompiler
from repro.models.model import build_model
from repro.runtime.serve_loop import make_decode_step

# classes whose buffers MUST alias in place on a donated plan: the cache
# pytree is the donated argument, and it splits into the paged attention
# slot stacks and the per-row recurrent/conv/cross state
DONATED_CLASSES = ("attention-slot-stack", "recurrent-state")


# ---------------------------------------------------------------------------
# lowering introspection
# ---------------------------------------------------------------------------


def lowered_aliases(lowered_text: str) -> Dict[int, int]:
    """Map flat input index -> aliased output index, parsed from the
    ``tf.aliasing_output`` attributes donation leaves on the lowered
    module's ``@main`` signature. Only the entry computation carries
    them, so the parse is scoped to the ``@main(...)`` argument list."""
    m = re.search(r"@main\((.*?)\)\s*->", lowered_text, re.S)
    sig = m.group(1) if m else lowered_text
    out: Dict[int, int] = {}
    for idx, attrs in re.findall(
            r"%arg(\d+): tensor<[^>]*>(?:\s*\{([^}]*)\})?", sig):
        if attrs:
            am = re.search(r"tf\.aliasing_output\s*=\s*(\d+)", attrs)
            if am:
                out[int(idx)] = int(am.group(1))
    return out


def classify_leaves(model, params, cache, n_extra: int,
                    has_tables: bool) -> List[Tuple[str, str]]:
    """(buffer class, leaf label) per flat argument, in the jit's flat
    order: params leaves, cache leaves (split by
    ``model.is_paged_cache_key``), then tokens / positions / page table.

    This is the one place a new step input gets its buffer class — add a
    branch here and the audit record, the donation check, and the peak
    computation all pick it up."""
    out: List[Tuple[str, str]] = []
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, _ in leaves_with_paths:
        out.append(("params", jax.tree_util.keystr(path)))
    # dict pytrees flatten in sorted-key order; mirror it exactly
    for key in sorted(cache):
        cls = ("attention-slot-stack" if model.is_paged_cache_key(key)
               else "recurrent-state")
        out.append((cls, key))
    out.append(("tokens", "tokens"))
    out.append(("positions", "pos"))
    if has_tables:
        out.append(("page-table", "tables"))
    assert n_extra == len(out), f"leaf map drift: {n_extra} != {len(out)}"
    return out


def _leaf_bytes(leaf) -> int:
    return int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------


def audit_cell(arch: str, dtype: str, batch: int, seq: int, *,
               page: int = PAGE_SIZE, pool_arenas: int = POOL_ARENAS,
               decode_kernel: str = "auto", donate: bool = True
               ) -> Tuple[Dict[str, Any], List[Finding]]:
    """Lower one decode cell exactly as the server would jit it and
    classify every argument's lifetime from the aliasing metadata."""
    where = f"{arch}/{dtype}/decode/b{batch}s{seq}"
    if decode_kernel != "auto":
        where += f"/{decode_kernel}"
    cfg = get_config(arch)
    mesh_cfg = MeshConfig(shape=(1,), axis_names=("data",))
    model = build_model(cfg, dtype=dtype)
    compiler = PlanCompiler(cache_page_size=page,
                            cache_pool_arenas=pool_arenas,
                            decode_kernel=decode_kernel,
                            donate_cache=donate)
    shape = InputShape(f"req_{batch}x{seq}", seq, batch, "decode")
    plan = compiler.compile(cfg, shape, mesh_cfg, dtype=dtype)

    params = model.param_specs()
    ent, n_pages, sc = model.paged_cache_entries(batch, seq, page)
    cache = {k: jax.ShapeDtypeStruct(s, d) for k, (s, _a, d) in ent.items()}
    step = make_decode_step(model, plan.config, mesh_cfg, page=page,
                            seq_len=seq)
    args: List[Any] = [params, cache,
                       jax.ShapeDtypeStruct((batch, 1), jnp.int32),
                       jax.ShapeDtypeStruct((batch,), jnp.int32)]
    if n_pages:
        args.append(jax.ShapeDtypeStruct((batch, -(-sc // page)), jnp.int32))

    # the server's exact jit, plus keep_unused so flat argument indices in
    # the lowered module stay 1:1 with the pytree leaves (jit drops unused
    # args by default, which would scramble the index -> leaf map; dropped
    # args are never donated, so aliasing classification is unaffected)
    donate_argnums = (1,) if plan.config.donate_cache else ()
    jitted = jax.jit(step, donate_argnums=donate_argnums, keep_unused=True)
    aliases = lowered_aliases(jitted.lower(*args).as_text())

    flat, _ = jax.tree_util.tree_flatten(tuple(args))
    labels = classify_leaves(model, params, cache, len(flat),
                             has_tables=bool(n_pages))
    out_tree = jax.eval_shape(step, *args)
    out_bytes = sum(_leaf_bytes(x) for x in jax.tree_util.tree_leaves(out_tree))

    classes: Dict[str, Dict[str, Any]] = {}
    findings: List[Finding] = []
    in_bytes = 0
    aliased_bytes = 0
    for i, leaf in enumerate(flat):
        cls, label = labels[i]
        nb = _leaf_bytes(leaf)
        in_bytes += nb
        aliased = i in aliases
        if aliased:
            aliased_bytes += nb
        rec = classes.setdefault(cls, {"bytes": 0, "leaves": 0,
                                       "aliased_leaves": 0,
                                       "lifetime": "double-buffered"})
        rec["bytes"] += nb
        rec["leaves"] += 1
        rec["aliased_leaves"] += int(aliased)
        if cls in DONATED_CLASSES and plan.config.donate_cache and not aliased:
            findings.append(Finding(
                rule="cache-not-donated", where=where,
                detail=f"plan records donate_cache=True but cache leaf "
                       f"{label!r} ({cls}) is not aliased in the lowered "
                       f"executable — the tick double-buffers it"))
    for cls, rec in classes.items():
        rec["lifetime"] = ("aliased-in-place"
                          if rec["leaves"] == rec["aliased_leaves"]
                          else "double-buffered")
    if not plan.config.donate_cache:
        findings.append(Finding(
            rule="cache-not-donated", where=where,
            detail=f"plan compiled without cache donation: every tick "
                   f"holds a second "
                   f"{sum(r['bytes'] for c, r in classes.items() if c in DONATED_CLASSES)}B "
                   f"copy of the arena"))

    # certified peak at the argument boundary: inputs + outputs coexist,
    # minus the aliased pairs that provably share one buffer
    peak = in_bytes + out_bytes - aliased_bytes
    record = {
        "arch": arch, "dtype": dtype, "batch": batch, "seq": seq,
        "decode_kernel": plan.config.decode_kernel,
        "forced_kernel": decode_kernel,
        "donate_cache": plan.config.donate_cache,
        "classes": classes,
        "input_bytes": int(in_bytes),
        "output_bytes": int(out_bytes),
        "aliased_bytes": int(aliased_bytes),
        "certified_peak_bytes": int(peak),
        "findings": len(findings),
    }
    return record, findings


# ---------------------------------------------------------------------------
# matrix + self-test
# ---------------------------------------------------------------------------


def run_audit(archs: Sequence[str] = SMOKE_ARCHS,
              dtypes: Sequence[str] = SMOKE_DTYPES,
              buckets: Sequence[Tuple[int, int]] = SMOKE_BUCKETS,
              page: int = PAGE_SIZE, pool_arenas: int = POOL_ARENAS,
              donate: bool = True,
              log=None) -> Tuple[List[Dict[str, Any]], List[Finding]]:
    cells: List[Dict[str, Any]] = []
    findings: List[Finding] = []
    for cell in smoke_cells(archs=archs, dtypes=dtypes, buckets=buckets,
                            kinds=("decode",)):
        rec, found = audit_cell(
            cell.arch, cell.dtype, cell.batch, cell.seq, page=page,
            pool_arenas=pool_arenas, decode_kernel=cell.forced_kernel,
            donate=donate)
        cells.append(rec)
        findings.extend(found)
        if log:
            slot = rec["classes"].get("attention-slot-stack")
            state = rec["classes"].get("recurrent-state")
            log(f"  {cell.where}: "
                f"slot-stack="
                f"{slot['lifetime'] if slot else 'n/a'} "
                f"state={state['lifetime'] if state else 'n/a'} "
                f"peak={rec['certified_peak_bytes']}B "
                f"{rec['findings']} finding(s)")
    return cells, findings


def selftest(arch: str = "yi-6b-smoke") -> Dict[str, Any]:
    """The auditor must flag a plan compiled without donation (the planted
    un-donated fixture) and pass the donated control for both the
    attention and the pure-recurrent family."""
    _, clean = audit_cell(arch, "bfloat16", 2, 64, decode_kernel="paged")
    _, planted = audit_cell(arch, "bfloat16", 2, 64, decode_kernel="paged",
                            donate=False)
    _, rec_clean = audit_cell("mamba2-1.3b-smoke", "bfloat16", 2, 64,
                              decode_kernel="gather")
    return {
        "clean_control": not clean,
        "undonated_cache_flagged": any(f.rule == "cache-not-donated"
                                       for f in planted),
        "recurrent_state_aliased": not rec_clean,
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def merge_report(path: str, memory: Dict[str, Any]) -> None:
    """Land the audit under the ``memory`` section of the (shared)
    analysis report, preserving every section the other passes wrote.
    Delegates to :func:`repro.analysis.matrix.merge_report`, which also
    survives a corrupt or non-dict report on disk — the historical
    failure mode was this function quietly discarding the plan auditor's
    sections when the on-disk JSON was not the dict it expected."""
    _merge_report(path, {"memory": memory})


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="certify in-place KV-cache donation from the lowered "
                    "executable's input-output aliasing")
    ap.add_argument("--smoke", action="store_true",
                    help="audit the CI smoke matrix (archs x dtypes x "
                         "buckets x both forced decode kernels) plus the "
                         "planted un-donated self-test")
    ap.add_argument("--archs", nargs="*", default=None,
                    help="override the arch list")
    ap.add_argument("--no-donate", action="store_true",
                    help="audit the un-donated A/B configuration (every "
                         "cell is expected to flag)")
    ap.add_argument("--report", default=REPORT_PATH,
                    help=f"JSON report path (default {REPORT_PATH})")
    ap.add_argument("--no-selftest", action="store_true",
                    help="skip the planted-violation self-test")
    args = ap.parse_args(argv)

    archs = tuple(args.archs) if args.archs else SMOKE_ARCHS
    print(f"memory_audit: {len(archs)} arch(s) x {len(SMOKE_DTYPES)} dtypes "
          f"x {len(SMOKE_BUCKETS)} buckets x 2 kernels")
    cells, findings = run_audit(archs=archs, donate=not args.no_donate,
                                log=print)

    st: Dict[str, Any] = {}
    if not args.no_selftest:
        st = selftest()
        for probe, ok in st.items():
            print(f"  selftest {probe}: {'ok' if ok else 'MISSED'}")

    memory = {
        "matrix": matrix_meta(archs=archs, kernels=["paged", "gather"]),
        "cells": cells,
        "findings": [{"rule": f.rule, "where": f.where, "detail": f.detail}
                     for f in findings],
        "selftest": st,
    }
    merge_report(args.report, memory)

    for f in findings:
        print(f)
    missed = [k for k, ok in st.items() if not ok]
    print(f"memory_audit: {len(cells)} cells, {len(findings)} finding(s), "
          f"report -> {args.report} [memory]")
    if missed:
        print(f"memory_audit: self-test MISSED: {', '.join(missed)}")
    return 1 if findings or missed else 0


if __name__ == "__main__":
    sys.exit(main())
