"""Paper claim (§3): the row-partitioned remote-parfor scoring plan "avoids
shuffling and scales linearly with the number of cluster nodes". Verified
structurally (this container has 2 cores — wall-time scaling is not
meaningful): per-worker row count halves as workers double, and the lowered
plan contains zero collectives (subprocess with placeholder devices)."""

from __future__ import annotations

import subprocess
import sys
import os

_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import sys; sys.path.insert(0, {src!r})
import time
import jax, jax.numpy as jnp
from repro.core.parfor import parfor, count_collectives
mesh = jax.make_mesh(({n},), ("data",))
w = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
x = jax.random.normal(jax.random.PRNGKey(1), (512, 64))
fn = lambda rows: parfor(lambda r: jax.nn.softmax(r @ w, -1), rows, mesh=mesh)[0]
jitted = jax.jit(fn)
compiled = jitted.lower(x).compile()
colls = count_collectives(compiled.as_text())
out = jitted(x); jax.block_until_ready(out)
t0 = time.perf_counter()
for _ in range(20): out = jitted(x)
jax.block_until_ready(out)
us = (time.perf_counter() - t0) / 20 * 1e6
print(f"RESULT,{{us:.1f}},{{colls}},{{512 // {n}}}")
"""


def run():
    rows = []
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    for n in (1, 2, 4, 8):
        body = _BODY.format(n=n, src=src)
        r = subprocess.run([sys.executable, "-c", body],
                           capture_output=True, text=True, timeout=300)
        line = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT")]
        if not line:
            rows.append(f"parfor_scaling_w{n},0,ERROR={r.stderr[-200:]}")
            continue
        _, us, colls, rows_per = line[0].split(",")
        rows.append(
            f"parfor_scaling_w{n},{us},collectives={colls};rows_per_worker={rows_per}")
    return rows
