"""repro.nn — the SystemML NN library analogue (manual backward; DESIGN C2)."""

from repro.nn import layers, loss, optim
from repro.nn.module import Sequential
from repro.nn.optim import OPTIMIZERS, get_optimizer, tree_init, tree_update

__all__ = ["layers", "loss", "optim", "Sequential", "OPTIMIZERS",
           "get_optimizer", "tree_init", "tree_update"]
