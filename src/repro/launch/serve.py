"""Serving launcher: batched greedy decoding with a planner-chosen cache
layout.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b-smoke \
        --batch 4 --context 128 --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import InputShape, MeshConfig
from repro.configs import ARCH_IDS, get_config
from repro.core.planner import compile_plan
from repro.models.model import build_model
from repro.runtime.serve_loop import greedy_decode, make_decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16
    model = build_model(cfg, dtype=dtype)

    n_dev = len(jax.devices())
    mesh_cfg = MeshConfig(shape=(n_dev,), axis_names=("data",))
    shape = InputShape("cli", args.context, args.batch, "decode")
    plan = compile_plan(cfg, shape, mesh_cfg)
    print(plan.explain())

    params = model.init_params(jax.random.PRNGKey(0))
    cache = model.init_cache(args.batch, args.context)
    step = jax.jit(make_decode_step(model, plan.config, mesh_cfg))

    first = jnp.ones((args.batch, 1), jnp.int32)
    # warmup
    _ = step(params, cache, first, jnp.int32(0))
    t0 = time.perf_counter()
    toks, cache = greedy_decode(model, params, cache, first, 0, args.tokens,
                                decode_step=step)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x {args.batch} seqs "
          f"in {dt:.2f}s = {args.tokens * args.batch / dt:.1f} tok/s")
    print("sample:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
