"""Scenario metadata for benchmark artifacts.

Every ``BENCH_*.json`` the harness writes is a point on a perf
trajectory; a point is only comparable to its neighbors if it says what
scenario produced it. :func:`scenario_meta` stamps the knobs that change
the numbers — model arch, replica count, arrival rate — plus the code
revision (``git describe``) and interpreter, so two artifacts can be
diffed without guessing which commit or fleet shape they came from.

:func:`artifact_revision_status` answers the follow-up confusion: the
committed copy of a ``BENCH_*.json`` is a snapshot from whatever revision
last regenerated it, and readers kept treating it as a statement about
HEAD. The checker compares the artifact's stamped revision hash against
the current one (``-dirty`` suffixes ignored: artifacts are regenerated
from the working tree that becomes the next commit) and returns
``current`` / ``stale`` / ``unknown``; benches print the verdict for the
previous on-disk copy before overwriting it, and ``python
benchmarks/bench_meta.py BENCH_*.json`` audits a checkout's artifacts in
bulk (CI runs exactly that in the analysis job and fails on ``stale``).

A stamp of HEAD's *parent* also counts as ``current``: regenerating from
the dirty working tree stamps ``<rev>-dirty`` where ``<rev>`` is the
commit the tree was based on, and that tree then *becomes* the next
commit — so at the new HEAD, the honest stamp for a fresh artifact is
the parent hash. Anything older is a genuinely stale snapshot.
"""

from __future__ import annotations

import json
import os
import platform
import subprocess
import sys
from typing import Any, Dict

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def git_describe() -> str:
    """Current revision (`git describe --always --dirty`), or "unknown"
    outside a git checkout — benches must not fail over provenance."""
    try:
        out = subprocess.run(
            ["git", "describe", "--always", "--dirty", "--tags"],
            capture_output=True, text=True, timeout=10, cwd=_REPO_ROOT)
    except (OSError, subprocess.SubprocessError):
        return "unknown"
    rev = out.stdout.strip()
    return rev if out.returncode == 0 and rev else "unknown"


def _base_rev(described: str) -> str:
    """The bare revision hash from a ``git describe --always --dirty``
    string: tags and the -dirty suffix don't identify the snapshot."""
    rev = described.split("-dirty")[0]
    # describe with a tag looks like v1.2-3-gabc1234; take the g-hash
    if "-g" in rev:
        rev = rev.rsplit("-g", 1)[1]
    return rev


def _parent_rev() -> str:
    """Short hash of HEAD's parent, or "" when there is none / no git."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD^"],
            capture_output=True, text=True, timeout=10, cwd=_REPO_ROOT)
    except (OSError, subprocess.SubprocessError):
        return ""
    rev = out.stdout.strip()
    return rev if out.returncode == 0 else ""


def artifact_revision_status(path: str, head: str = "",
                             parent: str = "") -> Dict[str, Any]:
    """Whether the on-disk copy of a ``BENCH_*.json`` was generated at the
    current revision. Returns ``{"path", "artifact_git", "head_git",
    "status"}`` with status ``current`` (stamped hash matches HEAD or its
    parent, -dirty ignored — a ``<parent>-dirty`` stamp is the working
    tree that *became* HEAD), ``stale`` (older than that: the numbers
    describe a superseded tree), or ``unknown`` (no artifact, no stamp,
    or no git)."""
    head = head or git_describe()
    parent = parent or _parent_rev()
    try:
        with open(path) as f:
            stamped = json.load(f).get("meta", {}).get("git", "unknown")
    except (OSError, json.JSONDecodeError):
        stamped = "unknown"
    if "unknown" in (stamped, head):
        status = "unknown"
    else:
        base = _base_rev(stamped)
        current = base == _base_rev(head) or (parent and base == parent)
        status = "current" if current else "stale"
    return {"path": path, "artifact_git": stamped, "head_git": head,
            "status": status}


def scenario_meta(arch: str, *, replicas: int = 1,
                  arrival_rate: float = 0.0, **extra: Any) -> Dict[str, Any]:
    """The dict every bench embeds under ``"meta"`` in its JSON artifact."""
    meta: Dict[str, Any] = {
        "arch": arch,
        "replicas": replicas,
        "arrival_rate_per_s": arrival_rate,
        "git": git_describe(),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }
    meta.update(extra)
    return meta


def main(argv=None) -> int:
    """Audit artifacts: ``python benchmarks/bench_meta.py BENCH_*.json``
    prints one status line per file; exits 1 if any is stale."""
    paths = list(argv if argv is not None else sys.argv[1:])
    if not paths:
        print("usage: bench_meta.py BENCH_*.json [...]", file=sys.stderr)
        return 2
    head = git_describe()
    parent = _parent_rev()
    stale = 0
    for p in paths:
        st = artifact_revision_status(p, head=head, parent=parent)
        print(f"{st['status']:8s} {p} (artifact {st['artifact_git']}, "
              f"head {st['head_git']})")
        stale += st["status"] == "stale"
    return 1 if stale else 0


if __name__ == "__main__":
    sys.exit(main())
