"""Continuous-batching scheduler: bucket-aware coalescing, queue fairness,
prefill-path plan caching, and the two PR-2 bugfixes (dtype-aware memory
estimates -> zero spurious fp32 recompiles; compile_seconds billed only when
a recompile actually ran)."""

import jax.numpy as jnp
import pytest

from repro.config import SINGLE_DEVICE_MESH, InputShape, TrainConfig, TPU_V5E
from repro.configs import get_config
from repro.core.memory import dtype_bytes, estimate_memory
from repro.core.plan_cache import BucketPolicy
from repro.core.planner import compile_plan
from repro.core.strategies import RuntimeStats
from repro.runtime.scheduler import (ContinuousBatchingScheduler,
                                     RequestQueue, simulate_arrivals)
from repro.runtime.serve_loop import PlanServer, ServeRequest

CFG = get_config("yi-6b-smoke")


# ---------------------------------------------------------------------------
# RequestQueue: coalescing + fairness
# ---------------------------------------------------------------------------


def test_coalescing_picks_covering_bucket():
    # buckets cover context + new_tokens (default 8): 100+8 -> 128 etc.
    q = RequestQueue(BucketPolicy(min_batch=1, min_seq=16), max_group_batch=8)
    q.admit(ServeRequest(1, 100))   # span 108, bucket 128
    q.admit(ServeRequest(2, 90))    # span  98, bucket 128 — joins
    q.admit(ServeRequest(1, 40))    # span  48, bucket 64  — different bucket
    q.admit(ServeRequest(2, 120))   # span 128, bucket 128 — joins
    group = q.next_group()
    assert [m.req.context for m in group] == [100, 90, 120]
    assert sum(m.req.batch for m in group) == 5
    # the other bucket's request is untouched, next in line
    assert [m.req.context for m in q.pending] == [40]


def test_coalescing_respects_batch_capacity():
    q = RequestQueue(max_group_batch=4)
    q.admit(ServeRequest(2, 100))
    q.admit(ServeRequest(3, 100))   # would overflow 4 — skipped this round
    q.admit(ServeRequest(2, 100))   # fills the remaining 2 slots
    group = q.next_group()
    assert [m.req.batch for m in group] == [2, 2]
    # the skipped request becomes head-of-line and is never starved
    group2 = q.next_group()
    assert [m.req.batch for m in group2] == [3]
    assert len(q) == 0


def test_queue_fairness_head_of_line_picks_bucket():
    """The oldest pending request defines the group bucket, even when a
    different bucket has more pending work (no starvation by popularity)."""
    q = RequestQueue(max_group_batch=8)
    q.admit(ServeRequest(1, 40))     # bucket 64, oldest
    for _ in range(5):
        q.admit(ServeRequest(1, 100))  # bucket 128, popular
    group = q.next_group()
    assert all(q.seq_bucket(m.req) == 64 for m in group)
    assert group[0].req.context == 40


def test_oversized_head_is_served_alone():
    q = RequestQueue(max_group_batch=4)
    q.admit(ServeRequest(6, 100))   # exceeds capacity on its own
    group = q.next_group()
    assert len(group) == 1 and group[0].req.batch == 6


# ---------------------------------------------------------------------------
# scheduler end-to-end (tiny model, CPU)
# ---------------------------------------------------------------------------


def test_scheduler_coalesces_and_completes_all():
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    sched = ContinuousBatchingScheduler(srv, max_group_batch=8)
    reqs = [ServeRequest(1, 100, 2), ServeRequest(2, 90, 2),
            ServeRequest(1, 120, 3), ServeRequest(1, 40, 2)]
    results = sched.run(simulate_arrivals(reqs))
    assert len(results) == 4
    assert sched.metrics.admitted == 4 and sched.metrics.completed == 4
    # results key on the request's own construction-stamped rid
    by_rid = {r["rid"]: r for r in results}
    assert set(by_rid) == {r.rid for r in reqs}
    # closed burst: the three 128-bucket requests share one group
    assert by_rid[reqs[0].rid]["group_size"] == 3
    assert by_rid[reqs[0].rid]["bucket"] == (4, 128)
    assert by_rid[reqs[3].rid]["group_size"] == 1
    # per-request tokens come back at the request's own batch size
    assert by_rid[reqs[1].rid]["tokens"].shape == (2, 2)
    assert by_rid[reqs[2].rid]["tokens"].shape == (1, 3)
    assert sched.metrics.groups == 2
    assert sched.metrics.coalesced_requests == 3
    assert sched.metrics.queue_latency.count == 4
    assert sched.summary()  # renders


def test_scheduler_prefill_plans_come_from_cache():
    """Both plan families live in the server's one PlanCache: a second group
    in the same bucket hits both the prefill and the decode entry."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    sched = ContinuousBatchingScheduler(srv, max_group_batch=2)
    # two groups in the same (2, 128) bucket: capacity forces the split
    reqs = [ServeRequest(2, 100, 1), ServeRequest(2, 100, 1)]
    sched.run(simulate_arrivals(reqs))
    kinds = {(k.kind, k.batch_bucket, k.seq_bucket) for k in srv.cache.keys()}
    assert ("prefill", 2, 128) in kinds and ("decode", 2, 128) in kinds
    assert srv.metrics.compiles == 2          # one prefill + one decode
    assert srv.metrics.hits >= 2              # second group hit both
    assert sched.metrics.groups == 2


def test_scheduler_interleaves_prefill_between_decode_steps():
    """A request arriving while a long decode is in flight starts (and can
    finish) before the first group drains — continuous, not sequential."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    sched = ContinuousBatchingScheduler(srv, max_group_batch=4)
    short = ServeRequest(1, 40, 1)                 # different bucket, short
    arrivals = [(0.0, ServeRequest(1, 100, 12)),   # long decode
                (0.0, short)]
    results = sched.run(arrivals)
    order = [r["rid"] for r in results]
    assert order[0] == short.rid              # short request finished first
    assert sched.metrics.groups == 2


def test_scheduler_slo_accounting():
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    sched = ContinuousBatchingScheduler(srv, max_group_batch=8,
                                        slo_ms=1e7)  # impossible to miss
    sched.run(simulate_arrivals([ServeRequest(1, 40, 1)]))
    assert sched.metrics.slo_met == 1 and sched.metrics.slo_missed == 0
    assert sched.metrics.slo_attainment == 1.0


def test_plan_server_prefill_mode_seeds_first_token():
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16, prefill=True)
    out = srv.handle(ServeRequest(2, 100, 2))
    assert out["tokens"].shape == (2, 2)
    kinds = {k.kind for k in srv.cache.keys()}
    assert kinds == {"prefill", "decode"}


# ---------------------------------------------------------------------------
# bugfix: dtype-aware memory estimates
# ---------------------------------------------------------------------------


def test_dtype_bytes_mapping():
    assert dtype_bytes("float32") == 4
    assert dtype_bytes("bfloat16") == 2
    assert dtype_bytes("no-such-dtype") == 4   # worst case, never under


def test_estimate_memory_follows_dtype():
    shape = InputShape("t", 128, 2, "decode")
    plan = compile_plan(CFG, shape, SINGLE_DEVICE_MESH).config
    bf16 = estimate_memory(CFG, shape, SINGLE_DEVICE_MESH, plan,
                           TrainConfig(), TPU_V5E, dtype="bfloat16")
    fp32 = estimate_memory(CFG, shape, SINGLE_DEVICE_MESH, plan,
                           TrainConfig(), TPU_V5E, dtype="float32")
    assert fp32.per_device["params"] == pytest.approx(
        2 * bf16.per_device["params"])
    assert fp32.per_device["kv_cache"] == pytest.approx(
        2 * bf16.per_device["kv_cache"])


def test_execution_plan_records_dtype():
    p32 = compile_plan(CFG, InputShape("t", 128, 2, "decode"),
                       SINGLE_DEVICE_MESH, dtype="float32")
    p16 = compile_plan(CFG, InputShape("t", 128, 2, "decode"),
                       SINGLE_DEVICE_MESH)
    assert p32.dtype == "float32" and p16.dtype == "bfloat16"
    assert p32.memory.per_device["params"] > p16.memory.per_device["params"]
    assert "float32" in p32.explain()


def test_fp32_stream_serves_with_zero_recompiles():
    """The headline bugfix: an fp32 server's first estimate per bucket is
    already fp32-sized, so no bucket burns a corrective recompile."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    for b, c in [(1, 40), (2, 100), (1, 90), (2, 100), (1, 40), (4, 60)]:
        out = srv.handle(ServeRequest(b, c, 1))
        assert not out["recompiled"], out["recompile_reasons"]
    assert srv.metrics.recompiles == 0


# ---------------------------------------------------------------------------
# bugfix: compile_seconds billed only for actual recompiles
# ---------------------------------------------------------------------------


def test_rebucket_reuse_leaves_compile_seconds_unchanged():
    """A refresh that rebuckets into an existing entry compiles nothing, so
    it must not be billed to compile_seconds (the old code billed whenever
    ``reasons`` was non-empty)."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    srv.handle(ServeRequest(2, 100, 1))   # installs (2, 128)
    srv.handle(ServeRequest(2, 300, 1))   # installs (2, 512)
    small = srv._key_for(2, 100, "decode")
    before = srv.metrics.compile_seconds
    recompiles_before = srv.metrics.recompiles
    # observed shape outgrew the small bucket; the grown bucket already
    # holds a compiled entry -> reuse, no planner walk, no billing
    refreshed, reasons = srv.observe(
        small, RuntimeStats(shape=InputShape("grown", 300, 2, "decode")))
    assert reasons and "exceeds compiled bucket" in reasons[0]
    assert refreshed is not None
    assert srv.metrics.recompiles == recompiles_before
    assert srv.metrics.compile_seconds == before


def test_real_recompile_still_billed():
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    srv.handle(ServeRequest(2, 100, 1))
    key = srv._key_for(2, 100, "decode")
    entry = srv.cache.get(key)
    before = srv.metrics.compile_seconds
    stats = RuntimeStats(shape=key.bucket_shape(),
                         watermark_bytes=3.0 * entry.plan.memory.total)
    _, reasons = srv.observe(key, stats)
    assert reasons and srv.metrics.recompiles == 1
    assert srv.metrics.compile_seconds > before
