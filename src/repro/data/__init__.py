from repro.data.pipeline import (SyntheticClassification, SyntheticLM,
                                 TokenDatasetSpec, make_batch)

__all__ = ["SyntheticLM", "SyntheticClassification", "TokenDatasetSpec",
           "make_batch"]
