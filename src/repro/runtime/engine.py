"""ServingEngine: the single request-lifecycle API for the serving path.

The serving stack had grown three divergent front doors — ``PlanServer.handle``
(one-shot, synchronous), ``ContinuousBatchingScheduler.run`` (offline: a
whole pre-sorted arrival trace in, results out at the end), and three
disjoint ``launch/serve.py`` modes — each with its own copy of the paged-row
admission sequence (exactly the drift that produced the PR-4 recycled-arena
``zero=`` leak). This module is the SystemML argument applied to serving:
*one* entry point whose internals pick the execution strategy, so new
scenarios land as configurations instead of forks.

The engine is re-entrant and tick-driven:

- :meth:`ServingEngine.submit` admits a request into a **live** engine at
  any time (no pre-sorted trace) and returns a :class:`RequestHandle`;
- :meth:`ServingEngine.step` advances every active group by one decode
  tick, decomposed into the ``joins -> form -> tick`` phases the old
  scheduler loop fused;
- :meth:`ServingEngine.stream` / :meth:`ServingEngine.events` yield
  :class:`TokenEvent`\\ s *as tokens are produced* (previously tokens only
  materialized when a request completed);
- :meth:`ServingEngine.cancel` and per-request stop conditions
  (``ServeRequest.eos_id`` / ``ServeRequest.stop`` token sequences)
  terminate a row early — its cache rows, committed pages, and undrawn
  span reservation are released the same tick, so early exits immediately
  become mid-decode join capacity and byte-budget headroom.

Time is injectable (:class:`Clock` protocol): :class:`VirtualClock` skips
idle gaps for simulated benches, :class:`WallClock` serves online traffic,
:class:`ReplicaClock` accrues only the compute executed between its
``resume``/``pause`` calls (per-replica device time for co-simulated
router fleets) — the same engine runs all three.
``ContinuousBatchingScheduler.run`` and ``PlanServer.handle`` are thin
adapters over this class, and the :class:`EngineClient` protocol names the
surface they (and ``repro.runtime.router.EngineRouter``) share, so callers
are written once against it.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import (TYPE_CHECKING, Any, Deque, Dict, Iterable, Iterator,
                    List, Optional, Protocol, Sequence, Tuple,
                    runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.sanitize import assert_engine
from repro.config import InputShape
from repro.core.plan_cache import BucketPolicy, CacheEntry, bucket_pow2
from repro.runtime.engine_config import (_UNSET, EngineConfig,
                                         fold_legacy_kwargs)
from repro.runtime.kv_cache import CacheArena
from repro.runtime.metrics import SchedulerMetrics, scheduler_summary

if TYPE_CHECKING:  # engine sits below serve_loop in the import DAG
    from repro.runtime.serve_loop import PlanServer, ServeRequest


# ===========================================================================
# clocks
# ===========================================================================


class Clock(Protocol):
    """Injectable time source for the tick loop. ``now`` is seconds since
    the clock's epoch; ``advance_to`` is called when the engine is idle and
    knows when the next arrival is due."""

    def now(self) -> float: ...

    def advance_to(self, t: float) -> None: ...


class VirtualClock:
    """Virtual clock: real elapsed time plus skipped idle gaps. Never runs
    slower than the wall — execution is measured, idle time is skipped —
    so simulated arrival traces replay at full speed."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self._skew = 0.0

    def now(self) -> float:
        return time.perf_counter() - self._t0 + self._skew

    def advance_to(self, t: float) -> None:
        self._skew += max(0.0, t - self.now())


class WallClock:
    """Real time for online traffic: idle gaps are waited out, not skipped
    (``advance_to`` sleeps until the target instant)."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def advance_to(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


class ReplicaClock:
    """Per-replica virtual device time for co-simulated fleets.

    Real ``perf_counter`` deltas accrue only between :meth:`resume` and
    :meth:`pause` — the window the router holds open around *this*
    replica's ``engine.step()`` — and idle gaps skip forward like
    :class:`VirtualClock`. N replicas interleaved serially on one host
    therefore each observe only their own compute: replica A's clock does
    not tick while replica B decodes, exactly as N distinct devices would
    behave. This is what lets a single-host bench measure the fleet's
    *device-time* throughput instead of the co-simulation's wall time."""

    def __init__(self):
        self._t = 0.0
        self._anchor: Optional[float] = None   # perf_counter at resume

    @property
    def running(self) -> bool:
        return self._anchor is not None

    def now(self) -> float:
        if self._anchor is not None:
            return self._t + (time.perf_counter() - self._anchor)
        return self._t

    def advance_to(self, t: float) -> None:
        if t > self.now():
            self._t = t
            if self._anchor is not None:
                self._anchor = time.perf_counter()

    def resume(self) -> None:
        if self._anchor is None:
            self._anchor = time.perf_counter()

    def pause(self) -> None:
        if self._anchor is not None:
            self._t += time.perf_counter() - self._anchor
            self._anchor = None


# ===========================================================================
# queue
# ===========================================================================


@dataclass
class QueuedRequest:
    """One admitted request plus its lifecycle timestamps (engine clock).
    ``rid`` is the request's own construction-stamped id — handles,
    scheduler results, and metrics all key on the same value."""

    rid: int
    req: "ServeRequest"
    arrival_s: float
    start_s: float = -1.0        # prefill began (group start or mid-decode join)
    finish_s: float = -1.0       # last requested token decoded

    @property
    def queue_s(self) -> float:
        return max(0.0, self.start_s - self.arrival_s)

    @property
    def exec_s(self) -> float:
        return max(0.0, self.finish_s - self.start_s)

    @property
    def total_s(self) -> float:
        return max(0.0, self.finish_s - self.arrival_s)


class RequestQueue:
    """FIFO admission with bucket-aware coalescing.

    Buckets are over ``context + new_tokens`` — the whole cache span a
    request occupies — so a context landing exactly on a power-of-two
    boundary still gets rows for every token it will generate.

    ``next_group`` is head-of-line fair by default (``select="hol"``): the
    *oldest* pending request picks the bucket, and only same-bucket
    requests may join its group (in arrival order, until the group's batch
    capacity is full). A popular bucket can therefore never starve an
    unpopular one — it just rides along whenever its own head reaches the
    front.

    ``select="arrival"`` is arrival-aware: the pending bucket with the
    most coalescable rows (ties broken toward the older bucket) forms
    first, trading a bounded amount of head-of-line fairness for fuller
    groups under bursty mixed-shape arrivals. The trade is bounded by
    ``max_defer``: after the head-of-line request's bucket has been passed
    over that many consecutive times, it forms next regardless — the
    starvation-freedom guarantee survives the reordering.
    """

    def __init__(self, policy: BucketPolicy = BucketPolicy(),
                 max_group_batch: int = 8, select: str = "hol",
                 max_defer: int = 4):
        if max_group_batch < 1:
            raise ValueError("max_group_batch must be >= 1")
        if select not in ("hol", "arrival"):
            raise ValueError(f"select must be hol|arrival, got {select!r}")
        self.policy = policy
        self.max_group_batch = max_group_batch
        self.select = select
        self.max_defer = max(1, max_defer)
        self._deferrals = 0          # consecutive head-bucket pass-overs
        self._pending: List[QueuedRequest] = []

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def pending(self) -> Tuple[QueuedRequest, ...]:
        return tuple(self._pending)

    def seq_bucket(self, req: "ServeRequest") -> int:
        return bucket_pow2(req.context + req.new_tokens, self.policy.min_seq)

    def admit(self, req: "ServeRequest", arrival_s: float = 0.0
              ) -> QueuedRequest:
        qr = QueuedRequest(rid=req.rid, req=req, arrival_s=arrival_s)
        self._pending.append(qr)
        return qr

    def remove(self, rid: int) -> Optional[QueuedRequest]:
        """Pull a still-pending request out of the queue (cancellation
        before admission); None if ``rid`` is not pending."""
        for qr in self._pending:
            if qr.rid == rid:
                self._pending.remove(qr)
                return qr
        return None

    def _select_bucket(self) -> int:
        """Pick the bucket the next group serves. "hol": the oldest
        pending request's bucket, unconditionally. "arrival": the bucket
        with the most immediately-coalescable rows (capped at the group
        capacity — rows that can't fit this group don't make it more
        attractive), ties broken toward the bucket with the oldest
        arrival; the head-of-line bucket is forced through after
        ``max_defer`` consecutive deferrals."""
        head_sb = self.seq_bucket(self._pending[0].req)
        if self.select != "arrival" or len(self._pending) == 1:
            self._deferrals = 0
            return head_sb
        if self._deferrals >= self.max_defer:
            self._deferrals = 0
            return head_sb
        rows: Dict[int, int] = {}
        oldest: Dict[int, int] = {}          # bucket -> first pending index
        for i, qr in enumerate(self._pending):
            sb = self.seq_bucket(qr.req)
            rows[sb] = min(self.max_group_batch,
                           rows.get(sb, 0) + qr.req.batch)
            oldest.setdefault(sb, i)
        best = max(rows, key=lambda sb: (rows[sb], -oldest[sb]))
        if best != head_sb:
            self._deferrals += 1
        else:
            self._deferrals = 0
        return best

    def next_group(self) -> List[QueuedRequest]:
        """Pop the next coalesced group (empty list if nothing pending).

        The selected bucket's oldest request always joins (even if its
        batch alone exceeds ``max_group_batch`` — it must be served
        eventually); later same-bucket requests fill the remaining batch
        slots in FIFO order, skipping any too big for the space left.
        """
        if not self._pending:
            return []
        sb = self._select_bucket()
        lead = next(qr for qr in self._pending
                    if self.seq_bucket(qr.req) == sb)
        group: List[QueuedRequest] = [lead]
        used = lead.req.batch
        for qr in self._pending:
            if qr is lead or self.seq_bucket(qr.req) != sb:
                continue
            if used + qr.req.batch > self.max_group_batch:
                continue
            group.append(qr)
            used += qr.req.batch
        for qr in group:
            self._pending.remove(qr)
        return group

    def requeue_front(self, members: Sequence[QueuedRequest]) -> None:
        """Return a popped group to the queue (pool refused the arena
        lease), merging by *arrival order* — not wholesale at the front.
        A refused group is its head plus same-bucket riders popped from
        deep in the queue; reinserting the riders ahead of older
        other-bucket requests would let them jump the line and silently
        break ``next_group``'s head-of-line fairness (``_pending[0]`` must
        stay the globally oldest pending request)."""
        self._pending = sorted(self._pending + list(members),
                               key=lambda qr: (qr.arrival_s, qr.rid))

    def take_joinable(self, seq_bucket: int, max_rows: int,
                      fits=None) -> List[QueuedRequest]:
        """Pop pending same-bucket requests that fit in ``max_rows`` free
        arena rows, strictly FIFO *within the bucket*: scanning stops at
        the first same-bucket request that does not fit, so later narrow
        arrivals can never leapfrog a wide head of their own bucket forever
        (the no-starvation guarantee extends to mid-decode joins).

        ``fits(qr)``: extra admission predicate (free cache pages, byte
        budget); it may track cumulative commitments across accepted
        candidates — it is called once per candidate, in scan order, and a
        False return stops the scan like an unfitting batch does."""
        taken: List[QueuedRequest] = []
        room = max_rows
        for qr in list(self._pending):
            if room <= 0:
                break
            if self.seq_bucket(qr.req) != seq_bucket:
                continue
            if qr.req.batch > room:
                break
            if fits is not None and not fits(qr):
                break
            taken.append(qr)
            room -= qr.req.batch
            self._pending.remove(qr)
        return taken


# ===========================================================================
# events + handles
# ===========================================================================


@dataclass(frozen=True)
class TokenEvent:
    """One per-token (or terminal) notification from the engine.

    ``token`` is the request's ``(batch, 1)`` int32 token for output
    position ``index`` — or None on the terminal event, which instead
    carries ``done=True`` and the ``finish_reason`` ("length", "eos",
    "stop", or "cancelled"). ``step`` is the owning group's decode step at
    emission (0 = produced by prefill); ``t`` is the engine-clock time."""

    rid: int
    index: int
    token: Optional[Any]
    t: float
    step: int
    done: bool = False
    finish_reason: Optional[str] = None


class RequestHandle:
    """A submitted request's lifecycle handle: inspect its state, stream
    its tokens, or cancel it. ``result`` is the completion record (the same
    dict that lands in ``engine.results``) once the request finished."""

    def __init__(self, engine: "ServingEngine", qr: QueuedRequest):
        self._engine = engine
        self.qr = qr
        # queued | active | done | cancelled | withdrawn (router failover)
        self.state = "queued"
        self.result: Optional[Dict[str, Any]] = None
        self._events: Deque[TokenEvent] = deque()

    @property
    def rid(self) -> int:
        return self.qr.rid

    @property
    def req(self) -> "ServeRequest":
        return self.qr.req

    @property
    def done(self) -> bool:
        return self.result is not None

    def tokens(self):
        """Generated tokens so far — the full output once ``done``."""
        if self.result is not None:
            return self.result["tokens"]
        member = self._engine._member_of(self.rid)
        if member is None or not member.toks:
            return jnp.zeros((self.req.batch, 0), jnp.int32)
        return jnp.concatenate(member.toks, axis=1)

    def stream(self) -> Iterator[TokenEvent]:
        return self._engine.stream(self)

    def cancel(self) -> bool:
        return self._engine.cancel(self)

    def __repr__(self) -> str:
        return f"RequestHandle(rid={self.rid}, state={self.state!r})"


# ===========================================================================
# group bookkeeping
# ===========================================================================


@dataclass
class _Member:
    """One request's tenancy inside a group: its arena rows, when it
    joined (in decode steps), and its emitted-token state."""

    qr: QueuedRequest
    rows: List[int]
    rows_a: Any                  # jnp int32 row-index array (cached)
    join_step: int
    base_pos: int = 0            # decode start position (prompt len / 0)
    done: bool = False
    finish_reason: Optional[str] = None
    toks: List[Any] = field(default_factory=list)   # emitted (batch, 1) arrays
    emitted: int = 0
    last_t: float = 0.0          # engine-clock time of the last token event
    rows_live: Optional[np.ndarray] = None          # eos/stop per-row mask
    tails: Optional[List[List[int]]] = None         # stop-sequence tails

    @property
    def req(self) -> "ServeRequest":
        return self.qr.req


@dataclass
class _Group:
    """One decode batch in flight over a leased cache-pool arena. Rows sit
    at per-row positions, so members at different generation depths (and
    mid-decode joiners) share the one jitted decode step."""

    entry: CacheEntry                 # decode plan for the group's bucket
    arena: CacheArena
    context: int                      # max member span (stats naming)
    members: List[_Member]
    toks: Any                         # (batch_bucket, 1) next decode inputs
    pos: Any                          # (batch_bucket,) int32 per-row positions
    steps_done: int = 0
    peak_rows: int = 0                # max *concurrent* leased rows observed
    # whether the last decode step consumed its relinquished cache input
    # (buffer donation aliased input onto output); True until observed
    # otherwise so a zero-step group charges no phantom double-buffer
    cache_donated: bool = True
    # peak extra cache-class bytes observed live during un-donated ticks
    # (input + output arena copies coexisting); sampled at tick time —
    # by group retire the members' pages are already freed
    double_buffer_bytes: float = 0.0

    @property
    def done(self) -> bool:
        return all(m.done for m in self.members)

    @property
    def seq_bucket(self) -> int:
        return self.entry.key.seq_bucket

    @property
    def total_batch(self) -> int:
        return sum(m.req.batch for m in self.members)


# ===========================================================================
# the client protocol
# ===========================================================================


@runtime_checkable
class EngineClient(Protocol):
    """The serving surface callers program against — satisfied by both
    :class:`ServingEngine` (one device) and
    :class:`repro.runtime.router.EngineRouter` (N replicas), so benches,
    tests, and ``launch/serve.py`` are written once and ``replicas=1`` is
    the bare engine. ``handles`` maps live request ids to their handles
    (event-driven cancellation routes through it)."""

    handles: Dict[int, Any]

    def submit(self, req: "ServeRequest",
               arrival_s: Optional[float] = None): ...

    def step(self) -> List[TokenEvent]: ...

    def events(self) -> Iterator[TokenEvent]: ...

    def stream(self, handle) -> Iterator[TokenEvent]: ...

    def cancel(self, handle) -> bool: ...

    def drain(self) -> List[Dict[str, Any]]: ...

    def run(self, arrivals: Iterable[Tuple[float, "ServeRequest"]],
            on_event=None) -> List[Dict[str, Any]]: ...

    def summary(self) -> str: ...

    @property
    def idle(self) -> bool: ...

    @property
    def metrics(self) -> SchedulerMetrics: ...


# ===========================================================================
# the engine
# ===========================================================================


class ServingEngine:
    """Re-entrant, tick-driven request-lifecycle engine over a
    :class:`~repro.runtime.serve_loop.PlanServer`.

    Both plan families come from the server's single
    :class:`~repro.core.plan_cache.PlanCache`: ``kind="prefill"`` entries
    for the batched prompt pass, ``kind="decode"`` entries for the
    shared-arena generation steps. Per tick (:meth:`step`): absorb pending
    same-bucket requests into free rows of in-flight groups (mid-decode
    joins), form at most one new group (pool budget permitting), then
    advance every active group by one decode step — emitting a
    :class:`TokenEvent` per live request.

    Mode flags (the adapters differ only in these):

    - ``prefill``: run the cached-prefill prompt pass at admission and seed
      decode with its first token (False: seed with token 1, the PR-1
      decode-only request shape);
    - ``count_first``: the prefill-produced token is output token #1
      (False: it only seeds decode — enc-dec / modality frontends, and the
      decode-only shape, emit exactly ``new_tokens`` decode outputs);
    - ``eager_pages``: commit each row's whole span at admission instead of
      growing page-by-page (the sequential ``handle`` adapter's shape);
    - ``sync_per_tick``: ``jax.block_until_ready`` after every decode step
      so per-token timestamps (TTFT / inter-token latency) measure compute,
      not dispatch. False lets XLA pipeline the whole decode asynchronously
      — the sequential ``handle`` adapter's choice, which measures one
      end-to-end latency and does not stream.
    """

    def __init__(
        self,
        server: "PlanServer",
        *,
        config: Optional[EngineConfig] = None,
        max_group_batch: int = _UNSET,
        slo_ms: float = _UNSET,
        queue: Optional[RequestQueue] = None,
        join_mid_decode: bool = _UNSET,
        clock: Optional[Clock] = None,
        prefill: bool = True,
        count_first: bool = True,
        eager_pages: bool = False,
        sync_per_tick: bool = True,
    ):
        # one config surface: explicit config wins, else inherit the
        # server's (so an engine over a config-built server needs no
        # re-plumbing); legacy kwargs overlay as deprecated shims.
        # prefill/count_first/eager_pages/sync_per_tick stay plain kwargs:
        # they are adapter-mode flags (handle() vs scheduler), not
        # scenario configuration.
        base = config if config is not None else getattr(server, "config",
                                                         None)
        self.config = fold_legacy_kwargs(
            base, "ServingEngine", max_group_batch=max_group_batch,
            slo_ms=slo_ms, join_mid_decode=join_mid_decode)
        self.server = server
        self.clock: Clock = clock or VirtualClock()
        self.queue = queue or RequestQueue(
            server.policy, self.config.max_group_batch,
            select=self.config.bucket_select)
        self.metrics = SchedulerMetrics(slo_s=self.config.slo_ms / 1e3)
        self.join_mid_decode = self.config.join_mid_decode
        self.prefill = prefill
        self.count_first = count_first
        self.eager_pages = eager_pages
        self.sync_per_tick = sync_per_tick
        self.active: List[_Group] = []
        self.results: List[Dict[str, Any]] = []
        # live requests only: entries are pruned at group retire (and on
        # queue-cancel), so a long-running engine holds handles for what is
        # in flight, not for everything it ever served — user-held handles
        # keep working off their own buffers and .result
        self.handles: Dict[int, RequestHandle] = {}
        # bounded: an events() consumer drains this every tick, so the cap
        # only bites when *nobody* consumes — then old events expire
        # instead of accumulating one device array per token forever
        self._events: Deque[TokenEvent] = deque(maxlen=8192)
        self._tick_sink: Optional[List[TokenEvent]] = None
        # requests already counted in pages_denied — the join predicate runs
        # every tick, and a retried candidate must not re-count as a denial
        self._page_denied_rids: set = set()

    # -- lifecycle API -----------------------------------------------------
    @property
    def idle(self) -> bool:
        """Nothing pending and nothing in flight."""
        return not len(self.queue) and not self.active

    def submit(self, req: "ServeRequest",
               arrival_s: Optional[float] = None) -> RequestHandle:
        """Admit a request into the live engine (any time, any order) and
        return its lifecycle handle. ``arrival_s`` defaults to the engine
        clock's now — pass explicit times when replaying a trace.

        A request can be in flight at most once per engine: ids are
        construction-stamped, and events/cancellation route by id, so
        resubmitting a live request would cross-wire delivery."""
        if req.rid in self.handles:
            raise ValueError(
                f"request rid={req.rid} is already in flight in this "
                f"engine; construct a new ServeRequest to resubmit")
        now = self.clock.now() if arrival_s is None else arrival_s
        qr = self.queue.admit(req, now)
        handle = RequestHandle(self, qr)
        self.handles[qr.rid] = handle
        self.metrics.admitted += 1
        return handle

    def step(self) -> List[TokenEvent]:
        """Advance the engine by one tick: mid-decode joins, at most one
        new group, then one decode step for every active group. Returns the
        events emitted during this tick."""
        self._tick_sink = []
        try:
            if self.join_mid_decode:
                for group in self.active:
                    self._phase_joins(group)
            self._phase_form()
            self.metrics.observe_resident(
                sum(1 for g in self.active for m in g.members if not m.done))
            for group in list(self.active):
                if not group.done:
                    self._phase_tick(group)
                if group.done:
                    self._retire_group(group)
                    self.active.remove(group)
            self._sanitize()
            return self._tick_sink
        finally:
            self._tick_sink = None

    def _sanitize(self) -> None:
        """Runtime sanitizer hook: under ``EngineConfig(sanitize=True)``
        cross-check pool/arena/handle invariants from scratch after every
        state transition and raise :class:`SanitizeError` on the first
        drifted tick instead of serving corrupt state."""
        if self.config.sanitize:
            assert_engine(self)

    def events(self) -> Iterator[TokenEvent]:
        """Yield token events as they are produced, stepping the engine
        whenever the buffer runs dry, until it is idle. Consumes the
        engine-wide buffer, which holds events since the last drain (it is
        bounded, so an engine nobody consumed for a long stretch only
        replays its recent tail)."""
        while True:
            while self._events:
                yield self._events.popleft()
            if self.idle:
                return
            self.step()

    def stream(self, handle: RequestHandle) -> Iterator[TokenEvent]:
        """Yield one request's token events as they are produced, stepping
        the engine as needed, until its terminal event."""
        while True:
            while handle._events:
                ev = handle._events.popleft()
                yield ev
                if ev.done:
                    return
            if handle.done or self.idle:
                return
            self.step()

    def cancel(self, handle: RequestHandle) -> bool:
        """Terminate a request now. Queued requests leave the queue with an
        empty output; active requests complete with the tokens produced so
        far, and their cache rows / committed pages / undrawn span
        reservation return to the pool the same tick (immediately joinable
        capacity). False if the request already finished."""
        if handle.done:
            return False
        now = self.clock.now()
        qr = self.queue.remove(handle.rid)
        if qr is not None:
            qr.start_s = qr.finish_s = now
            self.metrics.cancelled += 1
            self._finish_record(
                handle, rid=qr.rid, batch=qr.req.batch,
                context=qr.req.context, bucket=None, group_size=0,
                joined_at_step=-1,
                tokens=jnp.zeros((qr.req.batch, 0), jnp.int32),
                queue_s=qr.queue_s, exec_s=0.0, total_s=qr.total_s,
                finish_reason="cancelled")
            self._push(TokenEvent(rid=qr.rid, index=0, token=None, t=now,
                                  step=0, done=True,
                                  finish_reason="cancelled"))
            self.handles.pop(qr.rid, None)
            self._sanitize()
            return True
        for group in self.active:
            for m in group.members:
                if m.qr.rid == handle.rid and not m.done:
                    self._complete(m, group, now, "cancelled")
                    self._sanitize()
                    return True
        return False

    def drain(self) -> List[Dict[str, Any]]:
        """Step until idle; returns the accumulated completion records."""
        while not self.idle:
            self.step()
        return self.results

    def run(self, arrivals: Iterable[Tuple[float, "ServeRequest"]],
            on_event=None) -> List[Dict[str, Any]]:
        """Replay a ``(arrival_s, request)`` trace to completion (the
        offline front door, shared with the router via ``EngineClient``).

        Arrivals are submitted when due on the engine clock; between
        arrivals the engine ticks, and an idle engine skips ahead to the
        next arrival instead of sleeping (virtual clock). ``on_event(ev)``
        is called for every event each tick emits — the hook streaming
        consumers and cancellation drivers use without re-implementing
        this loop."""
        todo = sorted(arrivals, key=lambda a: a[0])
        idx = 0
        while idx < len(todo) or not self.idle:
            now = self.clock.now()
            while idx < len(todo) and todo[idx][0] <= now:
                self.submit(todo[idx][1], arrival_s=todo[idx][0])
                idx += 1
            if self.idle:
                # idle: skip ahead to the next arrival instead of sleeping
                self.clock.advance_to(todo[idx][0])
                continue
            events = self.step()
            if on_event is not None:
                for ev in events:
                    on_event(ev)
        return self.results

    def withdraw(self, handle: RequestHandle) -> Optional[QueuedRequest]:
        """Silently remove a live request for resubmission elsewhere (the
        router's failover primitive). Unlike :meth:`cancel` this emits no
        terminal event and writes no completion record — the request is
        not *finished*, it is *moving* — and the admission count is given
        back, so fleet metrics don't double-count the resubmission. An
        active member's rows, committed pages, and undrawn span
        reservation return to the pool immediately. Returns the queue
        record (its original ``arrival_s`` rides along to the new
        replica); None if the request already finished."""
        if handle.done:
            return None
        qr = self.queue.remove(handle.rid)
        if qr is None:
            for group in list(self.active):
                for m in group.members:
                    if m.qr.rid == handle.rid and not m.done:
                        m.done = True
                        m.finish_reason = "withdrawn"
                        self.server.pool.free_rows(group.arena, m.rows,
                                                   early=True)
                        if group.done:
                            self._retire_group(group)
                            self.active.remove(group)
                        qr = m.qr
                        break
                if qr is not None:
                    break
        if qr is None:
            return None
        self.metrics.admitted -= 1
        self.handles.pop(handle.rid, None)
        self._page_denied_rids.discard(handle.rid)
        handle.state = "withdrawn"
        handle._events.clear()
        self._sanitize()
        return qr

    def discard(self, handle: RequestHandle) -> None:
        """Forget a finished request's bookkeeping (long-lived adapters —
        ``PlanServer.handle`` — would otherwise accumulate every result and
        event buffer for the life of the server). Only this request's
        events leave the engine-wide buffer; other in-flight requests'
        buffered events stay consumable."""
        self.handles.pop(handle.rid, None)
        if handle.result is not None and handle.result in self.results:
            self.results.remove(handle.result)
        handle._events.clear()
        if any(ev.rid == handle.rid for ev in self._events):
            self._events = deque(
                (ev for ev in self._events if ev.rid != handle.rid),
                maxlen=self._events.maxlen)

    def summary(self) -> str:
        # the engine's own total latency, not server.latency — handle()
        # keeps its own accumulator for the sequential adapter
        return scheduler_summary(self.metrics, self.server.metrics,
                                 self.metrics.total_latency,
                                 pool=self.server.pool)

    # -- event plumbing ----------------------------------------------------
    def _push(self, ev: TokenEvent) -> None:
        self._events.append(ev)
        if self._tick_sink is not None:
            self._tick_sink.append(ev)
        handle = self.handles.get(ev.rid)
        if handle is not None:
            handle._events.append(ev)

    def _member_of(self, rid: int) -> Optional[_Member]:
        for group in self.active:
            for m in group.members:
                if m.qr.rid == rid:
                    return m
        return None

    def _register_token(self, m: _Member, tok, now: float,
                        step: int) -> Optional[str]:
        """Record one emitted ``(batch, 1)`` token for a member: event,
        TTFT / inter-token latency accounting, and stop-condition checks.
        Returns the finish reason if a stop condition fired."""
        idx = m.emitted
        m.toks.append(tok)
        m.emitted += 1
        if idx == 0:
            self.metrics.observe_first_token(max(0.0, now - m.qr.arrival_s))
        else:
            self.metrics.observe_token_gap(max(0.0, now - m.last_t))
        m.last_t = now
        self._push(TokenEvent(rid=m.qr.rid, index=idx, token=tok, t=now,
                              step=step))
        req = m.req
        if req.eos_id is None and not req.stop:
            return None
        tok_host = np.asarray(tok)[:, 0]
        if m.rows_live is None:
            m.rows_live = np.ones(req.batch, bool)
        reason = None
        if req.eos_id is not None:
            m.rows_live &= tok_host != req.eos_id
            if not m.rows_live.any():
                reason = "eos"
        if reason is None and req.stop:
            if m.tails is None:
                m.tails = [[] for _ in range(req.batch)]
            max_len = max(len(s) for s in req.stop)
            for i in range(req.batch):
                if not m.rows_live[i]:
                    continue
                tail = m.tails[i]
                tail.append(int(tok_host[i]))
                del tail[:-max_len]
                if any(len(s) <= len(tail)
                       and tail[len(tail) - len(s):] == list(s)
                       for s in req.stop):
                    m.rows_live[i] = False
            if not m.rows_live.any():
                reason = "stop"
        return reason

    # -- member lifecycle --------------------------------------------------
    def _admit_members(self, group: _Group, queued: List[QueuedRequest],
                       join_step: int, now: float) -> List[_Member]:
        """Admit ``queued`` into the group: lease + page-commit their arena
        rows through the pool's one admission helper, prefill them as one
        batch (engine ``prefill`` mode permitting), and seat them at their
        own positions. Used both at group start (join_step 0) and for
        mid-decode joins."""
        srv = self.server
        handoff = self.prefill and srv.model.supports_handoff
        total_batch = sum(qr.req.batch for qr in queued)
        span = max(srv.request_span(qr.req) for qr in queued)
        # one admission sequence for every caller (rows + page commitment):
        # PR-4's recycled-arena zero= leak came from this drifting between
        # the sequential and scheduled paths
        rows_per_member = [
            srv.pool.admit_request_rows(
                group.arena, qr.req.batch,
                prompt=qr.req.context if handoff else 0,
                span=srv.request_span(qr.req), eager=self.eager_pages,
                where="_admit_members")
            for qr in queued]
        rows_flat = [r for rows in rows_per_member for r in rows]
        rows_a = jnp.asarray(rows_flat, jnp.int32)

        lengths_rows = []
        for qr in queued:
            qr.start_s = now
            # once admitted (group start or join), a page denial is history
            self._page_denied_rids.discard(qr.rid)
            handle = self.handles.get(qr.rid)
            if handle is not None:
                handle.state = "active"
            lengths_rows += [qr.req.context] * qr.req.batch

        first, pkv = None, None
        if self.prefill:
            entry = srv.prefill_entry(total_batch, span)
            pb = entry.key.batch_bucket
            lengths = jnp.asarray(
                lengths_rows + [1] * (pb - len(lengths_rows)), jnp.int32)
            logits, pkv = srv.run_prefill(entry, lengths=lengths)
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        if pkv is not None:
            srv.pool.write_rows(group.arena, rows_flat, pkv,
                                src_rows=range(len(rows_flat)))
            pos_rows = lengths_rows
        else:  # no handoff (or no prefill): rows decode from zero state —
            # clear any state a prior tenant of these rows/pages left behind
            # (mid-decode joiners can inherit rows a completed member freed)
            if join_step > 0:
                srv.pool.zero_rows(group.arena, rows_flat)
            pos_rows = [0] * len(rows_flat)
        group.pos = group.pos.at[rows_a].set(jnp.asarray(pos_rows, jnp.int32))
        seed = (first[: len(rows_flat)] if first is not None
                else jnp.ones((len(rows_flat), 1), jnp.int32))
        group.toks = group.toks.at[rows_a].set(seed)

        members = []
        group.peak_rows = max(group.peak_rows, group.arena.rows_used)
        row_i = 0
        for qr, rows in zip(queued, rows_per_member):
            m = _Member(qr=qr, rows=rows,
                        rows_a=jnp.asarray(rows, jnp.int32),
                        join_step=join_step,
                        base_pos=qr.req.context if pkv is not None else 0)
            row_i += qr.req.batch
            members.append(m)
            group.members.append(m)
            if self.prefill and self.count_first:
                # the prefill token already is token #1: it is emitted at
                # admission (this is the time-to-first-token moment), and a
                # 1-token request completes before any decode step
                tok = seed[row_i - qr.req.batch: row_i]
                reason = self._register_token(m, tok, now, join_step)
                if reason is not None or m.emitted >= qr.req.new_tokens:
                    self._complete(m, group, now, reason or "length")
        return members

    def _form_group(self, queued: List[QueuedRequest],
                    now: float) -> Optional[_Group]:
        srv = self.server
        handoff = self.prefill and srv.model.supports_handoff
        total_batch = sum(qr.req.batch for qr in queued)
        span = max(srv.request_span(qr.req) for qr in queued)
        entry = srv.decode_entry(total_batch, span)
        b, s = entry.key.batch_bucket, entry.key.seq_bucket
        # page-exact admission demand: what this group's members commit
        # (rows + span pages), not the arena's bucket-shaped capacity
        demand = sum(srv.pool.member_bytes(s, qr.req.batch,
                                           srv.request_span(qr.req))
                     for qr in queued) if srv.pool.paged else None
        # the pool is the single owner of cache construction; force the
        # lease when nothing is in flight so progress is always possible.
        # A recycled arena may hold a previous tenant's K/V and recurrent
        # state: families without a prefill handoff decode from what they
        # assume is a zero cache, so their lease must be zeroed (the
        # handoff write overwrites admitted rows wholesale — no zero needed)
        arena = srv.pool.acquire(b, s, zero=not handoff,
                                 force=not self.active,
                                 demand_bytes=demand)
        if arena is None:
            return None
        group = _Group(
            entry=entry, arena=arena,
            context=max(qr.req.context for qr in queued),
            members=[],
            toks=jnp.ones((b, 1), jnp.int32),
            pos=jnp.zeros((b,), jnp.int32),
        )
        self._admit_members(group, queued, 0, now)
        self.metrics.observe_group([qr.req.batch for qr in queued], b)
        return group

    # -- tick phases -------------------------------------------------------
    def _phase_joins(self, group: _Group) -> None:
        """Absorb pending same-bucket requests into the group's free arena
        rows — and free cache *pages*, which is the real admission unit on
        a paged pool — prefilled at their own positions (token-level
        continuous batching). Joiners skip the line only for capacity the
        head-of-line request could not use anyway — its own group still
        forms through ``next_group`` as soon as the pool can lease an
        arena."""
        srv = self.server
        arena = group.arena
        free = arena.rows_free
        if not free:
            return
        fits = None
        if srv.pool.paged:
            state = {"pages": arena.allocator.available if arena.n_pages
                     else None,
                     "bytes": srv.pool.bytes_room()}

            def fits(qr):
                span = srv.request_span(qr.req)
                pages = arena.span_pages(span) * qr.req.batch
                nbytes = srv.pool.member_bytes(arena.seq, qr.req.batch, span)
                if (state["pages"] is not None and pages > state["pages"]) \
                        or nbytes > state["bytes"]:
                    # count each backpressured *request* once, not once per
                    # tick it stays refused
                    if qr.rid not in self._page_denied_rids:
                        self._page_denied_rids.add(qr.rid)
                        srv.pool.metrics.pages_denied += 1
                    return False
                if state["pages"] is not None:
                    state["pages"] -= pages
                state["bytes"] -= nbytes
                self._page_denied_rids.discard(qr.rid)
                return True

        queued = self.queue.take_joinable(group.seq_bucket, free, fits=fits)
        if not queued:
            return
        members = self._admit_members(group, queued, group.steps_done,
                                      self.clock.now())
        self.metrics.observe_joins([m.req.batch for m in members])

    def _phase_form(self) -> None:
        """Coalesce + admit at most one new group (pool permitting)."""
        if not len(self.queue):
            return
        queued = self.queue.next_group()
        if not queued:
            return
        group = self._form_group(queued, self.clock.now())
        if group is None:
            # pool budget exhausted: requests wait (or join)
            self.queue.requeue_front(queued)
        else:
            self.active.append(group)

    def _phase_tick(self, group: _Group) -> None:
        """One decode step for the group; emit each live member's token.

        The arena *relinquishes* its cache pytree for the step and
        *re-adopts* the step's output: with a donating step (the default)
        the input buffers are consumed in place by XLA, so nothing may
        read the relinquished reference between the call and the adopt —
        the ``use-after-donation`` lint rule enforces exactly this shape.
        """
        srv = self.server
        if srv.pool.paged:
            # grant the page covering each live row's next write position
            # (on-demand paging: drawn from the admission-time reservation,
            # so this can never fail mid-decode)
            for m in group.members:
                if not m.done:
                    wpos = m.base_pos + (group.steps_done - m.join_step)
                    srv.pool.ensure_decode_slots(group.arena, m.rows, wpos)
            tables = group.arena.tables
            cache_in = group.arena.relinquish()
            logits, cache_out = group.entry.step_fn(
                srv.params, cache_in, group.toks, group.pos, tables)
        else:
            cache_in = group.arena.relinquish()
            logits, cache_out = group.entry.step_fn(
                srv.params, cache_in, group.toks, group.pos)
        # whether the step actually consumed its cache input (donation
        # aliased the buffers): host-side flag check, no device sync —
        # feeds the observed live-bytes watermark at group retire
        group.cache_donated = all(  # metadata probe, never touches buffers
            x.is_deleted() for x in jax.tree.leaves(cache_in)  # lint: allow-use-after-donation
            if hasattr(x, "is_deleted"))
        del cache_in
        group.arena.adopt(cache_out)
        if not group.cache_donated:
            group.double_buffer_bytes = max(group.double_buffer_bytes,
                                            group.arena.live_nbytes())
        group.toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        if self.sync_per_tick:
            jax.block_until_ready(group.toks)
        group.pos = group.pos + 1
        group.steps_done += 1
        now = self.clock.now()
        for m in group.members:
            if m.done:
                continue
            tok = jnp.take(group.toks, m.rows_a, axis=0)
            reason = self._register_token(m, tok, now, group.steps_done)
            if reason is not None:
                self._complete(m, group, now, reason)
            elif m.emitted >= m.req.new_tokens:
                # every mode emits exactly new_tokens outputs; they differ
                # only in whether token #1 came from prefill or decode
                self._complete(m, group, now, "length")

    def _finish_record(self, handle: Optional[RequestHandle],
                       **rec) -> Dict[str, Any]:
        # every record carries the full key set from birth; the plan-level
        # outcome is refined at group retire (queue-cancelled requests
        # never had a plan, so the defaults are their final values)
        rec.setdefault("plan", None)
        rec.setdefault("recompiled", False)
        rec.setdefault("recompile_reasons", ())
        rec.setdefault("watermark_bytes", 0.0)
        rec.setdefault("pool_bytes", 0.0)
        self.results.append(rec)
        if handle is not None:
            handle.result = rec
            handle.state = ("cancelled" if rec["finish_reason"] == "cancelled"
                            else "done")
        return rec

    def _complete(self, m: _Member, group: _Group, now: float,
                  reason: str = "length") -> None:
        m.done = True
        m.finish_reason = reason
        m.qr.finish_s = now
        early = reason != "length"
        if reason == "cancelled":
            self.metrics.cancelled += 1
        else:
            self.metrics.observe_request(m.qr.queue_s, m.qr.exec_s)
            if early:
                self.metrics.early_exits += 1
        toks = (jnp.concatenate(m.toks, axis=1) if m.toks
                else jnp.zeros((m.req.batch, 0), jnp.int32))
        self._finish_record(
            self.handles.get(m.qr.rid),
            rid=m.qr.rid, batch=m.req.batch, context=m.req.context,
            bucket=(group.entry.key.batch_bucket, group.entry.key.seq_bucket),
            group_size=len(group.members), joined_at_step=m.join_step,
            tokens=toks, queue_s=m.qr.queue_s, exec_s=m.qr.exec_s,
            total_s=m.qr.total_s, finish_reason=reason)
        self._push(TokenEvent(rid=m.qr.rid, index=m.emitted, token=None,
                              t=now, step=group.steps_done, done=True,
                              finish_reason=reason))
        # freed rows — and, on early exits, their committed pages plus the
        # undrawn span reservation — become join capacity immediately
        self.server.pool.free_rows(group.arena, m.rows, early=early)

    def _retire_group(self, group: _Group) -> None:
        """Observed runtime statistics — including the cache pool's live
        bytes — feed dynamic recompilation exactly as in the sequential
        path; then the arena goes back to the pool for reuse. Completion
        records of the group's members are annotated with the plan-level
        outcome (what ``PlanServer.handle`` reports per request)."""
        srv = self.server
        # the observed batch is the peak *concurrent* row usage — members
        # joining rows another member freed never widened the batch
        shape = InputShape(
            f"group_{group.peak_rows}x{group.context}",
            group.seq_bucket, group.peak_rows, "decode")
        # an un-donated step held input + output copies of the arena at
        # once: charge the observed watermark the second copy honestly
        stats = srv.observed_stats(
            group.entry, shape, group.toks,
            double_buffer_bytes=group.double_buffer_bytes)
        refreshed, reasons = srv.observe(group.entry.key, stats)
        plan = (refreshed or group.entry).plan
        for m in group.members:
            # retiring members are finished: annotate their records with
            # the plan-level outcome, then stop tracking their handles
            # (user-held handles keep their buffers and .result)
            handle = self.handles.pop(m.qr.rid, None)
            if handle is not None and handle.result is not None:
                handle.result.update(
                    plan=plan, recompiled=bool(reasons),
                    recompile_reasons=reasons,
                    watermark_bytes=stats.watermark_bytes,
                    pool_bytes=stats.cache_pool_bytes)
        srv.pool.release(group.arena)
