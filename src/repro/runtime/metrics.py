"""Step metrics / throughput accounting."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.config import HardwareSpec, InputShape, MeshConfig, ModelConfig, TPU_V5E
from repro.core.cost import model_flops_per_step


@dataclass
class StepTimer:
    model: Optional[ModelConfig] = None
    shape: Optional[InputShape] = None
    mesh: Optional[MeshConfig] = None
    hw: HardwareSpec = TPU_V5E
    history: List[Dict] = field(default_factory=list)
    _t0: float = 0.0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int, metrics: Dict) -> Dict:
        dt = time.perf_counter() - self._t0
        rec = {"step": step, "seconds": dt}
        rec.update({k: float(v) for k, v in metrics.items()})
        if self.model is not None and self.shape is not None:
            flops = model_flops_per_step(self.model, self.shape)
            rec["tokens_per_s"] = self.shape.global_batch * self.shape.seq_len / dt
            if self.mesh is not None:
                rec["mfu"] = flops / dt / (self.mesh.num_devices * self.hw.peak_flops)
        self.history.append(rec)
        return rec

    def summary(self) -> Dict:
        if not self.history:
            return {}
        n = len(self.history)
        keys = self.history[-1].keys()
        return {k: sum(h.get(k, 0.0) for h in self.history) / n
                for k in keys if k != "step"}


def format_metrics(rec: Dict) -> str:
    parts = []
    for k, v in rec.items():
        if isinstance(v, float):
            parts.append(f"{k}={v:.4g}")
        else:
            parts.append(f"{k}={v}")
    return "  ".join(parts)
