"""Paper claim (§1/§3): the cost-based compiler automatically generates
hybrid execution plans from data + cluster characteristics. Benchmark: the
plan chosen per (arch x shape) and the compiler's own latency."""

from __future__ import annotations

import time

from repro.config import INPUT_SHAPES, SINGLE_POD_MESH
from repro.configs import ARCH_IDS, get_config
from repro.core.planner import compile_plan


def run():
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            t0 = time.perf_counter()
            plan = compile_plan(cfg, shape, SINGLE_POD_MESH)
            us = (time.perf_counter() - t0) * 1e6
            c = plan.config
            rows.append(
                f"plan_{arch}_{shape.name},{us:.0f},"
                f"strategy={c.strategy.value};micro={c.microbatches};"
                f"opt_dtype={c.opt_state_dtype};"
                f"est_gib={plan.memory.total / 2**30:.2f};"
                f"fits={plan.memory.fits()}"
            )
    return rows
