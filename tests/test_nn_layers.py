"""Every manual backward in the DML-style NN library is validated against
jax.grad (the library itself never uses autodiff — paper §2, SystemML 1.0
has none)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import layers as L  # noqa: E402
from repro.nn import loss as LOSS  # noqa: E402

KEY = jax.random.PRNGKey(0)


def _check(got, want, rtol=3e-4, atol=1e-5):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=rtol, atol=atol)


def test_affine_backward():
    x = jax.random.normal(KEY, (8, 5))
    w, b = L.affine.init(5, 3, KEY)
    dout = jax.random.normal(KEY, (8, 3))
    got = L.affine.backward(dout, x, w, b)
    want = jax.grad(lambda x, w, b: jnp.sum(L.affine.forward(x, w, b) * dout),
                    argnums=(0, 1, 2))(x, w, b)
    _check(got, want)


@pytest.mark.parametrize("name", ["relu", "leaky_relu", "elu", "sigmoid",
                                  "tanh", "gelu", "softmax", "log_softmax"])
def test_elementwise_backward(name):
    cls = getattr(L, name)
    x = jax.random.normal(KEY, (6, 7)) * 2
    dout = jax.random.normal(jax.random.PRNGKey(1), (6, 7))
    got = cls.backward(dout, x)
    want = jax.grad(lambda x: jnp.sum(cls.forward(x) * dout))(x)
    _check(got, want, rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("kern,stride,pad", [(3, 1, 1), (5, 2, 2), (3, 2, 0)])
def test_conv2d_backward(kern, stride, pad):
    c, h, w = 3, 8, 8
    x = jax.random.normal(KEY, (4, c * h * w))
    cw, cb = L.conv2d.init(c, 6, kern, KEY)
    out, cols = L.conv2d.forward(x, cw, cb, c, h, w, kern, stride, pad)
    dout = jax.random.normal(KEY, out.shape)
    dx, dw, db = L.conv2d.backward(dout, cols, x, cw, c, h, w, kern, stride, pad)
    ax, aw, ab = jax.grad(
        lambda a, b_, c_: jnp.sum(L.conv2d.forward(a, b_, c_, c, h, w, kern,
                                                   stride, pad)[0] * dout),
        argnums=(0, 1, 2))(x, cw, cb)
    _check((dx, dw, db), (ax, aw, ab))


@pytest.mark.parametrize("cls_name", ["max_pool2d", "avg_pool2d"])
def test_pool_backward(cls_name):
    cls = getattr(L, cls_name)
    c, h, w, pool = 2, 8, 8, 2
    x = jax.random.normal(KEY, (3, c * h * w))
    out, _ = cls.forward(x, c, h, w, pool)
    dout = jax.random.normal(KEY, out.shape)
    dx = cls.backward(dout, None, x, c, h, w, pool)
    ax = jax.grad(lambda a: jnp.sum(cls.forward(a, c, h, w, pool)[0] * dout))(x)
    _check(dx, ax)


def test_batch_norm1d_backward():
    x = jax.random.normal(KEY, (16, 5))
    g, b, rm, rv = L.batch_norm1d.init(5)
    out, cache, _, _ = L.batch_norm1d.forward(x, g, b, "train", rm, rv)
    dout = jax.random.normal(KEY, out.shape)
    dx, dg, db = L.batch_norm1d.backward(dout, cache, x, g)

    def f(x, g, b):
        return jnp.sum(L.batch_norm1d.forward(x, g, b, "train", rm, rv)[0] * dout)

    ax, ag, ab = jax.grad(f, argnums=(0, 1, 2))(x, g, b)
    _check((dx, dg, db), (ax, ag, ab), rtol=1e-3, atol=1e-5)


def test_batch_norm2d_backward():
    c, h, w = 3, 4, 4
    x = jax.random.normal(KEY, (5, c * h * w))
    g, b, rm, rv = L.batch_norm2d.init(c)
    out, cache, _, _ = L.batch_norm2d.forward(x, g, b, c, h, w, "train", rm, rv)
    dout = jax.random.normal(KEY, out.shape)
    dx, dg, db = L.batch_norm2d.backward(dout, cache, x, g, c, h, w)
    ax, ag, ab = jax.grad(
        lambda x, g, b: jnp.sum(
            L.batch_norm2d.forward(x, g, b, c, h, w, "train", rm, rv)[0] * dout),
        argnums=(0, 1, 2))(x, g, b)
    _check((dx, dg, db), (ax, ag, ab), rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("cls_name", ["layer_norm", "rms_norm"])
def test_norm_backward(cls_name):
    cls = getattr(L, cls_name)
    x = jax.random.normal(KEY, (6, 7))
    params = cls.init(7)
    out = cls.forward(x, *params)
    dout = jax.random.normal(KEY, out[0].shape)
    if cls_name == "layer_norm":
        dx, dg, db = cls.backward(dout, out[1], x, params[0])
        want = jax.grad(lambda x, g, b: jnp.sum(cls.forward(x, g, b)[0] * dout),
                        argnums=(0, 1, 2))(x, *params)
        _check((dx, dg, db), want, rtol=1e-3, atol=1e-5)
    else:
        dx, dg = cls.backward(dout, out[1], x, params[0])
        want = jax.grad(lambda x, g: jnp.sum(cls.forward(x, g)[0] * dout),
                        argnums=(0, 1))(x, *params)
        _check((dx, dg), want, rtol=1e-3, atol=1e-5)


def test_scale_shift_backward():
    x = jax.random.normal(KEY, (6, 7))
    g, b = L.scale_shift.init(7)
    dout = jax.random.normal(KEY, x.shape)
    got = L.scale_shift.backward(dout, x, g)
    want = jax.grad(lambda x, g, b: jnp.sum(L.scale_shift.forward(x, g, b) * dout),
                    argnums=(0, 1, 2))(x, g, b)
    _check(got, want)


def test_embedding_backward():
    table, = L.embedding.init(11, 4, KEY)
    ids = jnp.array([1, 3, 3, 0])
    dout = jax.random.normal(KEY, (4, 4))
    got = L.embedding.backward(dout, ids, table)
    want = jax.grad(lambda t: jnp.sum(L.embedding.forward(ids, t) * dout))(table)
    _check(got, want)


def test_dropout_backward_and_scaling():
    x = jnp.ones((400, 10))
    out, mask = L.dropout.forward(x, 0.3, KEY)
    # inverted dropout: expectation preserved
    assert abs(float(out.mean()) - 1.0) < 0.1
    dout = jax.random.normal(KEY, x.shape)
    _check(L.dropout.backward(dout, mask), dout * mask)


def test_simple_rnn_backward():
    x = jax.random.normal(KEY, (2, 5, 4))
    wx, wh, b = L.simple_rnn.init(4, 3, KEY)
    h0 = jnp.zeros((2, 3))
    hs, _ = L.simple_rnn.forward(x, wx, wh, b, h0)
    dhs = jax.random.normal(KEY, hs.shape)
    got = L.simple_rnn.backward(dhs, x, wx, wh, b, h0)
    want = jax.grad(lambda *a: jnp.sum(L.simple_rnn.forward(*a)[0] * dhs),
                    argnums=(0, 1, 2, 3, 4))(x, wx, wh, b, h0)
    _check(got, want, rtol=1e-3, atol=1e-5)


def test_lstm_backward():
    x = jax.random.normal(KEY, (2, 5, 4))
    wx, wh, b = L.lstm.init(4, 3, KEY)
    h0 = jnp.zeros((2, 3))
    c0 = jnp.zeros((2, 3))
    hs, _, cache = L.lstm.forward(x, wx, wh, b, h0, c0)
    dhs = jax.random.normal(KEY, hs.shape)
    got = L.lstm.backward(dhs, cache, x, wx, wh, b, h0, c0)
    want = jax.grad(lambda *a: jnp.sum(L.lstm.forward(*a)[0] * dhs),
                    argnums=(0, 1, 2, 3, 4, 5))(x, wx, wh, b, h0, c0)
    _check(got, want, rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("loss_name,probs", [
    ("cross_entropy_loss", True), ("softmax_cross_entropy", False),
    ("l2_loss", False), ("log_loss", True),
])
def test_loss_backward(loss_name, probs):
    cls = getattr(LOSS, loss_name)
    raw = jax.random.normal(KEY, (6, 4))
    pred = jax.nn.softmax(raw) if probs else raw
    y = jax.nn.one_hot(jnp.array([0, 1, 2, 3, 1, 0]), 4)
    got = cls.backward(pred, y)
    want = jax.grad(lambda p: cls.forward(p, y))(pred)
    _check(got, want, rtol=1e-3, atol=1e-5)


def test_reg_backward():
    w = jax.random.normal(KEY, (5, 5))
    _check(LOSS.l2_reg.backward(w, 0.1),
           jax.grad(lambda w: LOSS.l2_reg.forward(w, 0.1))(w))
    _check(LOSS.l1_reg.backward(w, 0.1),
           jax.grad(lambda w: LOSS.l1_reg.forward(w, 0.1))(w))


def test_library_has_20_plus_layers():
    layer_names = [n for n in dir(L) if not n.startswith("_")
                   and hasattr(getattr(L, n), "forward")]
    assert len(layer_names) >= 20, layer_names
