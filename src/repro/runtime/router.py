"""EngineRouter: N ServingEngine replicas behind one EngineClient surface.

PR 5 collapsed the serving front doors into one request-lifecycle API for
*one* engine; this module applies the same SystemML single-API argument to
serving **topology**. Callers still ``submit(req)`` and consume token
events — the router decides *which replica* runs the request, the way the
paper's compiler decides single-node vs. distributed execution from data
and cluster characteristics (and BigDL's Orca estimator fans one logical
fit/predict over workers):

- **placement** (``EngineConfig.placement``): the default ``"affinity"``
  policy scores replicas lexicographically on *deterministic, discrete*
  signals — can the request join an in-flight same-bucket group right now;
  would it have to queue at all; does the replica's plan cache already
  hold the bucket (no compile on the request's critical path); then
  queued+resident rows, pool live bytes, and replica index as tie-breaks.
  Immediacy outranks plan affinity on purpose: a busy warm replica must
  not win over an idle cold one, or the fleet would queue work while a
  device sits idle. Identical traces therefore place identically (the
  property tests gate on this). The ``"load"`` policy instead ranks by
  queue pressure and the replica's *observed* TTFT tail — wall-derived,
  so adaptive rather than deterministic.

- **per-replica device time** (:class:`~repro.runtime.engine.ReplicaClock`):
  replicas co-simulated serially on one host each accrue only their own
  compute, so fleet throughput is measured in device time — N replicas
  genuinely overlap, exactly as N distinct meshes would.

- **drain / failover** (:meth:`EngineRouter.drain_replica`): a draining
  replica's queued *and* mid-decode requests are silently withdrawn
  (``ServingEngine.withdraw`` — no spurious terminal events, rows/pages
  reclaimed) and resubmitted to survivors with their original arrival
  times. Replicas share params (same config seed) and greedy decode is
  group-composition-invariant, so the re-decode reproduces the tokens
  already streamed; :class:`RouterHandle` dedupes by delivered count and
  the consumer sees one gapless, byte-identical stream. Zero accepted
  requests are lost — the bench gate checks both properties.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from dataclasses import replace as dc_replace
from typing import (TYPE_CHECKING, Any, Deque, Dict, Iterable, Iterator,
                    List, Optional, Sequence, Tuple)

from repro.analysis.sanitize import assert_router
from repro.core.plan_cache import bucket_pow2
from repro.runtime.engine import (ReplicaClock, RequestHandle, ServingEngine,
                                  TokenEvent)
from repro.runtime.engine_config import EngineConfig
from repro.runtime.metrics import (RouterMetrics, SchedulerMetrics,
                                   merge_scheduler_metrics, router_summary)

if TYPE_CHECKING:
    from repro.runtime.serve_loop import PlanServer, ServeRequest


@dataclass
class _Replica:
    """One engine + its private device clock and drain flag."""

    idx: int
    server: "PlanServer"
    engine: ServingEngine
    clock: ReplicaClock
    draining: bool = False

    @property
    def load_rows(self) -> int:
        """Queued plus live resident batch rows — the placement load
        signal (discrete, deterministic)."""
        eng = self.engine
        return (sum(qr.req.batch for qr in eng.queue.pending)
                + sum(m.req.batch for g in eng.active
                      for m in g.members if not m.done))


@dataclass(frozen=True)
class PlacementDecision:
    """Audit record of one routing choice: which replica won and which
    score component decided it ("join" — fit an in-flight group;
    "idle" — serves immediately; "warm" — plan cache held the bucket;
    "load" — least-loaded fallback; "failover" — moved off a draining
    replica)."""

    rid: int
    replica: int
    reason: str
    t: float


class RouterHandle:
    """Fleet-level request handle: same shape as
    :class:`~repro.runtime.engine.RequestHandle`, but stable across
    failover. ``delivered`` counts token events forwarded to consumers —
    after a resubmission the new replica re-emits indices from 0, and the
    handle forwards only what was not already streamed, so one request is
    always one gapless token stream."""

    def __init__(self, router: "EngineRouter", req: "ServeRequest"):
        self._router = router
        self.req = req
        self.inner: Optional[RequestHandle] = None
        self.replica: Optional[_Replica] = None
        self.delivered = 0
        self.resubmits = 0
        self._events: Deque[TokenEvent] = deque()

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def qr(self):
        return self.inner.qr

    @property
    def result(self):
        return self.inner.result

    @property
    def done(self) -> bool:
        return self.inner.done

    @property
    def state(self) -> str:
        return self.inner.state

    def tokens(self):
        return self.inner.tokens()

    def stream(self) -> Iterator[TokenEvent]:
        return self._router.stream(self)

    def cancel(self) -> bool:
        return self._router.cancel(self)

    def __repr__(self) -> str:
        return (f"RouterHandle(rid={self.rid}, state={self.state!r}, "
                f"replica={self.replica.idx if self.replica else None})")


class EngineRouter:
    """N :class:`ServingEngine` replicas behind the one ``EngineClient``
    lifecycle — ``submit``/``step``/``events``/``stream``/``cancel``/
    ``drain``/``run`` — plus :meth:`drain_replica` for failover.

    ``servers`` are one :class:`PlanServer` per replica (distinct pools
    and plan caches; build them from the same :class:`EngineConfig` so
    params match and failover re-decodes are byte-identical).
    """

    def __init__(self, servers: Sequence["PlanServer"], *,
                 config: Optional[EngineConfig] = None):
        servers = list(servers)
        if not servers:
            raise ValueError("EngineRouter needs at least one server")
        cfg = config if config is not None else getattr(
            servers[0], "config", None) or EngineConfig()
        if cfg.replicas != len(servers):
            cfg = dc_replace(cfg, replicas=len(servers))
        self.config = cfg
        self.replicas: List[_Replica] = []
        for i, srv in enumerate(servers):
            clock = ReplicaClock()
            eng = ServingEngine(srv, config=cfg, clock=clock)
            self.replicas.append(_Replica(i, srv, eng, clock))
        self.handles: Dict[int, RouterHandle] = {}
        self.results: List[Dict[str, Any]] = []
        self.decisions: List[PlacementDecision] = []
        self.router_metrics = RouterMetrics()
        # same bounded-buffer semantics as the engine's event stream
        self._events: Deque[TokenEvent] = deque(maxlen=8192)

    # -- lifecycle API -----------------------------------------------------
    @property
    def idle(self) -> bool:
        return all(r.engine.idle for r in self.replicas)

    @property
    def metrics(self) -> SchedulerMetrics:
        """Fleet rollup of every replica's scheduler metrics (merged
        latency distributions, summed counters)."""
        return merge_scheduler_metrics([r.engine.metrics
                                        for r in self.replicas])

    def now(self) -> float:
        """Fleet virtual time: the most-advanced replica clock."""
        return max(r.clock.now() for r in self.replicas)

    def submit(self, req: "ServeRequest",
               arrival_s: Optional[float] = None) -> RouterHandle:
        """Place a request on one replica (see module docstring for the
        policy) and return its fleet-level handle."""
        if req.rid in self.handles:
            raise ValueError(
                f"request rid={req.rid} is already in flight in this "
                f"router; construct a new ServeRequest to resubmit")
        now = arrival_s if arrival_s is not None else self.now()
        handle = RouterHandle(self, req)
        self.handles[req.rid] = handle
        self._place(handle, now)
        return handle

    def step(self) -> List[TokenEvent]:
        """One fleet tick: rebalance queued work onto idle replicas, then
        step every busy replica once, laggard-first (keeps the per-replica
        clocks loosely synchronized), each inside its own clock's
        resume/pause window. Returns the forwarded events."""
        self._rebalance()
        out: List[TokenEvent] = []
        busy = [r for r in self.replicas if not r.engine.idle]
        for r in sorted(busy, key=lambda r: (r.clock.now(), r.idx)):
            out.extend(self._step_replica(r))
        self._sanitize()
        return out

    def _sanitize(self) -> None:
        """Fleet-level sanitizer hook (``EngineConfig(sanitize=True)``):
        every replica's pool/handle invariants plus router-level placement
        and delivery bookkeeping, re-derived from scratch each tick."""
        if self.config.sanitize:
            assert_router(self)

    def _rebalance(self) -> None:
        """Work stealing: placement is one-shot, so a replica that
        finishes early could otherwise sit idle while another's queue is
        backlogged — exactly the starvation the router exists to prevent.
        Each idle replica steals the oldest queued request from the
        most-backlogged donor (one per tick; followers migrate on
        subsequent ticks if the imbalance persists)."""
        for r in self.replicas:
            if r.draining or not r.engine.idle:
                continue
            donors = [d for d in self.replicas
                      if d is not r and len(d.engine.queue)]
            if not donors:
                continue
            donor = max(donors, key=lambda d: (len(d.engine.queue), -d.idx))
            qr = donor.engine.queue.pending[0]
            handle = self.handles.get(qr.rid)
            if handle is None:
                continue
            wqr = donor.engine.withdraw(handle.inner)
            if wqr is None:
                continue
            self._place(handle, wqr.arrival_s, reason="rebalance", target=r)

    def events(self) -> Iterator[TokenEvent]:
        while True:
            while self._events:
                yield self._events.popleft()
            if self.idle:
                return
            self.step()

    def stream(self, handle: RouterHandle) -> Iterator[TokenEvent]:
        while True:
            while handle._events:
                ev = handle._events.popleft()
                yield ev
                if ev.done:
                    return
            if handle.done or self.idle:
                return
            self.step()

    def cancel(self, handle: RouterHandle) -> bool:
        if handle.done:
            return False
        ok = handle.replica.engine.cancel(handle.inner)
        if ok:
            # the engine pushed the terminal event outside a tick; forward
            # it (delivered-count dedupe makes replayed tokens no-ops)
            while handle.inner._events:
                self._forward(handle.inner._events.popleft())
        return ok

    def drain(self) -> List[Dict[str, Any]]:
        while not self.idle:
            self.step()
        return self.results

    def run(self, arrivals: Iterable[Tuple[float, "ServeRequest"]],
            on_event=None) -> List[Dict[str, Any]]:
        """Co-simulated trace replay over the fleet. Between arrivals,
        the replica whose device clock lags furthest behind steps next —
        replicas process *concurrently in virtual time* while the host
        interleaves them serially — and each arrival is placed when every
        busy replica has reached its arrival instant, so placement sees
        the fleet state of that moment."""
        todo = sorted(arrivals, key=lambda a: a[0])
        idx = 0
        while idx < len(todo) or not self.idle:
            self._rebalance()
            t_next = todo[idx][0] if idx < len(todo) else math.inf
            busy = [r for r in self.replicas
                    if not r.engine.idle and r.clock.now() < t_next]
            if busy:
                lag = min(busy, key=lambda r: (r.clock.now(), r.idx))
                for ev in self._step_replica(lag):
                    if on_event is not None:
                        on_event(ev)
                continue
            t, req = todo[idx]
            idx += 1
            self.submit(req, arrival_s=t)
        return self.results

    # -- failover ----------------------------------------------------------
    def drain_replica(self, idx: int) -> List[RouterHandle]:
        """Take replica ``idx`` out of rotation and move its live work to
        the survivors: queued and mid-decode requests are silently
        withdrawn (rows/pages reclaimed, no terminal events) and
        resubmitted with their *original* arrival times, so queueing
        latency honestly includes the disruption. Returns the moved
        handles; zero accepted requests are lost."""
        r = self.replicas[idx]
        if r.draining:
            return []
        if not [x for x in self.replicas if not x.draining and x is not r]:
            raise ValueError("cannot drain the last live replica")
        r.draining = True
        self.router_metrics.failovers += 1
        self.router_metrics.drained += 1
        moved: List[RouterHandle] = []
        victims = [h for h in self.handles.values()
                   if h.replica is r and not h.done]
        for h in victims:
            qr = r.engine.withdraw(h.inner)
            if qr is None:
                continue
            self._place(h, qr.arrival_s, failover=True)
            h.resubmits += 1
            self.router_metrics.resubmitted += 1
            moved.append(h)
        self._sanitize()
        return moved

    def restore_replica(self, idx: int) -> None:
        """Put a drained replica back into placement rotation."""
        r = self.replicas[idx]
        if r.draining:
            r.draining = False
            self.router_metrics.drained -= 1

    # -- placement ---------------------------------------------------------
    def _place(self, handle: RouterHandle, arrival_s: float,
               failover: bool = False, reason: Optional[str] = None,
               target: Optional[_Replica] = None) -> None:
        req = handle.req
        if target is not None:
            best = target
        else:
            candidates = [r for r in self.replicas if not r.draining]
            if not candidates:
                raise RuntimeError("every replica is draining")
            score, best = min(((self._score(r, req), r)
                               for r in candidates), key=lambda sr: sr[0])
            if reason is None:
                reason = ("failover" if failover else
                          "join" if score[0] == 0 else
                          "idle" if score[1] == 0 else
                          "warm" if score[2] == 0 else "load")
        if best.engine.idle:
            # the device sat idle until this arrival: skip its clock
            # forward like any idle engine would (never rewinds)
            best.clock.advance_to(arrival_s)
        handle.inner = best.engine.submit(req, arrival_s=arrival_s)
        handle.replica = best
        self.decisions.append(
            PlacementDecision(req.rid, best.idx, reason, arrival_s))
        self.router_metrics.observe_placement(reason)

    def _score(self, r: _Replica, req: "ServeRequest") -> Tuple:
        """Lexicographic placement score — smaller wins. Order matters:
        immediacy (join / no-queue) outranks plan warmth, which outranks
        load; a busy warm replica must never beat an idle cold one, or
        the router would queue work while a device idles (the
        starvation-freedom property test)."""
        eng, srv = r.engine, r.server
        sb = eng.queue.seq_bucket(req)
        # rows already spoken for by queued same-bucket work: a joiner
        # only truly fits if capacity remains after the earlier queue
        # would be seated (conservative, keeps placement FIFO-honest)
        queued_rows = sum(qr.req.batch for qr in eng.queue.pending
                          if eng.queue.seq_bucket(qr.req) == sb)
        can_join = False
        if eng.join_mid_decode:
            for g in eng.active:
                if g.seq_bucket != sb:
                    continue
                if g.arena.rows_free - queued_rows < req.batch:
                    continue
                if self._join_fits(srv, g.arena, req):
                    can_join = True
                    break
        span = srv.request_span(req)
        demand = (srv.pool.member_bytes(sb, req.batch, span)
                  if srv.pool.paged else None)
        bb = bucket_pow2(req.batch, srv.policy.min_batch)
        # an idle engine can always force a lease; otherwise ask the pool
        can_form = (not eng.active) or srv.pool.can_acquire(
            bb, sb, demand_bytes=demand)
        # "immediate" means a join (shares the group's decode step — free
        # capacity) or an idle engine; a busy engine that can merely lease
        # another arena still contends for the device, so the request
        # effectively queues behind the in-flight work
        would_queue = (not can_join) and (
            len(eng.queue) > 0 or bool(eng.active) or not can_form)
        if self.config.placement == "load":
            # adaptive: queue pressure, then the replica's observed TTFT
            # tail (wall-derived — deliberately not deterministic)
            return (1 if would_queue else 0, r.load_rows,
                    eng.metrics.ttft_latency.percentile(95),
                    srv.pool.live_bytes(), r.idx)
        has_plan = any(k.kind == "decode" and k.seq_bucket == sb
                       for k in srv.cache.keys())
        return (0 if can_join else 1,
                1 if would_queue else 0,
                0 if has_plan else 1,
                r.load_rows, srv.pool.live_bytes(), r.idx)

    @staticmethod
    def _join_fits(srv: "PlanServer", arena, req: "ServeRequest") -> bool:
        """Mirror of the engine's paged join predicate: free rows are not
        enough, the request's pages and bytes must fit too."""
        if not srv.pool.paged:
            return True
        span = srv.request_span(req)
        pages = arena.span_pages(span) * req.batch
        if arena.n_pages and pages > arena.allocator.available:
            return False
        return (srv.pool.member_bytes(arena.seq, req.batch, span)
                <= srv.pool.bytes_room())

    # -- event plumbing ----------------------------------------------------
    def _step_replica(self, r: _Replica) -> List[TokenEvent]:
        r.clock.resume()
        try:
            tick = r.engine.step()
        finally:
            r.clock.pause()
        out = []
        for ev in tick:
            fwd = self._forward(ev)
            if fwd is not None:
                out.append(fwd)
        return out

    def _forward(self, ev: TokenEvent) -> Optional[TokenEvent]:
        """Dedupe + re-index one replica event into the fleet stream.
        Token events below the handle's delivered count are failover
        replays (already streamed) and are dropped; terminal events
        finalize the handle and append its record in fleet completion
        order."""
        handle = self.handles.get(ev.rid)
        if handle is None:
            return None
        if ev.token is not None:
            if ev.index < handle.delivered:
                return None
            fwd = (ev if ev.index == handle.delivered
                   else dc_replace(ev, index=handle.delivered))
            handle.delivered += 1
        elif ev.done:
            fwd = (ev if ev.index == handle.delivered
                   else dc_replace(ev, index=handle.delivered))
            self.results.append(handle.inner.result)
            self.handles.pop(ev.rid, None)
        else:
            return None
        self._events.append(fwd)
        handle._events.append(fwd)
        return fwd

    # -- reporting ---------------------------------------------------------
    def summary(self) -> str:
        return router_summary(self)
