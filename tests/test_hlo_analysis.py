"""The call-graph-weighted HLO cost parser (launch.hlo_analysis) on a
static fixture: while-loop trip-count multiplication, dot FLOPs through
the symbol table, and collective byte accounting."""

from repro.config import TPU_V5E
from repro.core.cost import roofline_terms
from repro.launch import hlo_analysis as H

FIXTURE = """
HloModule jit_step, num_partitions=8

%body (param: (s32[], f32[32,64], f32[6,256,64])) -> (s32[], f32[32,64], f32[6,256,64]) {
  %param = (s32[], f32[32,64]{1,0}, f32[6,256,64]{2,1,0}) parameter(0)
  %constant.10 = s32[] constant(1)
  %gte2 = f32[6,256,64]{2,1,0} get-tuple-element(%param), index=2
  %gte1 = f32[32,64]{1,0} get-tuple-element(%param), index=1
  %gte0 = s32[] get-tuple-element(%param), index=0
  %copy = f32[32,64]{0,1} copy(%gte1)
  %all-gather = f32[32,256]{0,1} all-gather(%copy), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}
  %wslice = f32[256,64]{1,0} slice(%gte2), slice={[0:1], [0:256], [0:64]}
  %dot = f32[32,64]{1,0} dot(%all-gather, %wslice), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %add = s32[] add(%gte0, %constant.10)
  ROOT %tuple.6 = (s32[], f32[32,64]{1,0}, f32[6,256,64]{2,1,0}) tuple(%add, %dot, %gte2)
}

%cond (param.1: (s32[], f32[32,64], f32[6,256,64])) -> pred[] {
  %param.1 = (s32[], f32[32,64]{1,0}, f32[6,256,64]{2,1,0}) parameter(0)
  %constant.18 = s32[] constant(6)
  %gte = s32[] get-tuple-element(%param.1), index=0
  ROOT %lt = pred[] compare(%gte, %constant.18), direction=LT
}

ENTRY %main (p0: f32[6,256,64], p1: f32[32,64]) -> f32[] {
  %p0 = f32[6,256,64]{2,1,0} parameter(0)
  %p1 = f32[32,64]{1,0} parameter(1)
  %c0 = s32[] constant(0)
  %tuple.4 = (s32[], f32[32,64]{1,0}, f32[6,256,64]{2,1,0}) tuple(%c0, %p1, %p0)
  %while.8 = (s32[], f32[32,64]{1,0}, f32[6,256,64]{2,1,0}) while(%tuple.4), condition=%cond, body=%body
  %gtew = f32[32,64]{1,0} get-tuple-element(%while.8), index=1
  %reduced = f32[] reduce(%gtew, %c0), dimensions={0,1}, to_apply=%cond
  ROOT %all-reduce = f32[] all-reduce(%reduced), channel_id=2, replica_groups=[2,4]<=[8]
}
"""


def test_trip_count_from_condition_constant():
    cost = H.analyze(FIXTURE)
    # dot: 2*32*64*256 flops, 6 trips
    dot_flops = 2 * 32 * 64 * 256 * 6
    assert cost.flops >= dot_flops
    assert cost.flops < dot_flops * 1.2  # small elementwise overhead only


def test_collectives_counted_with_loop_multiplier():
    cost = H.analyze(FIXTURE)
    ag_bytes = 32 * 256 * 4 * 6        # in-loop all-gather x 6
    ar_bytes = 2 * 4 * 3 // 4          # scalar all-reduce (2x(g-1)/g)
    assert cost.collectives["all-gather"] == ag_bytes
    assert abs(cost.collectives["all-reduce"] - ar_bytes) <= 8
    assert cost.collective_count == 6 + 1


def test_known_trip_count_backend_config_preferred():
    txt = FIXTURE.replace(
        "body=%body",
        'body=%body, backend_config={"known_trip_count":{"n":"3"}}')
    cost = H.analyze(txt)
    assert cost.collectives["all-gather"] == 32 * 256 * 4 * 3


def test_shape_bytes_tuple_types():
    assert H._shape_bytes("f32[4,4]{1,0}") == 64
    assert H._shape_bytes("bf16[8]{0}") == 16
    assert H._shape_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16
    assert H._shape_bytes("pred[]") == 1


def test_roofline_terms_math():
    t = roofline_terms(197e12, 819e9, 50e9, 1, TPU_V5E, per_chip=True)
    assert abs(t.compute_s - 1.0) < 1e-6
    assert abs(t.memory_s - 1.0) < 1e-6
    assert abs(t.collective_s - 1.0) < 1e-6
    assert t.dominant in ("compute", "memory", "collective")
