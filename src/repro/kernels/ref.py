"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the semantic ground truth; kernels are asserted allclose
against these across shape/dtype sweeps in ``tests/test_kernels_*.py``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


# ---------------------------------------------------------------------------
# conv2d via im2col (paper ref [5]) — NCHW, square kernel
# ---------------------------------------------------------------------------

def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, pad: int = 0) -> jnp.ndarray:
    """x: (N, C, H, W); w: (F, C, k, k) -> (N, F, Ho, Wo)."""
    return lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, causal, optional sliding window)
# ---------------------------------------------------------------------------

def attention_ref(
    q: jnp.ndarray,      # (B, Hq, Sq, D)
    k: jnp.ndarray,      # (B, Hkv, Sk, D)
    v: jnp.ndarray,      # (B, Hkv, Sk, D)
    causal: bool = True,
    window: int = 0,     # 0 = full; else sliding window size
    q_offset: Optional[int] = None,  # absolute position of q[0] (decode)
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qf = q.astype(jnp.float32) / (d ** 0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(b, hkv, g, sq, d)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf)
    sk = k.shape[2]
    off = q_offset if q_offset is not None else sk - sq
    qpos = off + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (can happen with tiny windows) -> zeros, not NaN
    row_has_any = jnp.any(mask, axis=-1)[None, None, None, :, None]  # (1,1,1,sq,1)
    p = jnp.where(row_has_any, p, 0.0)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged decode attention (page-table indirection + decode validity mask)
# ---------------------------------------------------------------------------

def phys_slots(tables: jnp.ndarray, sc: int, page: int) -> jnp.ndarray:
    """Physical slot index for every logical slot 0..sc-1 of every row.

    tables: (B, n_pages) int32 page table -> (B, sc) int32 flat-stack slots.
    Mirrors ``models/attention.py::paged_slots`` over a dense slot range;
    kept here so kernels stay import-free of the model layer.
    """
    b, n_pages = tables.shape
    i = jnp.arange(sc, dtype=jnp.int32)
    lp = jnp.clip(i // page, 0, n_pages - 1)
    entry = jnp.take_along_axis(tables, jnp.broadcast_to(lp, (b, sc)), axis=1)
    return entry * page + i % page


def paged_decode_ref(
    q: jnp.ndarray,        # (B, 1, Hq, D)
    k_cache: jnp.ndarray,  # (n_slots, Hkv, D) flat slot stack
    v_cache: jnp.ndarray,  # (n_slots, Hkv, D)
    tables: jnp.ndarray,   # (B, n_pages) int32
    pos: jnp.ndarray,      # (B,) int32
    *,
    page: int,
    sc: int,
    window: int = 0,       # >0: rotating per-row cache of modulus sc
) -> jnp.ndarray:
    """Semantic ground truth for the paged decode kernel.

    Deliberately the *literal* composition the serving path used before the
    fused kernel: gather every logical slot, expand GQA heads with repeat,
    and apply ``decode_attention``'s validity rule verbatim — including the
    rotating-window arithmetic, which the kernel replaces with the reduced
    ``i < min(pos + 1, sc)`` mask. Tests comparing the two prove that
    reduction.
    """
    bsz, _, hq, d = q.shape
    hkv = k_cache.shape[1]
    g = hq // hkv
    n_slots = k_cache.shape[0]
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (bsz,))[:, None]

    i = jnp.arange(sc, dtype=jnp.int32)[None, :]               # (1, sc)
    if window > 0:
        p_i = posb - jnp.mod(posb - i, sc)
        valid = (p_i >= 0) & (p_i <= posb)
    else:
        valid = i <= posb
    phys = jnp.minimum(phys_slots(tables, sc, page), n_slots - 1)

    ke = jnp.repeat(k_cache[phys], g, axis=2)                  # (B, sc, Hq, D)
    ve = jnp.repeat(v_cache[phys], g, axis=2)
    qf = q.astype(jnp.float32)[:, 0] * (d ** -0.5)             # (B, Hq, D)
    s = jnp.einsum("bhd,bkhd->bhk", qf, ke.astype(jnp.float32))
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhk,bkhd->bhd", p, ve.astype(jnp.float32))
    return o[:, None].astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) — sequential-scan semantics
# ---------------------------------------------------------------------------

def ssd_ref(
    x: jnp.ndarray,      # (B, S, H, P)
    dt: jnp.ndarray,     # (B, S, H)        softplus-activated step sizes
    a: jnp.ndarray,      # (H,)             negative decay rates (A = -exp(a_log))
    b_mat: jnp.ndarray,  # (B, S, N)
    c_mat: jnp.ndarray,  # (B, S, N)
    d: jnp.ndarray,      # (H,)             skip connection
    init_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
):
    """Returns (y: (B,S,H,P), final_state: (B,H,P,N)).

    Recurrence per head h:
        state_t = exp(dt_t a_h) state_{t-1} + dt_t x_t b_t^T
        y_t     = state_t c_t + d_h x_t
    """
    B, S, H, P = x.shape
    N = b_mat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)
    af = a.astype(jnp.float32)
    state0 = (jnp.zeros((B, H, P, N), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp          # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * af[None, :])                  # (B,H)
        upd = (dtt[..., None] * xt)[..., None] * bt[:, None, None, :]  # (B,H,P,N)
        state = decay[..., None, None] * state + upd
        yt = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, yt

    inputs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
              bf.transpose(1, 0, 2), cf.transpose(1, 0, 2))
    final, ys = lax.scan(step, state0, inputs)
    y = ys.transpose(1, 0, 2, 3) + d[None, None, :, None] * xf
    return y.astype(x.dtype), final


def ssd_chunked_ref(x, dt, a, b_mat, c_mat, d, chunk: int = 16, init_state=None):
    """Chunked (BLAS-3 / "duality") formulation — same math as :func:`ssd_ref`
    but expressed as within-chunk matmuls + inter-chunk state carry. This is
    the algorithm the Pallas kernel implements; kept in ref form so the
    kernel and the math can be tested independently."""
    B, S, H, P = x.shape
    N = b_mat.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    xf = x.astype(jnp.float32).reshape(B, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, chunk, H)
    bf = b_mat.astype(jnp.float32).reshape(B, nc, chunk, N)
    cf = c_mat.astype(jnp.float32).reshape(B, nc, chunk, N)
    af = a.astype(jnp.float32)

    def chunk_step(state, inp):
        xc, dtc, bc, cc = inp    # (B,c,H,P), (B,c,H), (B,c,N), (B,c,N)
        aseg = dtc * af[None, None, :]                 # (B,c,H)
        cum = jnp.cumsum(aseg, axis=1)                 # inclusive cumsum
        total = cum[:, -1]                             # (B,H)
        # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i>=j. Mask BEFORE the
        # exp: the i<j entries are positive and overflow to inf, which
        # poisons the gradient of jnp.where (NaN via inf * 0).
        li = cum[:, :, None, :] - cum[:, None, :, :]   # (B,c,c,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        lmat = jnp.exp(jnp.where(tri[None, :, :, None], li, -1e30))
        scores = jnp.einsum("bin,bjn->bij", cc, bc)    # (B,c,c)
        w = scores[..., None] * lmat                   # (B,c,c,H)
        dx = dtc[..., None] * xc                       # (B,c,H,P)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, dx)
        # inter-chunk: y += exp(cum_i) * C_i . state_prev
        y_inter = jnp.einsum("bhpn,bin->bihp", state, cc) * jnp.exp(cum)[..., None]
        # state update
        decay_to_end = jnp.exp(total[:, None, :] - cum)          # (B,c,H)
        contrib = jnp.einsum("bihp,bin->bhpn", dx * decay_to_end[..., None], bc)
        state = jnp.exp(total)[..., None, None] * state + contrib
        return state, y_intra + y_inter

    state0 = (jnp.zeros((B, H, P, N), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))
    inputs = (xf.transpose(1, 0, 2, 3, 4), dtf.transpose(1, 0, 2, 3),
              bf.transpose(1, 0, 2, 3), cf.transpose(1, 0, 2, 3))
    final, ys = lax.scan(chunk_step, state0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    y = y + d[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), final
