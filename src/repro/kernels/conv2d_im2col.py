"""conv2d via im2col lowering — the paper's convolution strategy (ref [5]),
tiled for the MXU.

SystemML lowers convolution to an im2col patch matrix followed by a GEMM
(and its GPU backend calls CuDNN which does the same). The TPU adaptation:
each grid step stages one image's input block in VMEM, materializes the
(Ho*Wo x C*k*k) patch matrix *in VMEM only*, and multiplies against a
filter tile — the im2col intermediate never touches HBM, which is exactly
the "reuse temporary im2col intermediates" optimization the paper lists as
future work for its codegen.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, c, h, w, kernel, stride, ho, wo):
    x = x_ref[0]                           # (C, Hp, Wp) pre-padded
    # build the (Ho*Wo, C*k*k) patch matrix in VMEM via static slicing
    cols = []
    for ci in range(c):
        for ki in range(kernel):
            for kj in range(kernel):
                patch = jax.lax.slice(
                    x, (ci, ki, kj),
                    (ci + 1, ki + stride * ho, kj + stride * wo),
                    (1, stride, stride),
                )  # (1, ho, wo)
                cols.append(patch.reshape(ho * wo))
    patches = jnp.stack(cols, axis=1)      # (Ho*Wo, C*k*k)
    wmat = w_ref[...]                      # (C*k*k, bf)
    out = jnp.dot(patches, wmat, preferred_element_type=jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)     # (Ho*Wo, bf)


@functools.partial(jax.jit, static_argnames=("stride", "pad", "bf", "interpret"))
def conv2d_im2col(
    x: jnp.ndarray,    # (N, C, H, W)
    w: jnp.ndarray,    # (F, C, k, k)
    *,
    stride: int = 1,
    pad: int = 0,
    bf: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    n, c, h, wd = x.shape
    f, _, kernel, _ = w.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    hp, wp = h + 2 * pad, wd + 2 * pad
    ho = (hp - kernel) // stride + 1
    wo = (wp - kernel) // stride + 1
    bf = min(bf, f)
    fp = ((f + bf - 1) // bf) * bf
    wmat = w.reshape(f, c * kernel * kernel).T      # (C*k*k, F)
    if fp != f:
        wmat = jnp.pad(wmat, ((0, 0), (0, fp - f)))

    out = pl.pallas_call(
        functools.partial(
            _conv_kernel, c=c, h=hp, w=wp, kernel=kernel, stride=stride,
            ho=ho, wo=wo,
        ),
        grid=(n, fp // bf),
        in_specs=[
            pl.BlockSpec((1, c, hp, wp), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((c * kernel * kernel, bf), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, ho * wo, bf), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, ho * wo, fp), x.dtype),
        interpret=interpret,
    )(x, wmat)
    # (N, Ho*Wo, F) -> (N, F, Ho, Wo)
    return out[:, :, :f].transpose(0, 2, 1).reshape(n, f, ho, wo)
