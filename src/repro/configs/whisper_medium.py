"""whisper-medium [audio] — encoder-decoder transformer backbone.

24 decoder layers, d_model=1024, 16 heads (GQA kv=16 i.e. MHA), d_ff=4096,
vocab=51865. Conv/mel frontend is a STUB: ``input_specs`` supplies
precomputed 1500-frame encoder embeddings. [arXiv:2212.04356]
"""

from repro.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51865,
        encoder_layers=24,
        encoder_seq=1500,
        frontend="audio",
        tie_embeddings=True,
        citation="arXiv:2212.04356",
    )
