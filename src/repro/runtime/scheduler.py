"""Continuous-batching request scheduler on top of :class:`PlanServer`.

The plan cache (PR 1) made steady-state serving cheap *per request*; the
coalescing scheduler (PR 2) made it cheap *per token* by filling each shape
bucket's batch dimension with real requests. This revision makes batching
*token-level*: groups decode over rows of a shared
:class:`~repro.runtime.kv_cache.KVCachePool` arena, prefill hands each
row's populated cache straight to decode (no zero-cache restart), and —
with ``join_mid_decode`` — newly arrived same-bucket requests are absorbed
into the free rows of **in-flight** groups between decode steps, each row
carrying its own position (true continuous batching, the serving-side
analogue of SystemML's parfor batching argument).

Mechanics:

- :class:`RequestQueue` admits :class:`ServeRequest`\\ s asynchronously
  (each stamped with an arrival time) and coalesces compatible pending
  requests — same power-of-two bucket over ``context + new_tokens`` so a
  request's cache rows cover its whole decode — into a shared *group*.
- :class:`ContinuousBatchingScheduler` per tick: admit due arrivals, join
  pending requests into free rows of active groups (mid-decode, prefilled
  at their own position), prefill at most one newly coalesced group (plans
  from the shared :class:`~repro.core.plan_cache.PlanCache`), then advance
  every active group by one decode step. Groups only form when the cache
  pool can lease an arena — a budgeted pool backpressures new groups while
  joins keep absorbing work into rows that are already resident.
- Per-request queueing vs. execution latency, SLO attainment, join counts
  and pool occupancy land in
  :class:`~repro.runtime.metrics.SchedulerMetrics` / ``scheduler_summary``.

Arrivals are simulated against a virtual clock that never runs slower
than the real one: execution timing is measured, idle gaps between
arrivals are skipped instead of slept through.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import InputShape
from repro.core.plan_cache import BucketPolicy, CacheEntry, bucket_pow2
from repro.runtime.kv_cache import CacheArena
from repro.runtime.metrics import SchedulerMetrics
from repro.runtime.serve_loop import PlanServer, ServeRequest


@dataclass
class QueuedRequest:
    """One admitted request plus its lifecycle timestamps (virtual clock)."""

    rid: int
    req: ServeRequest
    arrival_s: float
    start_s: float = -1.0        # prefill began (group start or mid-decode join)
    finish_s: float = -1.0       # last requested token decoded

    @property
    def queue_s(self) -> float:
        return max(0.0, self.start_s - self.arrival_s)

    @property
    def exec_s(self) -> float:
        return max(0.0, self.finish_s - self.start_s)

    @property
    def total_s(self) -> float:
        return max(0.0, self.finish_s - self.arrival_s)


class RequestQueue:
    """FIFO admission with bucket-aware coalescing.

    Buckets are over ``context + new_tokens`` — the whole cache span a
    request occupies — so a context landing exactly on a power-of-two
    boundary still gets rows for every token it will generate.

    ``next_group`` is deliberately head-of-line fair: the *oldest* pending
    request picks the bucket, and only same-bucket requests may join its
    group (in arrival order, until the group's batch capacity is full). A
    popular bucket can therefore never starve an unpopular one — it just
    rides along whenever its own head reaches the front.
    """

    def __init__(self, policy: BucketPolicy = BucketPolicy(),
                 max_group_batch: int = 8):
        if max_group_batch < 1:
            raise ValueError("max_group_batch must be >= 1")
        self.policy = policy
        self.max_group_batch = max_group_batch
        self._pending: List[QueuedRequest] = []
        self._next_rid = 0

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def pending(self) -> Tuple[QueuedRequest, ...]:
        return tuple(self._pending)

    def seq_bucket(self, req: ServeRequest) -> int:
        return bucket_pow2(req.context + req.new_tokens, self.policy.min_seq)

    def admit(self, req: ServeRequest, arrival_s: float = 0.0) -> QueuedRequest:
        qr = QueuedRequest(rid=self._next_rid, req=req, arrival_s=arrival_s)
        self._next_rid += 1
        self._pending.append(qr)
        return qr

    def next_group(self) -> List[QueuedRequest]:
        """Pop the next coalesced group (empty list if nothing pending).

        The head-of-line request always joins (even if its batch alone
        exceeds ``max_group_batch`` — it must be served eventually); later
        same-bucket requests fill the remaining batch slots in FIFO order,
        skipping any too big for the space left.
        """
        if not self._pending:
            return []
        head = self._pending[0]
        sb = self.seq_bucket(head.req)
        group: List[QueuedRequest] = [head]
        used = head.req.batch
        for qr in self._pending[1:]:
            if self.seq_bucket(qr.req) != sb:
                continue
            if used + qr.req.batch > self.max_group_batch:
                continue
            group.append(qr)
            used += qr.req.batch
        for qr in group:
            self._pending.remove(qr)
        return group

    def requeue_front(self, members: Sequence[QueuedRequest]) -> None:
        """Return a popped group to the queue (pool refused the arena
        lease), merging by *arrival order* — not wholesale at the front.
        A refused group is its head plus same-bucket riders popped from
        deep in the queue; reinserting the riders ahead of older
        other-bucket requests would let them jump the line and silently
        break ``next_group``'s head-of-line fairness (``_pending[0]`` must
        stay the globally oldest pending request)."""
        self._pending = sorted(self._pending + list(members),
                               key=lambda qr: (qr.arrival_s, qr.rid))

    def take_joinable(self, seq_bucket: int, max_rows: int,
                      fits=None) -> List[QueuedRequest]:
        """Pop pending same-bucket requests that fit in ``max_rows`` free
        arena rows, strictly FIFO *within the bucket*: scanning stops at
        the first same-bucket request that does not fit, so later narrow
        arrivals can never leapfrog a wide head of their own bucket forever
        (the no-starvation guarantee extends to mid-decode joins).

        ``fits(qr)``: extra admission predicate (free cache pages, byte
        budget); it may track cumulative commitments across accepted
        candidates — it is called once per candidate, in scan order, and a
        False return stops the scan like an unfitting batch does."""
        taken: List[QueuedRequest] = []
        room = max_rows
        for qr in list(self._pending):
            if room <= 0:
                break
            if self.seq_bucket(qr.req) != seq_bucket:
                continue
            if qr.req.batch > room:
                break
            if fits is not None and not fits(qr):
                break
            taken.append(qr)
            room -= qr.req.batch
            self._pending.remove(qr)
        return taken


class _Clock:
    """Virtual clock: real elapsed time plus skipped idle gaps."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self._skew = 0.0

    def now(self) -> float:
        return time.perf_counter() - self._t0 + self._skew

    def advance_to(self, t: float) -> None:
        self._skew += max(0.0, t - self.now())


@dataclass
class _Member:
    """One request's tenancy inside a group: its arena rows, when it
    joined (in decode steps), and its prefill-produced first token."""

    qr: QueuedRequest
    rows: List[int]
    join_step: int
    first: Any                   # (batch, 1) — token #1, from prefill
    base_pos: int = 0            # decode start position (prompt len / 0)
    done: bool = False

    @property
    def req(self) -> ServeRequest:
        return self.qr.req


@dataclass
class _Group:
    """One decode batch in flight over a leased cache-pool arena. Rows sit
    at per-row positions, so members at different generation depths (and
    mid-decode joiners) share the one jitted decode step."""

    entry: CacheEntry                 # decode plan for the group's bucket
    arena: CacheArena
    context: int                      # max member span (stats naming)
    members: List[_Member]
    toks: Any                         # (batch_bucket, 1) next decode inputs
    pos: Any                          # (batch_bucket,) int32 per-row positions
    steps_done: int = 0
    peak_rows: int = 0                # max *concurrent* leased rows observed
    decoded: List[Any] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return all(m.done for m in self.members)

    @property
    def seq_bucket(self) -> int:
        return self.entry.key.seq_bucket

    @property
    def total_batch(self) -> int:
        return sum(m.req.batch for m in self.members)


class ContinuousBatchingScheduler:
    """Drives a :class:`PlanServer` with coalesced groups instead of
    one-request-at-a-time ``handle`` calls.

    Both plan families come from the server's single :class:`PlanCache`:
    ``kind="prefill"`` entries for the batched prompt pass (which now also
    returns the populated cache rows), ``kind="decode"`` entries for the
    shared-arena generation steps. ``join_mid_decode`` turns on token-level
    continuous batching: pending same-bucket requests are prefilled and
    written into free rows of in-flight groups between decode steps.
    """

    def __init__(
        self,
        server: PlanServer,
        *,
        max_group_batch: int = 8,
        slo_ms: float = 0.0,
        queue: Optional[RequestQueue] = None,
        join_mid_decode: bool = True,
    ):
        self.server = server
        self.queue = queue or RequestQueue(server.policy, max_group_batch)
        self.metrics = SchedulerMetrics(slo_s=slo_ms / 1e3)
        self.join_mid_decode = join_mid_decode
        self.active: List[_Group] = []
        self.results: List[Dict[str, Any]] = []
        # requests already counted in pages_denied — the join predicate runs
        # every tick, and a retried candidate must not re-count as a denial
        self._page_denied_rids: set = set()

    # -- member lifecycle --------------------------------------------------
    def _alloc_rows_checked(self, arena, qr: QueuedRequest,
                            where: str) -> List[int]:
        """Lease a member's arena rows; a ``None`` return means the
        admission accounting upstream (free-row check, join predicate) is
        out of sync with the arena — fail loudly with context instead of
        letting a ``TypeError`` surface deep inside ``_admit_members``."""
        rows = self.server.pool.alloc_rows(arena, qr.req.batch)
        if rows is None:
            raise RuntimeError(
                f"KV pool row invariant violated in {where}: request "
                f"rid={qr.rid} needs {qr.req.batch} rows but arena "
                f"{arena.batch}x{arena.seq} has only {arena.rows_free} free "
                f"({arena.rows_used} leased)")
        return rows

    def _admit_members(self, group: _Group, queued: List[QueuedRequest],
                       rows_per_member: List[List[int]], join_step: int,
                       now: float) -> List[_Member]:
        """Prefill ``queued`` as one batch, write their populated cache
        rows into the group's arena, and seat them at their own positions.
        Used both at group start (join_step 0) and for mid-decode joins."""
        srv = self.server
        handoff = srv.model.supports_handoff
        total_batch = sum(qr.req.batch for qr in queued)
        span = max(srv.request_span(qr.req) for qr in queued)
        rows_flat = [r for rows in rows_per_member for r in rows]

        # commit pages before the handoff scatter lands on them: each row
        # leases its prompt-covering pages now and reserves its span
        for qr, rows in zip(queued, rows_per_member):
            for r in rows:
                srv.pool.admit_row(group.arena, r,
                                   prompt=qr.req.context if handoff else 0,
                                   span=srv.request_span(qr.req))

        lengths_rows = []
        for qr in queued:
            qr.start_s = now
            # once admitted (group start or join), a page denial is history
            self._page_denied_rids.discard(qr.rid)
            lengths_rows += [qr.req.context] * qr.req.batch
        entry = srv.prefill_entry(total_batch, span)
        pb = entry.key.batch_bucket
        lengths = jnp.asarray(
            lengths_rows + [1] * (pb - len(lengths_rows)), jnp.int32)
        logits, pkv = srv.run_prefill(entry, lengths=lengths)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        if pkv is not None:
            srv.pool.write_rows(group.arena, rows_flat, pkv,
                                src_rows=range(len(rows_flat)))
            pos_rows = lengths_rows
        else:  # no handoff for this family: rows decode from zero state —
            # clear any state a prior tenant of these rows/pages left behind
            # (mid-decode joiners can inherit rows a completed member freed)
            if join_step > 0:
                srv.pool.zero_rows(group.arena, rows_flat)
            pos_rows = [0] * len(rows_flat)
        rows_a = jnp.asarray(rows_flat, jnp.int32)
        group.pos = group.pos.at[rows_a].set(jnp.asarray(pos_rows, jnp.int32))
        group.toks = group.toks.at[rows_a].set(first[: len(rows_flat)])

        members = []
        group.peak_rows = max(group.peak_rows, group.arena.rows_used)
        row_i = 0
        for qr, rows in zip(queued, rows_per_member):
            m = _Member(qr=qr, rows=rows, join_step=join_step,
                        first=first[row_i: row_i + qr.req.batch],
                        base_pos=qr.req.context if (handoff and pkv is not None)
                        else 0)
            row_i += qr.req.batch
            members.append(m)
            group.members.append(m)
            # the prefill token already is token #1: a 1-token request
            # completes at admission, before any decode step
            if qr.req.new_tokens <= 1:
                self._complete(m, group, now)
        return members

    def _start_group(self, queued: List[QueuedRequest],
                     now: float) -> Optional[_Group]:
        srv = self.server
        handoff = srv.model.supports_handoff
        total_batch = sum(qr.req.batch for qr in queued)
        span = max(srv.request_span(qr.req) for qr in queued)
        entry = srv.decode_entry(total_batch, span)
        b, s = entry.key.batch_bucket, entry.key.seq_bucket
        # page-exact admission demand: what this group's members commit
        # (rows + span pages), not the arena's bucket-shaped capacity
        demand = sum(srv.pool.member_bytes(s, qr.req.batch,
                                           srv.request_span(qr.req))
                     for qr in queued) if srv.pool.paged else None
        # the pool is the single owner of cache construction; force the
        # lease when nothing is in flight so progress is always possible.
        # A recycled arena may hold a previous tenant's K/V and recurrent
        # state: families without a prefill handoff decode from what they
        # assume is a zero cache, so their lease must be zeroed (the
        # handoff write overwrites admitted rows wholesale — no zero needed)
        arena = srv.pool.acquire(b, s, zero=not handoff,
                                 force=not self.active,
                                 demand_bytes=demand)
        if arena is None:
            return None
        group = _Group(
            entry=entry, arena=arena,
            context=max(qr.req.context for qr in queued),
            members=[],
            toks=jnp.ones((b, 1), jnp.int32),
            pos=jnp.zeros((b,), jnp.int32),
        )
        rows_per_member = [
            self._alloc_rows_checked(arena, qr, "_start_group")
            for qr in queued]
        self._admit_members(group, queued, rows_per_member, 0, now)
        self.metrics.observe_group([qr.req.batch for qr in queued], b)
        return group

    def _try_joins(self, group: _Group, clock: _Clock) -> None:
        """Absorb pending same-bucket requests into the group's free arena
        rows — and free cache *pages*, which is the real admission unit on
        a paged pool — prefilled at their own positions (token-level
        continuous batching). Joiners skip the line only for capacity the
        head-of-line request could not use anyway — its own group still
        forms through ``next_group`` as soon as the pool can lease an
        arena."""
        srv = self.server
        arena = group.arena
        free = arena.rows_free
        if not free:
            return
        fits = None
        if srv.pool.paged:
            state = {"pages": arena.allocator.available if arena.n_pages
                     else None,
                     "bytes": srv.pool.bytes_room()}

            def fits(qr):
                span = srv.request_span(qr.req)
                pages = arena.span_pages(span) * qr.req.batch
                nbytes = srv.pool.member_bytes(arena.seq, qr.req.batch, span)
                if (state["pages"] is not None and pages > state["pages"]) \
                        or nbytes > state["bytes"]:
                    # count each backpressured *request* once, not once per
                    # tick it stays refused
                    if qr.rid not in self._page_denied_rids:
                        self._page_denied_rids.add(qr.rid)
                        srv.pool.metrics.pages_denied += 1
                    return False
                if state["pages"] is not None:
                    state["pages"] -= pages
                state["bytes"] -= nbytes
                self._page_denied_rids.discard(qr.rid)
                return True

        queued = self.queue.take_joinable(group.seq_bucket, free, fits=fits)
        if not queued:
            return
        rows_per_member = [
            self._alloc_rows_checked(arena, qr, "_try_joins")
            for qr in queued]
        members = self._admit_members(group, queued, rows_per_member,
                                      group.steps_done, clock.now())
        self.metrics.observe_joins([m.req.batch for m in members])

    def _decode_tick(self, group: _Group, clock: _Clock) -> None:
        srv = self.server
        if srv.pool.paged:
            # grant the page covering each live row's next write position
            # (on-demand paging: drawn from the admission-time reservation,
            # so this can never fail mid-decode)
            for m in group.members:
                if not m.done:
                    wpos = m.base_pos + (group.steps_done - m.join_step)
                    srv.pool.ensure_decode_slots(group.arena, m.rows, wpos)
            logits, group.arena.cache = group.entry.step_fn(
                srv.params, group.arena.cache, group.toks, group.pos,
                group.arena.tables)
        else:
            logits, group.arena.cache = group.entry.step_fn(
                srv.params, group.arena.cache, group.toks, group.pos)
        group.toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        jax.block_until_ready(group.toks)
        group.decoded.append(group.toks)
        group.pos = group.pos + 1
        group.steps_done += 1
        now = clock.now()
        for m in group.members:
            # the prefill token is token #1, so a member needs
            # new_tokens - 1 decode steps after its join
            if not m.done and (group.steps_done - m.join_step
                               >= m.req.new_tokens - 1):
                self._complete(m, group, now)

    def _complete(self, m: _Member, group: _Group, now: float) -> None:
        m.done = True
        m.qr.finish_s = now
        self.metrics.observe_request(m.qr.queue_s, m.qr.exec_s)
        rows = jnp.asarray(m.rows, jnp.int32)
        steps = group.decoded[m.join_step: m.join_step + m.req.new_tokens - 1]
        toks = jnp.concatenate(
            [m.first] + [jnp.take(t, rows, axis=0) for t in steps], axis=1)
        self.results.append({
            "rid": m.qr.rid,
            "batch": m.req.batch,
            "context": m.req.context,
            "bucket": (group.entry.key.batch_bucket,
                       group.entry.key.seq_bucket),
            "group_size": len(group.members),
            "joined_at_step": m.join_step,
            "tokens": toks,
            "queue_s": m.qr.queue_s,
            "exec_s": m.qr.exec_s,
            "total_s": m.qr.total_s,
        })
        # freed rows become mid-decode join capacity immediately
        self.server.pool.free_rows(group.arena, m.rows)

    def _retire_group(self, group: _Group) -> None:
        """Observed runtime statistics — including the cache pool's live
        bytes — feed dynamic recompilation exactly as in the sequential
        path; then the arena goes back to the pool for reuse."""
        srv = self.server
        # the observed batch is the peak *concurrent* row usage — members
        # joining rows another member freed never widened the batch
        shape = InputShape(
            f"group_{group.peak_rows}x{group.context}",
            group.seq_bucket, group.peak_rows, "decode")
        stats = srv.observed_stats(group.entry, shape, group.toks)
        srv.observe(group.entry.key, stats)
        srv.pool.release(group.arena)

    # -- main loop ---------------------------------------------------------
    def run(self, arrivals: Iterable[Tuple[float, ServeRequest]]
            ) -> List[Dict[str, Any]]:
        """Serve a stream of ``(arrival_s, request)`` pairs to completion.

        Returns one record per request (completion order). Tick structure:
        admit due arrivals → join pending requests into free rows of active
        groups (mid-decode) → coalesce + prefill at most one new group
        (pool permitting) → one decode step for every active group.
        """
        todo = sorted(arrivals, key=lambda a: a[0])
        clock = _Clock()
        idx = 0
        while idx < len(todo) or len(self.queue) or self.active:
            now = clock.now()
            while idx < len(todo) and todo[idx][0] <= now:
                self.queue.admit(todo[idx][1], todo[idx][0])
                self.metrics.admitted += 1
                idx += 1
            if not self.active and not len(self.queue):
                # idle: skip ahead to the next arrival instead of sleeping
                clock.advance_to(todo[idx][0])
                continue
            if self.join_mid_decode:
                for group in self.active:
                    self._try_joins(group, clock)
            if len(self.queue):
                members = self.queue.next_group()
                if members:
                    group = self._start_group(members, clock.now())
                    if group is None:
                        # pool budget exhausted: requests wait (or join)
                        self.queue.requeue_front(members)
                    else:
                        self.active.append(group)
            self.metrics.observe_resident(
                sum(1 for g in self.active for m in g.members if not m.done))
            for group in list(self.active):
                if not group.done:
                    self._decode_tick(group, clock)
                if group.done:
                    self._retire_group(group)
                    self.active.remove(group)
        return self.results

    def summary(self) -> str:
        from repro.runtime.metrics import scheduler_summary
        # the scheduler's own total latency, not server.latency — handle()
        # is never called on this path, so the server accumulator is empty
        return scheduler_summary(self.metrics, self.server.metrics,
                                 self.metrics.total_latency,
                                 pool=self.server.pool)


def simulate_arrivals(
    requests: Sequence[ServeRequest],
    rate_per_s: float = 0.0,
    seed: int = 0,
) -> List[Tuple[float, ServeRequest]]:
    """Stamp requests with Poisson-process arrival times at ``rate_per_s``
    (exponential inter-arrival gaps, seeded). ``rate_per_s <= 0`` means a
    closed burst: everything arrives at t=0 (maximal coalescing pressure).
    """
    import random

    if rate_per_s <= 0:
        return [(0.0, r) for r in requests]
    rng = random.Random(seed)
    t = 0.0
    out = []
    for r in requests:
        t += rng.expovariate(rate_per_s)
        out.append((t, r))
    return out
