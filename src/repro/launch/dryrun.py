import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) combination: compile the
planner-chosen execution plan via ``jax.jit(...).lower(...).compile()`` on
the production mesh built from 512 placeholder host devices, then extract

  * ``compiled.memory_analysis()``  — proves the plan fits / how close
  * ``compiled.cost_analysis()``    — XLA's raw (loop-body-once) numbers
  * call-graph-weighted HLO cost    — flops / HBM bytes / collective bytes
                                      per chip per step (launch.hlo_analysis)

and writes one JSON record per combo under ``experiments/dryrun/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--jobs N]
  PYTHONPATH=src python -m repro.launch.dryrun --arch X --shape Y \
      --force-strategy data_parallel        # paper-faithful baseline
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (INPUT_SHAPES, TPU_V5E, InputShape, MeshConfig,
                          ModelConfig, TrainConfig)
from repro.configs import ARCH_IDS, get_config
from repro.core.cost import model_flops_per_step, roofline_terms
from repro.core.planner import compile_plan
from repro.core.sharding import spec_for, tree_specs
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh, mesh_cfg_for
from repro.models.model import build_model
from repro.runtime.serve_loop import cache_shardings, make_decode_step, make_prefill
from repro.runtime.train_loop import (make_train_step, opt_state_specs,
                                      train_shardings, batch_specs)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def batch_input_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16):
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        return specs
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if shape.kind == "train":
        specs["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.frontend == "vision":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_frontend_tokens, cfg.d_model), dtype)
    if cfg.is_encdec:
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dtype)
    return specs


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                force_strategy: Optional[str] = None,
                train_cfg: TrainConfig = TrainConfig(),
                plan_override=None):
    """Lower + compile one combination; returns (record, compiled, plan)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_cfg = mesh_cfg_for(multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    if force_strategy:
        train_cfg = dataclasses.replace(train_cfg, force_strategy=force_strategy)
    plan = plan_override or compile_plan(cfg, shape, mesh_cfg, train_cfg)
    model = build_model(cfg, dtype=jnp.bfloat16)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            lowered = _lower_train(model, plan, mesh, mesh_cfg, shape, train_cfg)
        elif shape.kind == "prefill":
            lowered = _lower_prefill(model, plan, mesh, mesh_cfg, shape)
        else:
            lowered = _lower_decode(model, plan, mesh, mesh_cfg, shape)
        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = hlo_analysis.analyze(compiled.as_text())
    chips = mesh_cfg.num_devices
    mf = model_flops_per_step(cfg, shape)
    terms = roofline_terms(hlo.flops, hlo.hbm_bytes, hlo.collective_bytes,
                           chips, TPU_V5E, model_flops=mf, per_chip=True)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh_cfg.shape),
        "multi_pod": multi_pod,
        "strategy": plan.config.strategy.value,
        "plan_notes": list(plan.config.notes),
        "plan": {
            "batch_axes": list(plan.config.batch_axes),
            "seq_axes": list(plan.config.seq_axes),
            "tensor_parallel": plan.config.tensor_parallel,
            "params_over_data": plan.config.params_over_data,
            "expert_parallel": plan.config.expert_parallel,
            "opt_state_dtype": plan.config.opt_state_dtype,
            "microbatches": plan.config.microbatches,
            "seq_shard_checkpoints": plan.config.seq_shard_checkpoints,
            "attention_variant": plan.config.attention_variant,
            "cache_batch_axes": list(plan.config.cache_batch_axes),
            "cache_heads_over_model": plan.config.cache_heads_over_model,
            "cache_seq_axes": list(plan.config.cache_seq_axes),
        },
        "compile_seconds": compile_s,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
            "hbm_budget": TPU_V5E.hbm_bytes,
        },
        "xla_cost_analysis": {k: float(v) for k, v in ca.items()
                              if isinstance(v, (int, float))
                              and ("flops" in k or "bytes accessed" == k)},
        "hlo_cost": hlo.to_dict(),
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "model_flops_global": mf,
            "model_flops_per_chip": mf / chips,
            "useful_flops_ratio": (mf / chips) / hlo.flops if hlo.flops else 0.0,
            "step_time_lower_bound_s": terms.step_time_s,
        },
        "planner_estimate": dict(plan.memory.per_device),
        "planner_cost": {
            "compute_s": plan.cost.compute_s,
            "memory_s": plan.cost.memory_s,
            "collective_s": plan.cost.collective_s,
        },
    }
    return record, compiled, plan


def _scalar_shard(mesh):
    return NamedSharding(mesh, P())


def _lower_train(model, plan, mesh, mesh_cfg, shape, train_cfg):
    (pspecs, _, pshard), (ospecs, _, oshard) = train_shardings(
        model, plan.config, mesh_cfg, train_cfg, mesh)
    bspecs = batch_input_specs(model.cfg, shape, model.dtype)
    bparts = batch_specs(bspecs, plan.config, mesh_cfg)
    bshard = {k: NamedSharding(mesh, v) for k, v in bparts.items()}
    step_fn = make_train_step(model, plan.config, mesh_cfg, train_cfg)
    metric_shard = {"xent": _scalar_shard(mesh), "aux": _scalar_shard(mesh),
                    "loss": _scalar_shard(mesh), "grad_norm": _scalar_shard(mesh)}
    jitted = jax.jit(
        step_fn,
        in_shardings=(pshard, oshard, bshard, _scalar_shard(mesh)),
        out_shardings=(pshard, oshard, metric_shard),
        donate_argnums=(0, 1),
    )
    step_spec = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted.lower(pspecs, ospecs, bspecs, step_spec)


def _lower_prefill(model, plan, mesh, mesh_cfg, shape):
    pspecs = model.param_specs()
    pparts = tree_specs(pspecs, model.param_axes(), plan.config, mesh_cfg, "param")
    pshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pparts,
                          is_leaf=lambda x: isinstance(x, P))
    bspecs = batch_input_specs(model.cfg, shape, model.dtype)
    bparts = batch_specs(bspecs, plan.config, mesh_cfg)
    bshard = {k: NamedSharding(mesh, v) for k, v in bparts.items()}
    fn = make_prefill(model, plan.config, mesh_cfg)
    jitted = jax.jit(fn, in_shardings=(pshard, bshard))
    return jitted.lower(pspecs, bspecs)


def _lower_decode(model, plan, mesh, mesh_cfg, shape):
    pspecs = model.param_specs()
    pparts = tree_specs(pspecs, model.param_axes(), plan.config, mesh_cfg, "param")
    pshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pparts,
                          is_leaf=lambda x: isinstance(x, P))
    cspecs, _, cshard = cache_shardings(
        model, shape.global_batch, shape.seq_len, plan.config, mesh_cfg, mesh)
    tspec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tshard = NamedSharding(
        mesh, spec_for((shape.global_batch, 1), ("batch", None),
                       plan.config, mesh_cfg, "act"))
    fn = make_decode_step(model, plan.config, mesh_cfg)
    jitted = jax.jit(
        fn,
        in_shardings=(pshard, cshard, tshard, _scalar_shard(mesh)),
        out_shardings=(None, cshard),
        donate_argnums=(1,),
    )
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted.lower(pspecs, cspecs, tspec, pos_spec)


# ---------------------------------------------------------------------------


def run_one(arch, shape_name, multi_pod, force_strategy=None, out_dir=OUT_DIR):
    tag = f"{arch}_{shape_name}_{'2pod' if multi_pod else '1pod'}"
    if force_strategy:
        tag += f"_{force_strategy}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, tag + ".json")
    try:
        record, compiled, plan = lower_combo(
            arch, shape_name, multi_pod=multi_pod,
            force_strategy=force_strategy)
        record["ok"] = True
    except Exception as e:  # noqa: BLE001 — recorded as a dry-run failure
        record = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                  "ok": False, "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    status = "OK " if record.get("ok") else "FAIL"
    peak = record.get("memory", {}).get("peak_estimate_bytes", 0) / 2**30
    dom = record.get("roofline", {}).get("dominant", "?")
    print(f"[{status}] {tag:60s} peak={peak:7.2f}GiB dominant={dom} "
          f"strategy={record.get('strategy', '?')}", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["all"], default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES) + ["all"], default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all archs, shapes, meshes")
    ap.add_argument("--force-strategy", default=None)
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch in (None, "all")) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape == "all") else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]

    failures = 0
    for mp in meshes:
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch}_{shape_name}_{'2pod' if mp else '1pod'}"
                if args.force_strategy:
                    tag += f"_{args.force_strategy}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            print(f"[SKIP] {tag}", flush=True)
                            continue
                rec = run_one(arch, shape_name, mp, args.force_strategy, args.out)
                failures += 0 if rec.get("ok") else 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
