"""Tiled matmul Pallas kernel (MXU-aligned, fp32 VMEM accumulator).

The paper's "Native BLAS Exploitation" / "GPU Backend" point: compute-bound
ops (matmul, conv) dispatch to tuned kernels. This is the TPU-native tuned
kernel: (bm x bk) @ (bk x bn) tiles staged through VMEM, accumulated in a
float32 scratch register tile, written back once per (i, j) block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile sizes (128 is the v5e systolic edge).
BM, BN, BK = 128, 128, 128


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = BM,
    bn: int = BN,
    bk: int = BK,
    interpret: bool = False,
) -> jnp.ndarray:
    """(M, K) @ (K, N); M, N, K need not be tile-aligned (padded)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, _rup(m)), min(bn, _rup(n)), min(bk, _rup(k))
    mp, np_, kp = _pad(m, bm), _pad(n, bn), _pad(k, bk)
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu_scratch(bm, bn)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


def _rup(x: int, mult: int = 8) -> int:
    return max(mult, ((x + mult - 1) // mult) * mult)


def _pad(x: int, b: int) -> int:
    return ((x + b - 1) // b) * b


def pltpu_scratch(bm, bn):
    # deferred: pallas.tpu only resolves on TPU-capable installs
    from jax.experimental.pallas import tpu as pltpu  # lint: allow-local-import

    return pltpu.VMEM((bm, bn), jnp.float32)
