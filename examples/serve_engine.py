"""ServingEngine example: the one request-lifecycle API, driven directly.

Shows the three scenarios the old batch API could not express:

1. **online submission** — requests enter a *live* engine at any time
   (no pre-sorted arrival trace); late arrivals join in-flight groups
   mid-decode;
2. **token streaming** — per-token events as they are produced, instead
   of whole outputs at completion (time-to-first-token is real);
3. **early termination** — cancellation and EOS stop conditions free a
   request's cache rows/pages the same tick, making room for others.

    PYTHONPATH=src python examples/serve_engine.py --arch yi-6b-smoke
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.configs import get_config
from repro.runtime.engine_config import EngineConfig
from repro.runtime.serve_loop import ServeRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b-smoke")
    args = ap.parse_args()

    cfg = EngineConfig(cache_capacity=16)
    srv = cfg.build_server(get_config(args.arch))
    eng = cfg.build_engine(srv)

    # --- 1. online submission: no trace, just submit into the live engine
    a = eng.submit(ServeRequest(batch=5, context=100, new_tokens=12))
    eng.step()                                     # a's group is in flight
    b = eng.submit(ServeRequest(batch=1, context=90, new_tokens=4))
    # b arrived mid-decode; the engine seats it in a free row of a's group

    # --- 2. streaming: consume b's tokens as they are produced
    print("b streams:", end=" ")
    for ev in b.stream():
        if ev.token is not None:
            print(int(ev.token[0, 0]), end=" ", flush=True)
        else:
            print(f"<{ev.finish_reason}>")
    print(f"b joined a's group at decode step "
          f"{b.result['joined_at_step']}")

    # --- 3. early termination: the client for `a` hangs up
    eng.cancel(a)
    print(f"a cancelled after {a.result['tokens'].shape[1]} tokens; "
          f"pool reclaimed {srv.pool.metrics.pages_reclaimed} pages")

    # an EOS-stopped request: ends at its first end-of-sequence token
    c = eng.submit(ServeRequest(batch=1, context=60, new_tokens=32,
                                eos_id=450))
    eng.drain()
    print(f"c finished '{c.result['finish_reason']}' with "
          f"{c.result['tokens'].shape[1]}/32 tokens")

    print(eng.summary())


if __name__ == "__main__":
    main()
