"""Checkpoint roundtrip + data pipeline determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.config import InputShape
from repro.configs import get_config
from repro.data import SyntheticLM, TokenDatasetSpec, make_batch
from repro.models.model import build_model


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("yi-6b-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path / "ck"), params, step=7)
    restored, step = load_checkpoint(str(tmp_path / "ck"), params)
    assert step == 7
    for k in params:
        np.testing.assert_array_equal(restored[k], params[k])


def test_checkpoint_nested_structures(tmp_path):
    tree = {"layers": {"w": jnp.ones((3, 3))}, "opt": (jnp.zeros(2), jnp.ones(2))}
    save_checkpoint(str(tmp_path / "ck"), tree, step=1)
    restored, _ = load_checkpoint(str(tmp_path / "ck"), tree)
    np.testing.assert_array_equal(restored["opt"][1], tree["opt"][1])
    assert isinstance(restored["opt"], tuple)


def test_checkpoint_mismatch_raises(tmp_path):
    tree = {"a": jnp.ones(3)}
    save_checkpoint(str(tmp_path / "ck"), tree)
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path / "ck"), {"b": jnp.ones(3)})
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path / "ck"), {"a": jnp.ones(4)})


def test_synthetic_lm_deterministic_and_learnable():
    spec = TokenDatasetSpec(vocab_size=97, seq_len=16, global_batch=8, seed=3)
    a = SyntheticLM(spec).batch(0)
    b = SyntheticLM(spec).batch(0)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # targets are mostly the deterministic markov successor
    pred = (a["tokens"] * 31 + SyntheticLM(spec)._shift) % 97
    agree = float(np.mean(pred == a["targets"]))
    assert agree > 0.7, agree


def test_make_batch_shapes_per_family():
    shape = InputShape("t", 16, 2, "train")
    for arch in ("whisper-medium", "internvl2-2b", "yi-6b"):
        cfg = get_config(arch + "-smoke")
        b = make_batch(cfg, shape, dtype=jnp.float32)
        assert b["tokens"].shape == (2, 16)
        if cfg.is_encdec:
            assert b["frames"].shape == (2, cfg.encoder_seq, cfg.d_model)
        if cfg.frontend == "vision":
            assert b["patch_embeds"].shape == (2, cfg.num_frontend_tokens, cfg.d_model)
