"""Paper claim (§1/§3): the cost-based compiler automatically generates
hybrid execution plans from data + cluster characteristics. Benchmark: the
plan chosen per (arch x shape) and the compiler's own latency."""

from __future__ import annotations

import argparse
import time

from repro.config import INPUT_SHAPES, SINGLE_POD_MESH
from repro.configs import ARCH_IDS, get_config
from repro.core.planner import compile_plan


def run(smoke: bool = False):
    archs = ARCH_IDS[:2] if smoke else ARCH_IDS
    rows = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            t0 = time.perf_counter()
            plan = compile_plan(cfg, shape, SINGLE_POD_MESH)
            us = (time.perf_counter() - t0) * 1e6
            c = plan.config
            rows.append(
                f"plan_{arch}_{shape.name},{us:.0f},"
                f"strategy={c.strategy.value};micro={c.microbatches};"
                f"opt_dtype={c.opt_state_dtype};"
                f"est_gib={plan.memory.total / 2**30:.2f};"
                f"fits={plan.memory.fits()}"
            )
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="first two archs only (CI bench-smoke job)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke):
        print(row, flush=True)
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
