"""Quickstart — the paper's §2 worked example, end to end.

A softmax classifier declared Keras-style, compiled through Keras2Plan
(the Keras2DML analogue): generates the DML script, trains with minibatch
SGD, and scores with the parfor ``test_algo="allreduce"`` plan.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data import SyntheticClassification
from repro.frontend import Keras2Plan


def main():
    # --- data (NumPy in, like the paper's fit(X, Y)) ----------------------
    data = SyntheticClassification(num_features=50, num_classes=10, seed=0)
    x_train, y_train = data.batch(4096)
    x_test, y_test = data.batch(512, step=1)

    # --- declare the model (Keras Sequential analogue) --------------------
    spec = [
        {"kind": "affine", "units": 10},
        {"kind": "softmax"},
    ]
    meta = {"input_shape": (50,), "num_classes": 10}

    model = Keras2Plan(spec, meta, optimizer="sgd", lr=0.5, batch_size=32,
                       epochs=2, train_algo="minibatch",
                       test_algo="allreduce")

    print("=== generated DML script (paper §2) ===")
    print(model.dml_script)
    print()

    # --- train -------------------------------------------------------------
    model.fit(x_train, y_train)
    print(f"loss: {model.history[0]:.3f} -> {model.history[-1]:.3f} "
          f"({len(model.history)} minibatch steps)")
    print(f"input format decision: X stored {model.format_decisions['X']}")

    # --- score -------------------------------------------------------------
    acc = model.score(x_test, y_test)
    print(f"test accuracy: {acc:.3f}")
    assert acc > 0.8, "quickstart should reach >80% accuracy"
    print("OK")


if __name__ == "__main__":
    main()
