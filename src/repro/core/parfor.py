"""Task-parallel ``parfor`` (paper §3, "Distributed Operations").

SystemML: "for scoring using a compute-intensive deep network ... it is often
better to use the task-parallel loop construct — parfor — with a small
batch_size ... The parfor optimizer then automatically creates optimal
parallel execution plans that exploit multi-core, multi-GPU, and cluster
parallelism ... compiles a row-partitioned remote-parfor plan ... that avoids
shuffling and scales linearly."

TPU adaptation:

* *remote parfor*  -> ``shard_map`` over the data axes with a
  **collective-free body** (the "avoids shuffling" property — asserted in
  tests by grepping the lowered HLO for collectives).
* *local parfor*   -> ``jax.vmap`` / batched execution on one device.
* the *parfor optimizer* -> :func:`choose_parfor_plan`, which picks
  local vs remote from data size and mesh size, like SystemML's optimizer
  picks local vs remote workers.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

# Below this many rows per device, distributing is not worth it (SystemML's
# local-parfor decision for small task sets).
MIN_ROWS_PER_WORKER = 1


def choose_parfor_plan(num_rows: int, mesh: Optional[Mesh]) -> str:
    if mesh is None or len(mesh.devices.flatten()) == 1:
        return "local"
    workers = _data_size(mesh)
    if num_rows < workers * MIN_ROWS_PER_WORKER or num_rows % workers != 0:
        return "local"
    return "remote"


def _data_size(mesh: Mesh) -> int:
    n = 1
    for ax in mesh.axis_names:
        if ax in ("pod", "data"):
            n *= mesh.shape[ax]
    return n


def parfor(
    body: Callable,
    rows: jnp.ndarray,
    *,
    mesh: Optional[Mesh] = None,
    reduce: Optional[str] = None,
):
    """Row-partitioned task-parallel map: ``body`` maps a row batch -> output
    batch. ``reduce``: None (stack results) | "sum" | "mean" — the
    ``test_algo="allreduce"`` aggregation.
    """
    plan = choose_parfor_plan(rows.shape[0], mesh)
    if plan == "local":
        out = body(rows)
        return _reduce_local(out, reduce), plan

    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    in_spec = P(daxes)
    if reduce is None:
        out_spec = P(daxes)

        def shard_body(x):
            return body(x)

    else:
        out_spec = P()

        def shard_body(x):
            o = body(x)
            # one final all-reduce of the per-worker aggregate — the only
            # collective in the whole parfor plan (the paper's "allreduce")
            s = jnp.sum(o, axis=0)
            for ax in daxes:
                s = jax.lax.psum(s, ax)
            if reduce == "mean":
                s = s / rows.shape[0]
            return s

    fn = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(in_spec,),
        out_specs=out_spec,
        check_rep=False,
    )
    return fn(rows), plan


def _reduce_local(out, reduce):
    if reduce == "sum":
        return jnp.sum(out, axis=0)
    if reduce == "mean":
        return jnp.mean(out, axis=0)
    return out


def count_collectives(hlo_text: str) -> int:
    """Number of collective ops in an HLO dump (test helper for the
    "avoids shuffling" claim)."""
    keys = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute")
    return sum(hlo_text.count(k) for k in keys)
