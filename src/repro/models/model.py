"""Model assembly: config -> init/apply/loss/decode_step for every family.

Uniform-stack families (dense, moe, ssm, vlm, audio enc+dec) scan over a
layer-stacked param tree (compact HLO, required for the 126-layer dry-runs);
the hybrid family (recurrentgemma's interleaved RG-LRU/attention pattern)
unrolls its 26 layers.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models import blocks as B
from repro.models.common import NULL_CTX, ShardCtx, SpecBuilder, rms_norm, softmax_xent_logits

MOE_AUX_COEF = 0.01


def _stack(entries: Dict, n: int, prefix: str, sb: SpecBuilder):
    for name, (shape, axes, init) in entries.items():
        sb.add(f"{prefix}{name}", (n, *shape), ("layers", *axes), init)


def _subtree(params: Dict, prefix: str) -> Dict:
    plen = len(prefix)
    return {k[plen:]: v for k, v in params.items() if k.startswith(prefix)}


class Model:
    def __init__(self, cfg: ModelConfig, dtype=jnp.bfloat16):
        self.cfg = cfg
        self.dtype = dtype
        self.sb = self._build_specs()

    # ------------------------------------------------------------------
    # parameter specs
    # ------------------------------------------------------------------
    def _build_specs(self) -> SpecBuilder:
        cfg = self.cfg
        sb = SpecBuilder(self.dtype)
        sb.add("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
               "normal", scale=0.02)
        if cfg.family == "hybrid":
            pat = cfg.layer_pattern()
            n_r, n_a = pat.count("r"), pat.count("a")
            _stack(B.rglru_block_params(cfg), n_r, "r.", sb)
            _stack(B.attn_block_params(cfg), n_a, "a.", sb)
        elif cfg.family == "ssm":
            _stack(B.ssd_block_params(cfg), cfg.num_layers, "l.", sb)
        elif cfg.is_encdec:
            _stack(B.attn_block_params(cfg), cfg.encoder_layers, "e.", sb)
            _stack(B.attn_block_params(cfg, cross=True), cfg.num_layers, "d.", sb)
        else:
            _stack(B.attn_block_params(cfg), cfg.num_layers, "l.", sb)
        sb.add("final_ln", (cfg.d_model,), (None,), "ones")
        if not cfg.tie_embeddings:
            sb.add("head", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                   "normal", scale=0.02)
        return sb

    def param_specs(self):
        return self.sb.specs()

    def param_axes(self):
        return self.sb.axes()

    def init_params(self, key):
        return self.sb.init(key)

    def param_count(self) -> int:
        return sum(math.prod(s.shape) for s in self.param_specs().values())

    # ------------------------------------------------------------------
    # embedding / head
    # ------------------------------------------------------------------
    def _embed(self, params, tokens):
        x = params["embed"][tokens]
        if self.cfg.tie_embeddings:
            # tied table serves both roles; sqrt(d) output scaling (gemma/
            # whisper convention) keeps logit and embedding scales sane
            x = x * (self.cfg.d_model ** 0.5)
        return x

    def _logits(self, params, x):
        if self.cfg.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", x, params["embed"])
        return jnp.einsum("bsd,dv->bsv", x, params["head"])

    # ------------------------------------------------------------------
    # full-sequence forward (train / prefill)
    # ------------------------------------------------------------------
    def apply(self, params, tokens: jnp.ndarray,
              extra: Optional[Dict[str, jnp.ndarray]] = None,
              ctx: ShardCtx = NULL_CTX,
              window_override: Optional[int] = None,
              last_only: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (logits, aux_loss). ``extra``: frames / patch_embeds.
        ``last_only`` projects logits for the final position only — the
        serving prefill path needs one next-token distribution, and the
        (seq x vocab) logits matmul dominates an otherwise forward-only
        pass."""
        cfg = self.cfg
        extra = extra or {}
        x = self._embed(params, tokens)
        prefix = 0
        if cfg.frontend == "vision" and "patch_embeds" in extra:
            pe = extra["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([pe, x], axis=1)
            prefix = pe.shape[1]
        positions = jnp.arange(x.shape[1])
        window = cfg.window_size if window_override is None else window_override

        enc_out = None
        if cfg.is_encdec:
            enc_out = self._encode(params, extra["frames"].astype(x.dtype), ctx)

        if cfg.family == "hybrid":
            x, aux = self._hybrid_apply(params, x, positions, ctx)
        else:
            x, aux = self._scan_apply(params, x, positions, ctx,
                                      window=window, enc_out=enc_out,
                                      prefix="d." if cfg.is_encdec else "l.")
        x = rms_norm(x, params["final_ln"])
        if last_only:
            # the last position is never inside the vision prefix
            return self._logits(params, x[:, -1:]), aux
        logits = self._logits(params, x)
        if prefix:
            logits = logits[:, prefix:]
        return logits, aux

    def _layer_apply(self, kind, lp, x, positions, ctx, *, causal=True,
                     window=0, enc_out=None):
        if kind == "a":
            return B.attn_block_apply(self.cfg, lp, x, positions,
                                      causal=causal, window=window, ctx=ctx,
                                      enc_out=enc_out)
        if kind == "s":
            return B.ssd_block_apply(self.cfg, lp, x, positions, ctx=ctx)
        return B.rglru_block_apply(self.cfg, lp, x, positions, ctx=ctx)

    def _scan_apply(self, params, x, positions, ctx, *, window, enc_out,
                    prefix, causal=True, kind="a"):
        cfg = self.cfg
        stacked = _subtree(params, prefix)
        if cfg.family == "ssm":
            kind = "s"

        def layer_fn(carry, lp):
            h, _ = self._layer_apply(kind, lp, carry, positions, ctx,
                                     causal=causal, window=window,
                                     enc_out=enc_out)
            h = ctx.ckpt_constrain(h)
            return h, jnp.float32(0.0) if not cfg.num_experts else None

        if cfg.num_experts:
            def layer_fn(carry, lp):  # noqa: F811 (aux-carrying variant)
                h, aux = self._layer_apply(kind, lp, carry, positions, ctx,
                                           causal=causal, window=window,
                                           enc_out=enc_out)
                h = ctx.ckpt_constrain(h)
                return h, aux

        fn = layer_fn
        if ctx.plan is not None and ctx.plan.remat:
            fn = jax.checkpoint(layer_fn, prevent_cse=False)
        x, auxs = lax.scan(fn, x, stacked)
        aux = jnp.mean(auxs) if cfg.num_experts else jnp.float32(0.0)
        return x, aux

    def _encode(self, params, frames, ctx):
        positions = jnp.arange(frames.shape[1])
        x, _ = self._scan_apply(params, frames, positions, ctx, window=0,
                                enc_out=None, prefix="e.", causal=False)
        return rms_norm(x, params["final_ln"])

    def _hybrid_apply(self, params, x, positions, ctx):
        cfg = self.cfg
        pat = cfg.layer_pattern()
        rp = _subtree(params, "r.")
        ap = _subtree(params, "a.")
        ri = ai = 0
        def rglru_fn(lp_, x_):
            return B.rglru_block_apply(cfg, lp_, x_, positions, ctx=ctx)[0]

        def attn_fn(lp_, x_):
            return B.attn_block_apply(cfg, lp_, x_, positions, causal=True,
                                      window=cfg.window_size, ctx=ctx)[0]

        for kind in pat:
            if kind == "r":
                lp = jax.tree.map(lambda v, i=ri: v[i], rp)
                fn = rglru_fn
                ri += 1
            else:
                lp = jax.tree.map(lambda v, i=ai: v[i], ap)
                fn = attn_fn
                ai += 1
            if ctx.plan is not None and ctx.plan.remat:
                fn = jax.checkpoint(fn, prevent_cse=False)
            x = ctx.ckpt_constrain(fn(lp, x))
        return x, jnp.float32(0.0)

    # ------------------------------------------------------------------
    # loss
    # ------------------------------------------------------------------
    def loss(self, params, batch: Dict[str, jnp.ndarray],
             ctx: ShardCtx = NULL_CTX) -> Tuple[jnp.ndarray, Dict]:
        logits, aux = self.apply(params, batch["tokens"],
                                 extra=batch, ctx=ctx)
        xent = softmax_xent_logits(logits, batch["targets"])
        total = xent + MOE_AUX_COEF * aux
        return total, {"xent": xent, "aux": aux}

    # ------------------------------------------------------------------
    # serving: cache construction + one-token decode
    # ------------------------------------------------------------------
    def attn_cache_len(self, seq_len: int) -> int:
        """Attention cache slots for a ``seq_len`` context: the window for
        sliding-window archs, min(seq, serve_window) beyond the long-context
        threshold (DESIGN §5), the full context otherwise."""
        cfg = self.cfg
        if cfg.window_size:
            return min(seq_len, cfg.window_size)
        if seq_len > 262_144 and cfg.serve_window:
            return min(seq_len, cfg.serve_window)
        return seq_len

    def cache_entries(self, batch: int, seq_len: int) -> Dict[str, Tuple]:
        """{name: (shape, axes, dtype)} for the decode cache. ``seq_len`` is
        the max context; full-attention caches hold min(seq, serve_window)
        slots beyond the long-context threshold (DESIGN §5)."""
        cfg = self.cfg
        ent: Dict[str, Tuple] = {}
        pat = cfg.layer_pattern()

        if cfg.family == "hybrid":
            n_r, n_a = pat.count("r"), pat.count("a")
            for name, (shape, axes, dt) in B.rglru_cache_spec(cfg, batch, self.dtype).items():
                ent[f"r.{name}"] = ((n_r, *shape), ("layers", *axes), dt)
            sc = self.attn_cache_len(seq_len)
            for name, (shape, axes) in B.attn_cache_spec(cfg, batch, sc, self.dtype).items():
                ent[f"a.{name}"] = ((n_a, *shape), ("layers", *axes), self.dtype)
        elif cfg.family == "ssm":
            for name, (shape, axes, dt) in B.ssd_cache_spec(cfg, batch, self.dtype).items():
                ent[f"l.{name}"] = ((cfg.num_layers, *shape), ("layers", *axes), dt)
        else:
            sc = self.attn_cache_len(seq_len)
            n = cfg.num_layers
            pfx = "d." if cfg.is_encdec else "l."
            for name, (shape, axes) in B.attn_cache_spec(cfg, batch, sc, self.dtype).items():
                ent[f"{pfx}{name}"] = ((n, *shape), ("layers", *axes), self.dtype)
            if cfg.is_encdec:
                kv = (n, batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
                axes = ("layers", "batch", None, "kv_heads", "head_dim")
                ent["x.k"] = (kv, axes, self.dtype)
                ent["x.v"] = (kv, axes, self.dtype)
        return ent

    @staticmethod
    def is_paged_cache_key(key: str) -> bool:
        """Whether a cache entry pages its sequence dimension: attention
        K/V stacks do; recurrent state (SSD/RG-LRU/conv) and enc-dec cross
        K/V are O(1) or fixed in sequence and stay per-row."""
        return (key.endswith(".k") or key.endswith(".v")) \
            and not key.startswith("x.")

    def paged_cache_entries(self, batch: int, seq_len: int, page: int):
        """Block-granular cache layout: attention K/V entries trade their
        per-row sequence dimension ``(L, B, sc, Kv, Dh)`` for one flat
        per-arena slot stack ``(L, n_pages * page, Kv, Dh)`` shared by all
        rows through per-row page tables; everything else keeps its
        ``(L, B, ...)`` row layout. Returns ``(entries, n_pages, sc)``
        where ``sc`` is the logical slots per row and ``n_pages`` the
        physical page capacity (``batch * ceil(sc / page)``)."""
        ent = self.cache_entries(batch, seq_len)
        sc = self.attn_cache_len(seq_len)
        has_paged = any(self.is_paged_cache_key(k) for k in ent)
        n_pages = batch * -(-sc // page) if has_paged else 0
        out: Dict[str, Tuple] = {}
        for k, (shape, axes, dt) in ent.items():
            if self.is_paged_cache_key(k):
                ll, _b, s, *rest = shape
                assert s == sc, (k, s, sc)
                out[k] = ((ll, n_pages * page, *rest),
                          (axes[0], "kv_slots", *axes[3:]), dt)
            else:
                out[k] = (shape, axes, dt)
        return out, n_pages, sc

    def init_paged_cache(self, batch: int, seq_len: int, page: int):
        ent, _n_pages, _sc = self.paged_cache_entries(batch, seq_len, page)
        return {k: jnp.zeros(s, d) for k, (s, a, d) in ent.items()}

    def cache_specs(self, batch: int, seq_len: int):
        ent = self.cache_entries(batch, seq_len)
        specs = {k: jax.ShapeDtypeStruct(s, d) for k, (s, a, d) in ent.items()}
        axes = {k: a for k, (s, a, d) in ent.items()}
        return specs, axes

    def init_cache(self, batch: int, seq_len: int):
        ent = self.cache_entries(batch, seq_len)
        return {k: jnp.zeros(s, d) for k, (s, a, d) in ent.items()}

    def decode_window(self, seq_len: int) -> int:
        cfg = self.cfg
        if cfg.window_size:
            return cfg.window_size
        if seq_len > 262_144 and cfg.serve_window:
            return cfg.serve_window
        return 0

    def decode_step(self, params, cache: Dict, tokens: jnp.ndarray,
                    pos: jnp.ndarray, ctx: ShardCtx = NULL_CTX,
                    window_override: Optional[int] = None,
                    tables: Optional[jnp.ndarray] = None, page: int = 0,
                    seq_len: int = 0, decode_kernel: str = "gather"):
        """tokens: (B, 1); pos: scalar int32 *or* a (B,) per-row position
        vector — rows of one batch may sit at different generation depths
        (the row-addressable cache-pool decode shape). Returns
        (logits, new_cache). ``window_override``: force rotating-cache
        semantics with this window (otherwise inferred: arch window or
        long-context serve_window).

        ``tables``/``page``: block-granular paged decode — attention K/V in
        ``cache`` are flat per-arena slot stacks (``paged_cache_entries``)
        addressed through the (B, max_pages) page table; ``seq_len`` is
        then the logical context bucket the arena was sized for (the flat
        layout no longer carries it). ``decode_kernel`` is the plan-chosen
        physical operator for the paged read side (paged | gather | ref,
        see ``blocks.attn_block_decode``); ignored on the dense path."""
        cfg = self.cfg
        pos = jnp.asarray(pos, jnp.int32)
        x = self._embed(params, tokens)
        paged = tables is not None and page > 0
        sc = self.attn_cache_len(seq_len) if paged else 0
        window = (window_override if window_override is not None
                  else self.decode_window(seq_len if paged
                                          else cache_seq(cache)))
        if not paged:
            tables, page, sc = None, 0, 0

        if cfg.family == "hybrid":
            x, cache = self._hybrid_decode(params, x, cache, pos, window, ctx,
                                           tables=tables, page=page, sc=sc,
                                           decode_kernel=decode_kernel)
        elif cfg.family == "ssm":
            x, cache = self._scan_decode(params, x, cache, pos, 0, ctx,
                                         prefix="l.", kind="s")
        elif cfg.is_encdec:
            x, cache = self._scan_decode(params, x, cache, pos, window, ctx,
                                         prefix="d.", kind="a", cross=True,
                                         tables=tables, page=page, sc=sc,
                                         decode_kernel=decode_kernel)
        else:
            x, cache = self._scan_decode(params, x, cache, pos, window, ctx,
                                         prefix="l.", kind="a",
                                         tables=tables, page=page, sc=sc,
                                         decode_kernel=decode_kernel)
        x = rms_norm(x, params["final_ln"])
        return self._logits(params, x), cache

    def _scan_decode(self, params, x, cache, pos, window, ctx, *, prefix,
                     kind, cross=False, tables=None, page=0, sc=0,
                     decode_kernel="gather"):
        cfg = self.cfg
        stacked = _subtree(params, prefix)
        lcache = _subtree({k: v for k, v in cache.items()
                           if not k.startswith("x.")}, prefix)
        xkv = (cache.get("x.k"), cache.get("x.v")) if cross else None

        def layer_fn(carry, xs):
            if cross:
                lp, lc, xk, xv = xs
                h, lc2 = B.attn_block_decode(cfg, lp, carry, lc, pos,
                                             window=window, ctx=ctx,
                                             enc_out_kv=(xk, xv),
                                             tables=tables, page=page, sc=sc,
                                             decode_kernel=decode_kernel)
            elif kind == "s":
                lp, lc = xs
                h, lc2 = B.ssd_block_decode(cfg, lp, carry, lc, pos, ctx=ctx)
            else:
                lp, lc = xs
                h, lc2 = B.attn_block_decode(cfg, lp, carry, lc, pos,
                                             window=window, ctx=ctx,
                                             tables=tables, page=page, sc=sc,
                                             decode_kernel=decode_kernel)
            return h, lc2

        xs = (stacked, lcache, *xkv) if cross else (stacked, lcache)
        x, new_lcache = lax.scan(layer_fn, x, xs)
        out = dict(cache)
        for k, v in new_lcache.items():
            out[prefix + k] = v
        return x, out

    def _hybrid_decode(self, params, x, cache, pos, window, ctx,
                       tables=None, page=0, sc=0, decode_kernel="gather"):
        cfg = self.cfg
        pat = cfg.layer_pattern()
        rp, ap = _subtree(params, "r."), _subtree(params, "a.")
        rc = _subtree({k: v for k, v in cache.items() if k.startswith("r.")}, "r.")
        ac = _subtree({k: v for k, v in cache.items() if k.startswith("a.")}, "a.")
        new_rc = {k: v for k, v in rc.items()}
        new_ac = {k: v for k, v in ac.items()}
        ri = ai = 0
        for kind in pat:
            if kind == "r":
                lp = jax.tree.map(lambda v, i=ri: v[i], rp)
                lc = {k: v[ri] for k, v in rc.items()}
                x, lc2 = B.rglru_block_decode(cfg, lp, x, lc, pos, ctx=ctx)
                for k, v in lc2.items():
                    new_rc[k] = new_rc[k].at[ri].set(v)
                ri += 1
            else:
                lp = jax.tree.map(lambda v, i=ai: v[i], ap)
                lc = {k: v[ai] for k, v in ac.items()}
                x, lc2 = B.attn_block_decode(cfg, lp, x, lc, pos,
                                             window=cfg.window_size, ctx=ctx,
                                             tables=tables, page=page, sc=sc,
                                             decode_kernel=decode_kernel)
                for k, v in lc2.items():
                    new_ac[k] = new_ac[k].at[ai].set(v)
                ai += 1
        out = dict(cache)
        out.update({f"r.{k}": v for k, v in new_rc.items()})
        out.update({f"a.{k}": v for k, v in new_ac.items()})
        return x, out

    def build_cross_cache(self, params, frames, ctx: ShardCtx = NULL_CTX):
        """Enc-dec serving setup: run the encoder once and precompute every
        decoder layer's cross-attention K/V over the encoder output.
        Returns {"x.k": (L,B,Senc,Kv,Dh), "x.v": ...} to merge into the
        decode cache."""
        assert self.cfg.is_encdec
        enc_out = self._encode(params, frames, ctx)
        dp = _subtree(params, "d.")
        xk = jnp.einsum("bsd,ldhk->lbshk", enc_out, dp["xwk"])
        xv = jnp.einsum("bsd,ldhk->lbshk", enc_out, dp["xwv"])
        return {"x.k": xk.astype(self.dtype), "x.v": xv.astype(self.dtype)}

    # ------------------------------------------------------------------
    # prefill: full prompt pass that *populates* the decode cache
    # ------------------------------------------------------------------
    @property
    def supports_handoff(self) -> bool:
        """Whether prefill can hand a populated cache to decode. Decoder-
        only text stacks (dense / moe / ssm / hybrid) do; enc-dec and
        modality-prefix frontends still start decode from a zero cache."""
        return not self.cfg.is_encdec and self.cfg.frontend == "none"

    def prefill(self, params, tokens, extra=None, ctx: ShardCtx = NULL_CTX,
                *, lengths: Optional[jnp.ndarray] = None,
                cache_len: Optional[int] = None):
        """Prompt pass returning ``(last_logits, cache)``.

        ``last_logits`` is each row's next-token distribution at its own
        final prompt position (``(B, vocab)``); ``cache`` is a *populated*
        decode cache — the same pytree as :meth:`init_cache` at
        ``(batch, cache_len)`` — so decode continues from the prompt instead
        of restarting on zeros (prefill→decode handoff). ``lengths`` gives
        the per-row prompt length inside the padded ``tokens`` (default: the
        full width); ``cache_len`` sizes the cache context (default: the
        tokens width). Families without handoff return ``cache=None``.
        """
        cfg = self.cfg
        b, s = tokens.shape[0], tokens.shape[1]
        if not self.supports_handoff:
            logits, _ = self.apply(params, tokens, extra=extra, ctx=ctx,
                                   last_only=True)
            return logits[:, -1], None
        if lengths is None:
            lengths = jnp.full((b,), s, jnp.int32)
        lengths = jnp.asarray(lengths, jnp.int32)
        cache_len = int(cache_len) if cache_len else s  # lint: allow-tracer-host-sync (static python int)
        x = self._embed(params, tokens)
        positions = jnp.arange(s)
        if cfg.family == "hybrid":
            x, cache = self._hybrid_prefill(params, x, positions, lengths, ctx)
        else:
            x, cache = self._stack_prefill(params, x, positions, lengths, ctx)
        x = rms_norm(x, params["final_ln"])
        xl = jnp.take_along_axis(x, (lengths - 1)[:, None, None], axis=1)
        logits = self._logits(params, xl)[:, 0]
        # attention K/V land in their decode-slot layout (rotating-window
        # aware); recurrent state entries are already in decode form
        sc = self.attn_cache_len(cache_len)
        cache = {k: (gather_cache_slots(v, lengths, sc)
                     if k.endswith(".k") or k.endswith(".v") else v)
                 for k, v in cache.items()}
        # exact init_cache pytree contract: hybrids whose reduced pattern
        # drops a block kind still carry that kind's zero-layer entries
        for k, (shape, _axes, dt) in self.cache_entries(b, cache_len).items():
            if k not in cache:
                cache[k] = jnp.zeros(shape, dt)
        return logits, cache

    def _stack_prefill(self, params, x, positions, lengths, ctx):
        cfg = self.cfg
        stacked = _subtree(params, "l.")
        if cfg.family == "ssm":
            def layer_fn(carry, lp):
                h, _, c = B.ssd_block_apply(cfg, lp, carry, positions,
                                            ctx=ctx, lengths=lengths,
                                            want_cache=True)
                return ctx.ckpt_constrain(h), c
        else:
            window = cfg.window_size

            def layer_fn(carry, lp):
                h, _, c = B.attn_block_apply(cfg, lp, carry, positions,
                                             causal=True, window=window,
                                             ctx=ctx, want_kv=True)
                return ctx.ckpt_constrain(h), c
        x, ccache = lax.scan(layer_fn, x, stacked)
        return x, {f"l.{k}": v for k, v in ccache.items()}

    def _hybrid_prefill(self, params, x, positions, lengths, ctx):
        cfg = self.cfg
        rp, ap = _subtree(params, "r."), _subtree(params, "a.")
        ri = ai = 0
        rcs, acs = [], []
        for kind in cfg.layer_pattern():
            if kind == "r":
                lp = jax.tree.map(lambda v, i=ri: v[i], rp)
                x, _, c = B.rglru_block_apply(cfg, lp, x, positions, ctx=ctx,
                                              lengths=lengths, want_cache=True)
                rcs.append(c)
                ri += 1
            else:
                lp = jax.tree.map(lambda v, i=ai: v[i], ap)
                x, _, c = B.attn_block_apply(cfg, lp, x, positions,
                                             causal=True,
                                             window=cfg.window_size, ctx=ctx,
                                             want_kv=True)
                acs.append(c)
                ai += 1
            x = ctx.ckpt_constrain(x)
        cache = {}
        for prefix, layer_caches in (("r.", rcs), ("a.", acs)):
            for k in (layer_caches[0] if layer_caches else ()):
                cache[prefix + k] = jnp.stack([c[k] for c in layer_caches])
        return x, cache


def gather_cache_slots(kv: jnp.ndarray, lengths: jnp.ndarray,
                       sc: int) -> jnp.ndarray:
    """Map full-sequence K/V ``(L, B, S, Kv, Dh)`` onto decode-cache slots
    ``(L, B, sc, Kv, Dh)``: slot ``i`` of row ``r`` holds the latest prompt
    position ``p ≡ i (mod sc)`` with ``p < lengths[r]`` — the rotating-
    window layout :func:`attention.decode_attention` masks against (the
    identity layout is the ``sc >= S`` special case). Slots with no valid
    position are zeroed; the decode mask never exposes them."""
    s = kv.shape[2]
    last = lengths - 1
    i = jnp.arange(sc)[None, :]
    p = last[:, None] - jnp.mod(last[:, None] - i, sc)          # (B, sc)
    valid = (p >= 0)[None, :, :, None, None]
    pc = jnp.clip(p, 0, s - 1)[None, :, :, None, None]
    out = jnp.take_along_axis(kv, pc, axis=2)
    return jnp.where(valid, out, jnp.zeros((), kv.dtype))


def cache_seq(cache: Dict) -> int:
    for k, v in cache.items():
        if k.endswith(".k") and not k.startswith("x."):
            return v.shape[2]
    return 0


def build_model(cfg: ModelConfig, dtype=jnp.bfloat16) -> Model:
    return Model(cfg, dtype)
