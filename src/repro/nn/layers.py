"""The NN library (paper §2) — layers with ``init``/``forward``/``backward``.

SystemML 1.0 has **no automatic differentiation**: every layer in its NN
library ships a hand-written backward pass in DML. This module reproduces
that library faithfully in JAX: each layer is a namespace with

    init(...)                 -> params
    forward(X, ...)           -> out            (pure, matrix in/matrix out)
    backward(dout, X, ...)    -> input/param gradients

All activations flow as **linearized 2-D matrices** (paper §3 "Tensor
Representation"): an [N, C, H, W] tensor travels as an (N, C*H*W) matrix;
conv/pool layers take (C, H, W) metadata exactly like SystemML's
``conv2d::forward(X, W, b, C, Hin, Win, ...)``.

Every backward here is validated against ``jax.grad`` in
``tests/test_nn_layers.py`` — the library never relies on autodiff at
runtime, autodiff is only the test oracle.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.linearize import conv2d_out_hw


# ---------------------------------------------------------------------------
# affine
# ---------------------------------------------------------------------------

class affine:
    @staticmethod
    def init(d: int, m: int, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
        # SystemML: W ~ N(0, sqrt(2/D)) (He); b = 0
        w = jax.random.normal(key, (d, m)) * math.sqrt(2.0 / d)
        return w, jnp.zeros((1, m))

    @staticmethod
    def forward(x, w, b):
        return x @ w + b

    @staticmethod
    def backward(dout, x, w, b):
        dx = dout @ w.T
        dw = x.T @ dout
        db = jnp.sum(dout, axis=0, keepdims=True)
        return dx, dw, db


# ---------------------------------------------------------------------------
# elementwise activations
# ---------------------------------------------------------------------------

class relu:
    @staticmethod
    def forward(x):
        return jnp.maximum(x, 0)

    @staticmethod
    def backward(dout, x):
        return dout * (x > 0)


class leaky_relu:
    alpha = 0.01

    @classmethod
    def forward(cls, x):
        return jnp.where(x > 0, x, cls.alpha * x)

    @classmethod
    def backward(cls, dout, x):
        return dout * jnp.where(x > 0, 1.0, cls.alpha)


class elu:
    @staticmethod
    def forward(x, alpha: float = 1.0):
        return jnp.where(x > 0, x, alpha * (jnp.exp(x) - 1.0))

    @staticmethod
    def backward(dout, x, alpha: float = 1.0):
        return dout * jnp.where(x > 0, 1.0, alpha * jnp.exp(x))


class sigmoid:
    @staticmethod
    def forward(x):
        return 1.0 / (1.0 + jnp.exp(-x))

    @staticmethod
    def backward(dout, x):
        s = sigmoid.forward(x)
        return dout * s * (1.0 - s)


class tanh:
    @staticmethod
    def forward(x):
        return jnp.tanh(x)

    @staticmethod
    def backward(dout, x):
        t = jnp.tanh(x)
        return dout * (1.0 - t * t)


class gelu:
    """tanh-approximate GELU (matches the transformer stack)."""

    _c = math.sqrt(2.0 / math.pi)

    @classmethod
    def forward(cls, x):
        inner = cls._c * (x + 0.044715 * x**3)
        return 0.5 * x * (1.0 + jnp.tanh(inner))

    @classmethod
    def backward(cls, dout, x):
        inner = cls._c * (x + 0.044715 * x**3)
        t = jnp.tanh(inner)
        dinner = cls._c * (1.0 + 3 * 0.044715 * x**2)
        return dout * (0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * dinner)


class softmax:
    @staticmethod
    def forward(x):
        z = x - jnp.max(x, axis=1, keepdims=True)
        e = jnp.exp(z)
        return e / jnp.sum(e, axis=1, keepdims=True)

    @staticmethod
    def backward(dout, x):
        p = softmax.forward(x)
        return p * (dout - jnp.sum(dout * p, axis=1, keepdims=True))


class log_softmax:
    @staticmethod
    def forward(x):
        z = x - jnp.max(x, axis=1, keepdims=True)
        return z - jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))

    @staticmethod
    def backward(dout, x):
        p = softmax.forward(x)
        return dout - p * jnp.sum(dout, axis=1, keepdims=True)


# ---------------------------------------------------------------------------
# dropout (inverted dropout, as in SystemML's nn/layers/dropout.dml)
# ---------------------------------------------------------------------------

class dropout:
    @staticmethod
    def forward(x, p: float, key):
        mask = (jax.random.uniform(key, x.shape) > p) / (1.0 - p)
        return x * mask, mask

    @staticmethod
    def backward(dout, mask):
        return dout * mask


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

class batch_norm1d:
    eps = 1e-5

    @staticmethod
    def init(d: int):
        return jnp.ones((1, d)), jnp.zeros((1, d)), jnp.zeros((1, d)), jnp.ones((1, d))
        # gamma, beta, running_mean, running_var

    @staticmethod
    def forward(x, gamma, beta, mode: str = "train",
                running_mean=None, running_var=None, momentum: float = 0.9):
        if mode == "train":
            mu = jnp.mean(x, axis=0, keepdims=True)
            var = jnp.var(x, axis=0, keepdims=True)
            new_rm = momentum * running_mean + (1 - momentum) * mu if running_mean is not None else mu
            new_rv = momentum * running_var + (1 - momentum) * var if running_var is not None else var
        else:
            mu, var = running_mean, running_var
            new_rm, new_rv = running_mean, running_var
        xhat = (x - mu) / jnp.sqrt(var + batch_norm1d.eps)
        return gamma * xhat + beta, (xhat, mu, var), new_rm, new_rv

    @staticmethod
    def backward(dout, cache, x, gamma):
        xhat, mu, var = cache
        n = x.shape[0]
        istd = 1.0 / jnp.sqrt(var + batch_norm1d.eps)
        dgamma = jnp.sum(dout * xhat, axis=0, keepdims=True)
        dbeta = jnp.sum(dout, axis=0, keepdims=True)
        dxhat = dout * gamma
        dx = istd / n * (n * dxhat - jnp.sum(dxhat, axis=0, keepdims=True)
                         - xhat * jnp.sum(dxhat * xhat, axis=0, keepdims=True))
        return dx, dgamma, dbeta


class batch_norm2d:
    """Spatial batch-norm on linearized (N, C*H*W) input."""

    @staticmethod
    def init(c: int):
        return batch_norm1d.init(c)

    @staticmethod
    def forward(x, gamma, beta, c, h, w, mode="train",
                running_mean=None, running_var=None, momentum=0.9):
        n = x.shape[0]
        # (N, C*H*W) -> (N*H*W, C): per-channel statistics
        xc = x.reshape(n, c, h * w).transpose(0, 2, 1).reshape(n * h * w, c)
        out, cache, rm, rv = batch_norm1d.forward(
            xc, gamma, beta, mode, running_mean, running_var, momentum)
        out = out.reshape(n, h * w, c).transpose(0, 2, 1).reshape(n, c * h * w)
        return out, (cache, xc), rm, rv

    @staticmethod
    def backward(dout, cache, x, gamma, c, h, w):
        inner_cache, xc = cache
        n = x.shape[0]
        doutc = dout.reshape(n, c, h * w).transpose(0, 2, 1).reshape(n * h * w, c)
        dxc, dgamma, dbeta = batch_norm1d.backward(doutc, inner_cache, xc, gamma)
        dx = dxc.reshape(n, h * w, c).transpose(0, 2, 1).reshape(n, c * h * w)
        return dx, dgamma, dbeta


class layer_norm:
    eps = 1e-5

    @staticmethod
    def init(d: int):
        return jnp.ones((1, d)), jnp.zeros((1, d))

    @staticmethod
    def forward(x, gamma, beta):
        mu = jnp.mean(x, axis=1, keepdims=True)
        var = jnp.var(x, axis=1, keepdims=True)
        xhat = (x - mu) / jnp.sqrt(var + layer_norm.eps)
        return gamma * xhat + beta, (xhat, var)

    @staticmethod
    def backward(dout, cache, x, gamma):
        xhat, var = cache
        d = x.shape[1]
        istd = 1.0 / jnp.sqrt(var + layer_norm.eps)
        dgamma = jnp.sum(dout * xhat, axis=0, keepdims=True)
        dbeta = jnp.sum(dout, axis=0, keepdims=True)
        dxhat = dout * gamma
        dx = istd / d * (d * dxhat - jnp.sum(dxhat, axis=1, keepdims=True)
                         - xhat * jnp.sum(dxhat * xhat, axis=1, keepdims=True))
        return dx, dgamma, dbeta


class rms_norm:
    eps = 1e-5

    @staticmethod
    def init(d: int):
        return (jnp.ones((1, d)),)

    @staticmethod
    def forward(x, gamma):
        ms = jnp.mean(x * x, axis=1, keepdims=True)
        inv = 1.0 / jnp.sqrt(ms + rms_norm.eps)
        return gamma * x * inv, inv

    @staticmethod
    def backward(dout, inv, x, gamma):
        d = x.shape[1]
        dgamma = jnp.sum(dout * x * inv, axis=0, keepdims=True)
        dxhat = dout * gamma
        dx = inv * dxhat - (inv**3 / d) * x * jnp.sum(dxhat * x, axis=1, keepdims=True)
        return dx, dgamma


class scale_shift:
    """SystemML nn/layers/scale_shift*.dml: out = gamma*x + beta."""

    @staticmethod
    def init(d: int):
        return jnp.ones((1, d)), jnp.zeros((1, d))

    @staticmethod
    def forward(x, gamma, beta):
        return gamma * x + beta

    @staticmethod
    def backward(dout, x, gamma):
        return dout * gamma, jnp.sum(dout * x, 0, keepdims=True), jnp.sum(dout, 0, keepdims=True)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------

class embedding:
    @staticmethod
    def init(vocab: int, d: int, key):
        return (jax.random.normal(key, (vocab, d)) * 0.02,)

    @staticmethod
    def forward(ids, table):
        return table[ids]

    @staticmethod
    def backward(dout, ids, table):
        return jnp.zeros_like(table).at[ids].add(dout)


# ---------------------------------------------------------------------------
# conv2d — im2col lowering (paper ref [5]) on linearized matrices
# ---------------------------------------------------------------------------

def im2col(x2d, c, h, w, kernel, stride, pad):
    """(N, C*H*W) -> (N, Ho*Wo, C*k*k) patch matrix."""
    n = x2d.shape[0]
    x = x2d.reshape(n, c, h, w)
    patches = lax.conv_general_dilated_patches(
        x, (kernel, kernel), (stride, stride),
        [(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (N, C*k*k, Ho, Wo)
    ho, wo = conv2d_out_hw(h, w, kernel, stride, pad)
    return patches.reshape(n, c * kernel * kernel, ho * wo).transpose(0, 2, 1)


class conv2d:
    @staticmethod
    def init(c: int, filters: int, kernel: int, key):
        fan_in = c * kernel * kernel
        w = jax.random.normal(key, (filters, fan_in)) * math.sqrt(2.0 / fan_in)
        return w, jnp.zeros((filters, 1))

    @staticmethod
    def forward(x2d, w, b, c, h, w_in, kernel, stride, pad):
        n = x2d.shape[0]
        ho, wo = conv2d_out_hw(h, w_in, kernel, stride, pad)
        cols = im2col(x2d, c, h, w_in, kernel, stride, pad)   # (N, HoWo, Ckk)
        out = cols @ w.T + b.T                                 # (N, HoWo, F)
        out = out.transpose(0, 2, 1).reshape(n, -1)            # (N, F*Ho*Wo)
        return out, cols

    @staticmethod
    def backward(dout, cols, x2d, w, c, h, w_in, kernel, stride, pad):
        n = x2d.shape[0]
        f = w.shape[0]
        ho, wo = conv2d_out_hw(h, w_in, kernel, stride, pad)
        do_ = dout.reshape(n, f, ho * wo).transpose(0, 2, 1)    # (N, HoWo, F)
        dw = jnp.einsum("npf,npk->fk", do_, cols)
        db = jnp.sum(do_, axis=(0, 1))[:, None]
        dcols = jnp.einsum("npf,fk->npk", do_, w)               # (N, HoWo, Ckk)
        dx = col2im(dcols, c, h, w_in, kernel, stride, pad)
        return dx, dw, db


def col2im(dcols, c, h, w, kernel, stride, pad):
    """Scatter-add patch gradients back to the (N, C*H*W) image — the
    hand-derived transpose of im2col."""
    n = dcols.shape[0]
    ho, wo = conv2d_out_hw(h, w, kernel, stride, pad)
    # (N, HoWo, C*k*k) -> (N, C, k, k, Ho, Wo)
    d = dcols.transpose(0, 2, 1).reshape(n, c, kernel, kernel, ho, wo)
    hp, wp = h + 2 * pad, w + 2 * pad
    out = jnp.zeros((n, c, hp, wp), dcols.dtype)
    for ki in range(kernel):
        for kj in range(kernel):
            patch = jnp.zeros((n, c, hp, wp), dcols.dtype)
            patch = patch.at[
                :, :, ki : ki + stride * ho : stride, kj : kj + stride * wo : stride
            ].set(d[:, :, ki, kj])
            out = out + patch
    out = out[:, :, pad : pad + h, pad : pad + w]
    return out.reshape(n, c * h * w)


# ---------------------------------------------------------------------------
# pooling (stride == pool, dims divisible — the SystemML demo-model cases)
# ---------------------------------------------------------------------------

class max_pool2d:
    @staticmethod
    def forward(x2d, c, h, w, pool):
        n = x2d.shape[0]
        x = x2d.reshape(n, c, h // pool, pool, w // pool, pool)
        out = jnp.max(x, axis=(3, 5))
        return out.reshape(n, -1), None

    @staticmethod
    def backward(dout, _cache, x2d, c, h, w, pool):
        n = x2d.shape[0]
        x = x2d.reshape(n, c, h // pool, pool, w // pool, pool)
        mx = jnp.max(x, axis=(3, 5), keepdims=True)
        mask = (x == mx).astype(x.dtype)
        # split ties evenly (matches the subgradient; jax.grad does the same)
        mask = mask / jnp.sum(mask, axis=(3, 5), keepdims=True)
        d = dout.reshape(n, c, h // pool, 1, w // pool, 1)
        return (mask * d).reshape(n, -1)


class avg_pool2d:
    @staticmethod
    def forward(x2d, c, h, w, pool):
        n = x2d.shape[0]
        x = x2d.reshape(n, c, h // pool, pool, w // pool, pool)
        return jnp.mean(x, axis=(3, 5)).reshape(n, -1), None

    @staticmethod
    def backward(dout, _cache, x2d, c, h, w, pool):
        n = x2d.shape[0]
        d = dout.reshape(n, c, h // pool, 1, w // pool, 1)
        d = jnp.broadcast_to(d / (pool * pool),
                             (n, c, h // pool, pool, w // pool, pool))
        return d.reshape(n, -1)


# ---------------------------------------------------------------------------
# recurrent layers (simple RNN + LSTM), manual BPTT
# ---------------------------------------------------------------------------

class simple_rnn:
    @staticmethod
    def init(d: int, m: int, key):
        k1, k2 = jax.random.split(key)
        wx = jax.random.normal(k1, (d, m)) * math.sqrt(1.0 / d)
        wh = jax.random.normal(k2, (m, m)) * math.sqrt(1.0 / m)
        return wx, wh, jnp.zeros((1, m))

    @staticmethod
    def forward(x, wx, wh, b, h0):
        """x: (N, T, D); returns (hs: (N, T, M), caches)."""

        def step(h, xt):
            a = xt @ wx + h @ wh + b
            hn = jnp.tanh(a)
            return hn, hn

        hT, hs = lax.scan(step, h0, x.transpose(1, 0, 2))
        return hs.transpose(1, 0, 2), hT

    @staticmethod
    def backward(dhs, x, wx, wh, b, h0):
        """Manual BPTT (reverse scan over time)."""
        hs, _ = simple_rnn.forward(x, wx, wh, b, h0)
        n, t, m = dhs.shape
        h0b = jnp.broadcast_to(h0, (n, m))[:, None, :]
        hs_prev = jnp.concatenate([h0b, hs[:, :-1]], axis=1)

        def step(carry, inp):
            dh_next = carry
            ht, hprev, xt, dht = inp
            dh = dht + dh_next
            da = dh * (1.0 - ht * ht)
            dxt = da @ wx.T
            dwx = xt.T @ da
            dwh = hprev.T @ da
            db = jnp.sum(da, axis=0, keepdims=True)
            return da @ wh.T, (dxt, dwx, dwh, db)

        seq = (hs.transpose(1, 0, 2)[::-1], hs_prev.transpose(1, 0, 2)[::-1],
               x.transpose(1, 0, 2)[::-1], dhs.transpose(1, 0, 2)[::-1])
        dh0, (dxs, dwxs, dwhs, dbs) = lax.scan(step, jnp.zeros((n, m)), seq)
        return (dxs[::-1].transpose(1, 0, 2), dwxs.sum(0), dwhs.sum(0),
                dbs.sum(0), dh0)


class lstm:
    @staticmethod
    def init(d: int, m: int, key):
        k1, k2 = jax.random.split(key)
        wx = jax.random.normal(k1, (d, 4 * m)) * math.sqrt(1.0 / d)
        wh = jax.random.normal(k2, (m, 4 * m)) * math.sqrt(1.0 / m)
        return wx, wh, jnp.zeros((1, 4 * m))

    @staticmethod
    def _gates(a, m):
        i = sigmoid.forward(a[:, :m])
        f = sigmoid.forward(a[:, m : 2 * m])
        o = sigmoid.forward(a[:, 2 * m : 3 * m])
        g = jnp.tanh(a[:, 3 * m :])
        return i, f, o, g

    @staticmethod
    def forward(x, wx, wh, b, h0, c0):
        m = h0.shape[1]

        def step(carry, xt):
            h, c = carry
            a = xt @ wx + h @ wh + b
            i, f, o, g = lstm._gates(a, m)
            cn = f * c + i * g
            hn = o * jnp.tanh(cn)
            return (hn, cn), (hn, cn, i, f, o, g)

        (hT, cT), (hs, cs, i_, f_, o_, g_) = lax.scan(step, (h0, c0), x.transpose(1, 0, 2))
        cache = (hs, cs, i_, f_, o_, g_)
        return hs.transpose(1, 0, 2), (hT, cT), cache

    @staticmethod
    def backward(dhs, cache, x, wx, wh, b, h0, c0):
        hs, cs, i_, f_, o_, g_ = cache
        n, t, _ = dhs.shape
        m = h0.shape[1]
        hs_prev = jnp.concatenate([h0[None], hs[:-1]], axis=0)   # (T, N, M)
        cs_prev = jnp.concatenate([c0[None], cs[:-1]], axis=0)

        def step(carry, inp):
            dh_next, dc_next = carry
            (ht, ct, it, ft, ot, gt, hprev, cprev, xt, dht) = inp
            dh = dht + dh_next
            tc = jnp.tanh(ct)
            do = dh * tc
            dc = dc_next + dh * ot * (1 - tc * tc)
            di = dc * gt
            df = dc * cprev
            dg = dc * it
            da = jnp.concatenate(
                [di * it * (1 - it), df * ft * (1 - ft),
                 do * ot * (1 - ot), dg * (1 - gt * gt)], axis=1)
            dxt = da @ wx.T
            dwx = xt.T @ da
            dwh = hprev.T @ da
            db = jnp.sum(da, 0, keepdims=True)
            return (da @ wh.T, dc * ft), (dxt, dwx, dwh, db)

        seq = tuple(
            arr[::-1]
            for arr in (hs, cs, i_, f_, o_, g_, hs_prev, cs_prev,
                        x.transpose(1, 0, 2), dhs.transpose(1, 0, 2))
        )
        (dh0, dc0), (dxs, dwxs, dwhs, dbs) = lax.scan(
            step, (jnp.zeros((n, m)), jnp.zeros((n, m))), seq)
        return (dxs[::-1].transpose(1, 0, 2), dwxs.sum(0), dwhs.sum(0),
                dbs.sum(0), dh0, dc0)
