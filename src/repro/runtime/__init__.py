from repro.runtime.train_loop import (init_opt_state, make_train_step,
                                      opt_state_specs, train_shardings,
                                      batch_specs)
from repro.runtime.serve_loop import (PlanServer, ServeRequest,
                                      cache_shardings, greedy_decode,
                                      make_decode_step, make_prefill)
from repro.runtime.engine import (Clock, RequestHandle, ServingEngine,
                                  TokenEvent, VirtualClock, WallClock)
from repro.runtime.scheduler import (ContinuousBatchingScheduler,
                                     QueuedRequest, RequestQueue,
                                     simulate_arrivals)
from repro.runtime.kv_cache import CacheArena, KVCachePool, PoolMetrics
from repro.runtime.metrics import (LatencyStats, PlanCacheMetrics,
                                   SchedulerMetrics, StepTimer,
                                   format_metrics, pool_summary,
                                   scheduler_summary, serve_summary)

__all__ = ["make_train_step", "init_opt_state", "opt_state_specs",
           "train_shardings", "batch_specs", "make_decode_step",
           "make_prefill", "cache_shardings", "greedy_decode", "PlanServer",
           "ServeRequest", "ServingEngine", "RequestHandle", "TokenEvent",
           "Clock", "VirtualClock", "WallClock",
           "ContinuousBatchingScheduler", "RequestQueue",
           "QueuedRequest", "simulate_arrivals", "StepTimer",
           "format_metrics", "LatencyStats", "PlanCacheMetrics",
           "SchedulerMetrics", "scheduler_summary", "serve_summary",
           "KVCachePool", "CacheArena", "PoolMetrics", "pool_summary"]
