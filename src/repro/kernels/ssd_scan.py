"""Mamba-2 SSD (state-space duality) Pallas kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the sequential
recurrence is re-expressed as *chunked matmuls* (BLAS-3) — exactly the kind
of rewrite SystemML's compiler performs when it lowers iterative DML to
matrix operators. Within a chunk everything is dense matmul on the MXU;
across chunks a (P x N) state tile is carried in VMEM scratch along the
sequential minor grid axis.

Grid: (B, H, S/chunk) with the chunk axis innermost (sequential on TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_ref,
    *, chunk: int, n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)     # (chunk, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)   # (chunk, 1)
    a = a_ref[0]                               # (1,) decay rate (negative)
    bm = b_ref[0, 0].astype(jnp.float32)       # (chunk, N)
    cm = c_ref[0, 0].astype(jnp.float32)       # (chunk, N)
    d = d_ref[0]                               # (1,)

    aseg = dt * a                              # (chunk, 1)
    cum = jnp.cumsum(aseg, axis=0)             # (chunk, 1) inclusive
    total = cum[chunk - 1, 0]

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) * [i >= j]
    li = cum - cum.reshape(1, chunk)           # (chunk, chunk)
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    lmat = jnp.exp(jnp.where(tri, li, -1e30))  # mask before exp (overflow)
    scores = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)
    w = scores * lmat                          # (chunk, chunk)
    dx = dt * x                                # (chunk, P)
    y = jnp.dot(w, dx, preferred_element_type=jnp.float32)

    # inter-chunk: exp(cum_i) * C_i . state_prev^T   (state: (P, N))
    state = state_ref[...]
    y += jnp.exp(cum) * jnp.dot(cm, state.T, preferred_element_type=jnp.float32)

    # state update: exp(total) * state + sum_t exp(total - cum_t) dx_t b_t^T
    decay_to_end = jnp.exp(total - cum)        # (chunk, 1)
    contrib = jnp.dot((dx * decay_to_end).T, bm, preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(total) * state + contrib

    y_ref[0, 0, 0] = (y + d * x).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,      # (B, S, H, P)
    dt: jnp.ndarray,     # (B, S, H)
    a: jnp.ndarray,      # (H,)
    b_mat: jnp.ndarray,  # (B, S, N)
    c_mat: jnp.ndarray,  # (B, S, N)
    d: jnp.ndarray,      # (H,)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, H, P = x.shape
    N = b_mat.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    # layouts: (B, H, nc, chunk, *)
    xr = x.transpose(0, 2, 1, 3).reshape(B, H, nc, chunk, P)
    dtr = dt.transpose(0, 2, 1).reshape(B, H, nc, chunk, 1)
    br = b_mat.reshape(B, nc, chunk, N)
    cr = c_mat.reshape(B, nc, chunk, N)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nc, chunk, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, a.astype(jnp.float32), br, cr, d.astype(jnp.float32))
    return out.reshape(B, H, S, P).transpose(0, 2, 1, 3)
