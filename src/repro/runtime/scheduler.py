"""Continuous-batching scheduler — now a trace-replay adapter over
:class:`~repro.runtime.engine.ServingEngine`.

The plan cache (PR 1) made steady-state serving cheap *per request*; the
coalescing scheduler (PR 2) made it cheap *per token*; the KV pool (PR 3/4)
made batching token-level over paged arenas. PR 5 moved the whole request
lifecycle — admission, mid-decode joins, group formation, decode ticks,
token streaming, cancellation, stop conditions — into the engine, so this
module keeps only what is specific to *offline trace replay*: feed a
pre-sorted ``(arrival_s, request)`` trace into a live engine against a
virtual clock that skips idle gaps, and collect the completion records.

:class:`RequestQueue` / :class:`QueuedRequest` (bucket-aware head-of-line
fair coalescing) live in ``repro.runtime.engine`` now and are re-exported
here for compatibility — the engine is their real home because *every*
serving front door admits through them.
"""

from __future__ import annotations

import random
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.runtime.engine import (Clock, QueuedRequest,  # noqa: F401
                                  RequestQueue, ServingEngine, VirtualClock)
from repro.runtime.engine_config import _UNSET, EngineConfig
from repro.runtime.metrics import SchedulerMetrics
from repro.runtime.serve_loop import PlanServer, ServeRequest


class ContinuousBatchingScheduler:
    """Replays an arrival trace through a :class:`ServingEngine`.

    Kept as the batch-mode front door (benches, offline evaluation): the
    engine itself serves *online* traffic — ``submit`` at any time,
    ``stream``/``events`` for tokens, ``cancel`` for early exits — while
    this adapter preserves the PR-2 contract: ``run(arrivals)`` consumes a
    whole trace and returns one completion record per request. Observable
    results are unchanged; the tick structure (admit due arrivals → joins →
    form at most one group → one decode step per active group) lives in
    ``ServingEngine.step``, and the replay loop itself is
    ``ServingEngine.run`` (shared with the router via ``EngineClient``).
    Configuration flows through :class:`EngineConfig`; the per-knob kwargs
    are deprecated shims.
    """

    def __init__(
        self,
        server: PlanServer,
        *,
        config: Optional[EngineConfig] = None,
        max_group_batch: int = _UNSET,
        slo_ms: float = _UNSET,
        queue: Optional[RequestQueue] = None,
        join_mid_decode: bool = _UNSET,
        clock: Optional[Clock] = None,
    ):
        self.engine = ServingEngine(
            server, config=config, max_group_batch=max_group_batch,
            slo_ms=slo_ms, queue=queue, join_mid_decode=join_mid_decode,
            clock=clock or VirtualClock())

    # engine views (the adapter adds no state of its own) ------------------
    @property
    def server(self) -> PlanServer:
        return self.engine.server

    @property
    def queue(self) -> RequestQueue:
        return self.engine.queue

    @property
    def metrics(self) -> SchedulerMetrics:
        return self.engine.metrics

    @property
    def join_mid_decode(self) -> bool:
        return self.engine.join_mid_decode

    @property
    def active(self):
        return self.engine.active

    @property
    def results(self) -> List[Dict[str, Any]]:
        return self.engine.results

    # ----------------------------------------------------------------------
    def run(self, arrivals: Iterable[Tuple[float, ServeRequest]],
            on_event=None) -> List[Dict[str, Any]]:
        """Serve a stream of ``(arrival_s, request)`` pairs to completion.

        Returns one record per request (completion order). Arrivals are
        submitted into the live engine when due on its clock; between
        arrivals the engine ticks, and an idle engine skips ahead to the
        next arrival instead of sleeping (virtual clock).

        ``on_event(ev)``: optional per-:class:`TokenEvent` callback, called
        for every event each tick emits — the hook streaming consumers and
        cancellation drivers (``serve.py --cancel-after``) use without
        re-implementing this replay loop.
        """
        return self.engine.run(arrivals, on_event=on_event)

    def summary(self) -> str:
        return self.engine.summary()


def simulate_arrivals(
    requests: Sequence[ServeRequest],
    rate_per_s: float = 0.0,
    seed: int = 0,
) -> List[Tuple[float, ServeRequest]]:
    """Stamp requests with Poisson-process arrival times at ``rate_per_s``
    (exponential inter-arrival gaps, seeded). ``rate_per_s <= 0`` means a
    closed burst: everything arrives at t=0 (maximal coalescing pressure).
    """
    if rate_per_s <= 0:
        return [(0.0, r) for r in requests]
    rng = random.Random(seed)
    t = 0.0
    out = []
    for r in requests:
        t += rng.expovariate(rate_per_s)
        out.append((t, r))
    return out
