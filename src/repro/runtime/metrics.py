"""Step metrics / throughput accounting + serving-path counters.

The plan-cache counters (:class:`PlanCacheMetrics`) live next to the cache
in ``repro.core.plan_cache``; they are re-exported here so the runtime layer
has one metrics surface, and :func:`serve_summary` renders them together
with per-request latency."""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.config import HardwareSpec, InputShape, MeshConfig, ModelConfig, TPU_V5E
from repro.core.cost import model_flops_per_step
from repro.core.plan_cache import PlanCacheMetrics  # noqa: F401  (re-export)


@dataclass
class StepTimer:
    model: Optional[ModelConfig] = None
    shape: Optional[InputShape] = None
    mesh: Optional[MeshConfig] = None
    hw: HardwareSpec = TPU_V5E
    history: List[Dict] = field(default_factory=list)
    _t0: float = 0.0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int, metrics: Dict) -> Dict:
        dt = time.perf_counter() - self._t0
        rec = {"step": step, "seconds": dt}
        rec.update({k: float(v) for k, v in metrics.items()})
        if self.model is not None and self.shape is not None:
            flops = model_flops_per_step(self.model, self.shape)
            rec["tokens_per_s"] = self.shape.global_batch * self.shape.seq_len / dt
            if self.mesh is not None:
                rec["mfu"] = flops / dt / (self.mesh.num_devices * self.hw.peak_flops)
        self.history.append(rec)
        return rec

    def summary(self) -> Dict:
        if not self.history:
            return {}
        n = len(self.history)
        keys = self.history[-1].keys()
        return {k: sum(h.get(k, 0.0) for h in self.history) / n
                for k in keys if k != "step"}


@dataclass
class LatencyStats:
    """Per-request latency accumulator for the serving stream."""

    samples: List[float] = field(default_factory=list)

    def record(self, seconds: float) -> None:
        self.samples.append(float(seconds))

    @property
    def count(self) -> int:
        return len(self.samples)

    def mean(self) -> float:
        return sum(self.samples) / len(self.samples) if self.samples else 0.0

    def percentile(self, q: float) -> float:
        """Ceil-based nearest-rank percentile: the smallest sample with at
        least ``q``% of the distribution at or below it. The previous
        ``int(round(q/100 * (n-1)))`` indexing went through Python's
        banker's rounding, which on small sample counts rounds half-way
        ranks *down* to the even index — flattering p50/p95 by picking the
        lower sample. Nearest-rank never reports below the true rank."""
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        idx = min(len(xs) - 1, max(0, math.ceil(q / 100.0 * len(xs)) - 1))
        return xs[idx]

    def summary(self) -> str:
        ms = 1e3
        return (f"requests={self.count} mean={self.mean() * ms:.2f}ms "
                f"p50={self.percentile(50) * ms:.2f}ms "
                f"p95={self.percentile(95) * ms:.2f}ms")


def serve_summary(cache: PlanCacheMetrics, latency: LatencyStats) -> str:
    """One-line serving report: plan-cache counters + request latency."""
    return (f"plan_cache: hits={cache.hits} misses={cache.misses} "
            f"evictions={cache.evictions} compiles={cache.compiles} "
            f"recompiles={cache.recompiles} hit_rate={cache.hit_rate:.2f} "
            f"compile_s={cache.compile_seconds:.2f}  |  {latency.summary()}")


@dataclass
class SchedulerMetrics:
    """Continuous-batching accounting: queueing vs. execution latency per
    request, coalescing effectiveness, and SLO attainment.

    ``slo_s`` is the per-request total-latency objective (admission to last
    token); 0 disables SLO accounting. ``batch_slots_used`` /
    ``batch_slots_total`` measure how well coalescing fills each group's
    batch-bucket capacity (the anti-padding story: sequential serving pads
    every request up to its own bucket alone)."""

    slo_s: float = 0.0
    admitted: int = 0
    completed: int = 0
    groups: int = 0
    coalesced_requests: int = 0     # requests that shared a group
    joins: int = 0                  # requests absorbed mid-decode
    join_rows: int = 0              # arena rows filled by mid-decode joins
    peak_resident: int = 0          # max concurrently in-flight requests
    batch_slots_used: int = 0       # sum of member request batches
    batch_slots_total: int = 0      # sum of group batch-bucket capacities
    cancelled: int = 0              # requests terminated by cancel()
    early_exits: int = 0            # completed before max_tokens (eos/stop)
    slo_met: int = 0
    slo_missed: int = 0
    queue_latency: LatencyStats = field(default_factory=LatencyStats)
    exec_latency: LatencyStats = field(default_factory=LatencyStats)
    total_latency: LatencyStats = field(default_factory=LatencyStats)
    # streaming-consumer latencies: admission -> first token, and the gap
    # between consecutive token events of one request
    ttft_latency: LatencyStats = field(default_factory=LatencyStats)
    itl_latency: LatencyStats = field(default_factory=LatencyStats)

    @property
    def bucket_fill(self) -> float:
        """Fraction of coalesced batch-bucket slots holding real requests."""
        return (self.batch_slots_used / self.batch_slots_total
                if self.batch_slots_total else 0.0)

    @property
    def slo_attainment(self) -> float:
        judged = self.slo_met + self.slo_missed
        return self.slo_met / judged if judged else 1.0

    def observe_group(self, member_batches, bucket_batch: int) -> None:
        self.groups += 1
        if len(member_batches) > 1:
            self.coalesced_requests += len(member_batches)
        self.batch_slots_used += sum(member_batches)
        self.batch_slots_total += bucket_batch

    def observe_joins(self, member_batches) -> None:
        """Mid-decode joins: requests absorbed into free rows of an
        in-flight group. Tracked separately from ``bucket_fill`` — that
        ratio stays an admission-time fill fraction (<= 1.0); joins reuse
        slots the group already paid for, and their utilization shows up
        in the pool occupancy line instead."""
        self.joins += len(member_batches)
        self.join_rows += sum(member_batches)

    def observe_resident(self, live_requests: int) -> None:
        """Track the peak number of concurrently in-flight requests — the
        residency the pool budget actually admitted (the paged-vs-dense
        fragmentation benchmark gates on this)."""
        self.peak_resident = max(self.peak_resident, live_requests)

    def observe_first_token(self, ttft_s: float) -> None:
        """Time-to-first-token: request admission to its first TokenEvent
        (the latency a streaming consumer actually perceives)."""
        self.ttft_latency.record(ttft_s)

    def observe_token_gap(self, gap_s: float) -> None:
        """Inter-token latency: gap between consecutive token events of
        one request (steady-state streaming cadence)."""
        self.itl_latency.record(gap_s)

    def observe_request(self, queue_s: float, exec_s: float) -> None:
        self.completed += 1
        total = queue_s + exec_s
        self.queue_latency.record(queue_s)
        self.exec_latency.record(exec_s)
        self.total_latency.record(total)
        if self.slo_s > 0:
            if total <= self.slo_s:
                self.slo_met += 1
            else:
                self.slo_missed += 1

    def summary(self) -> str:
        ms = 1e3
        line = (f"scheduler: admitted={self.admitted} "
                f"completed={self.completed} groups={self.groups} "
                f"coalesced={self.coalesced_requests} "
                f"joins={self.joins} join_rows={self.join_rows} "
                f"peak_resident={self.peak_resident} "
                f"bucket_fill={self.bucket_fill:.2f}  |  "
                f"queue p50={self.queue_latency.percentile(50) * ms:.1f}ms "
                f"p95={self.queue_latency.percentile(95) * ms:.1f}ms  "
                f"exec p50={self.exec_latency.percentile(50) * ms:.1f}ms "
                f"p95={self.exec_latency.percentile(95) * ms:.1f}ms")
        if self.ttft_latency.count:
            line += (f"  |  ttft p50={self.ttft_latency.percentile(50) * ms:.1f}ms "
                     f"p95={self.ttft_latency.percentile(95) * ms:.1f}ms  "
                     f"itl p50={self.itl_latency.percentile(50) * ms:.1f}ms "
                     f"p95={self.itl_latency.percentile(95) * ms:.1f}ms")
        if self.cancelled or self.early_exits:
            line += (f"  |  cancelled={self.cancelled} "
                     f"early_exits={self.early_exits}")
        if self.slo_s > 0:
            line += (f"  |  slo<{self.slo_s * ms:.0f}ms: "
                     f"met={self.slo_met} missed={self.slo_missed} "
                     f"attainment={self.slo_attainment:.2f}")
        return line


def merge_scheduler_metrics(parts) -> "SchedulerMetrics":
    """Fleet rollup: one :class:`SchedulerMetrics` summing N replicas'
    counters and pooling their latency samples (percentiles over the
    merged distribution, not averages of per-replica percentiles — an
    idle replica must not dilute a hot one's p95). ``peak_resident`` sums
    per-replica peaks: an upper bound on fleet-wide concurrent residency
    (the peaks need not have coincided)."""
    parts = list(parts)
    out = SchedulerMetrics(slo_s=parts[0].slo_s if parts else 0.0)
    for m in parts:
        out.admitted += m.admitted
        out.completed += m.completed
        out.groups += m.groups
        out.coalesced_requests += m.coalesced_requests
        out.joins += m.joins
        out.join_rows += m.join_rows
        out.peak_resident += m.peak_resident
        out.batch_slots_used += m.batch_slots_used
        out.batch_slots_total += m.batch_slots_total
        out.cancelled += m.cancelled
        out.early_exits += m.early_exits
        out.slo_met += m.slo_met
        out.slo_missed += m.slo_missed
        for dst, src in ((out.queue_latency, m.queue_latency),
                         (out.exec_latency, m.exec_latency),
                         (out.total_latency, m.total_latency),
                         (out.ttft_latency, m.ttft_latency),
                         (out.itl_latency, m.itl_latency)):
            dst.samples.extend(src.samples)
    return out


@dataclass
class RouterMetrics:
    """EngineRouter accounting: where requests were placed and why, plus
    the failover counters (``resubmitted`` requests moved off ``drained``
    replicas with zero loss — the bench gate checks the zero)."""

    placements: Dict[str, int] = field(default_factory=dict)
    failovers: int = 0             # drain_replica invocations
    resubmitted: int = 0           # live requests moved to survivors
    drained: int = 0               # replicas currently draining

    def observe_placement(self, reason: str) -> None:
        self.placements[reason] = self.placements.get(reason, 0) + 1

    def summary(self) -> str:
        placed = ",".join(f"{k}={v}"
                          for k, v in sorted(self.placements.items()))
        return (f"placements[{placed}] failovers={self.failovers} "
                f"resubmitted={self.resubmitted} drained={self.drained}")


def router_summary(router) -> str:
    """Multi-line fleet report: one line per replica (its scheduler
    counters, TTFT tail, and device-clock time), that replica's KV-pool
    line, then the fleet rollup over the merged metrics."""
    ms = 1e3
    lines = [f"router: replicas={len(router.replicas)} "
             f"placement={router.config.placement} "
             f"{router.router_metrics.summary()}"]
    for r in router.replicas:
        m = r.engine.metrics
        flag = " DRAINING" if r.draining else ""
        lines.append(
            f"replica[{r.idx}]{flag}: admitted={m.admitted} "
            f"completed={m.completed} groups={m.groups} joins={m.joins} "
            f"ttft_p95={m.ttft_latency.percentile(95) * ms:.1f}ms "
            f"device_t={r.clock.now():.3f}s")
        lines.append("  " + pool_summary(r.server.pool).replace("\n", "\n  "))
    lines.append("fleet: " + merge_scheduler_metrics(
        [r.engine.metrics for r in router.replicas]).summary())
    return "\n".join(lines)


def pool_summary(pool) -> str:
    """KV-cache pool report (``repro.runtime.kv_cache``): arena churn, row
    reuse, live occupancy — plus, for paged pools, page churn and internal
    fragmentation (slack inside leased pages)."""
    m = pool.metrics
    mib = 1024 ** 2
    line = (f"kv_pool: arenas={m.arenas_created} reused={m.arenas_reused} "
            f"denied={m.arenas_denied} rows={m.rows_leased} "
            f"rows_reused={m.rows_reused} handoffs={m.handoff_writes} "
            f"occupancy={pool.occupancy():.2f} "
            f"live={pool.live_bytes() / mib:.1f}MiB "
            f"peak={m.peak_bytes / mib:.1f}MiB")
    if getattr(pool, "paged", False):
        line += (f"\nkv_pages: size={pool.page_size} "
                 f"leased={m.pages_leased} freed={m.pages_freed} "
                 f"denied={m.pages_denied} reclaimed={m.pages_reclaimed} "
                 f"peak={m.peak_pages} live={pool.pages_live()} "
                 f"frag={1.0 - pool.slot_utilization():.2f}")
    return line


def scheduler_summary(sched: "SchedulerMetrics", cache: PlanCacheMetrics,
                      latency: LatencyStats, pool=None) -> str:
    """Scheduler accounting, optional KV-pool line, plan-cache line."""
    lines = [sched.summary()]
    if pool is not None:
        lines.append(pool_summary(pool))
    lines.append(serve_summary(cache, latency))
    return "\n".join(lines)


def format_metrics(rec: Dict) -> str:
    parts = []
    for k, v in rec.items():
        if isinstance(v, float):
            parts.append(f"{k}={v:.4g}")
        else:
            parts.append(f"{k}={v}")
    return "  ".join(parts)
