"""Row-addressable, block-granular (paged) KV-cache pool for serving.

The decode KV cache is the serving path's single largest memory object, yet
the seed treated it as a per-group throwaway blob: every group called
``model.init_cache`` itself, prefill state was discarded, and the planner
never saw the bytes. This module gives the cache a single owner:

- :class:`CacheArena` — one bucket-shaped cache pytree whose *batch rows*
  are individually leasable. Rows at different generation depths coexist in
  one arena because the decode step takes a per-row position vector.
- :class:`BlockAllocator` — free-list of fixed-size *pages* inside an
  arena's sequence dimension (vLLM-style paging). Rows lease pages on
  demand as their position advances; a row's page table maps logical slot
  ``i`` to physical slot ``table[i // page] * page + i % page``.
- :class:`KVCachePool` — owns every arena: leases them to request groups,
  recycles fully-freed arenas (no reallocation), scatters prefill-produced
  cache rows into leased arenas (the prefill→decode handoff write), and
  accounts live bytes for the planner. A leased arena's free rows are where
  the scheduler lands mid-decode joins.

With ``page_size > 0`` the attention K/V entries lose their per-row
sequence dimension: one flat ``(L, n_pages * page, Kv, Dh)`` slot stack is
shared by every row of the arena, and each row only *commits* the pages its
request span actually needs. A 70-token request inside a 512-slot bucket
therefore pins ~2 pages, not 512 slots — the pool's live bytes (what the
planner sees, what the byte budget charges) become page-exact. Recurrent
rows (SSD state, RG-LRU state, conv tails, enc-dec cross K/V) keep their
single-state per-row fast path: they are O(1) in the sequence dimension and
paging them would buy nothing.

The pool's live bytes feed :class:`~repro.core.strategies.RuntimeStats`
(``cache_pool_bytes``): when the pool outgrows the plan's compile-time
cache statistic, dynamic recompilation triggers exactly like an
activation-watermark breach (``core.plan_cache.recompile_reasons``).

Budgets (``max_arenas`` / ``max_bytes``) bound the pool the way an HBM
reservation would: ``acquire`` refuses new leases beyond the budget (the
scheduler then queues the group — or joins its requests into free rows and
free pages of in-flight arenas instead, which is the whole point).
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PoolMetrics:
    """Pool-level accounting surfaced through ``scheduler_summary``."""

    arenas_created: int = 0
    arenas_reused: int = 0      # leases served from the free pool
    arenas_denied: int = 0      # acquire refused by budget
    arenas_evicted: int = 0     # free arenas dropped (LRU cap / budget)
    rows_leased: int = 0
    rows_reused: int = 0        # leased rows whose arena had a prior tenant
    handoff_writes: int = 0     # prefill→decode row scatters
    peak_bytes: float = 0.0
    pages_leased: int = 0       # page-grant churn (cumulative)
    pages_freed: int = 0
    pages_denied: int = 0       # joins/admissions refused for lack of pages
    pages_reclaimed: int = 0    # pages (leased + undrawn reservation) given
    #                             back by early exits: cancel / eos / stop
    peak_pages: int = 0         # max concurrently committed pages

    def as_dict(self) -> Dict[str, float]:
        return {
            "arenas_created": self.arenas_created,
            "arenas_reused": self.arenas_reused,
            "arenas_denied": self.arenas_denied,
            "arenas_evicted": self.arenas_evicted,
            "rows_leased": self.rows_leased,
            "rows_reused": self.rows_reused,
            "handoff_writes": self.handoff_writes,
            "peak_bytes": self.peak_bytes,
            "pages_leased": self.pages_leased,
            "pages_freed": self.pages_freed,
            "pages_denied": self.pages_denied,
            "pages_reclaimed": self.pages_reclaimed,
            "peak_pages": self.peak_pages,
        }


class BlockAllocator:
    """Free-list allocator over an arena's physical pages.

    ``reserve``/``alloc(from_reserve=True)`` split admission-time capacity
    checks from on-demand page grants: a row reserves every page its span
    can ever need when it is admitted (so mid-decode growth can never
    starve), then draws pages from that reservation one at a time as its
    position crosses page boundaries.

    Free pages live in a min-heap (lowest-index-first grants) mirrored by a
    set, so the per-tick grant path is O(log n) and double-free detection
    O(1) — long-context arenas can hold thousands of pages.
    """

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._heap: List[int] = list(range(n_pages))  # already heap-ordered
        self._free_set = set(self._heap)
        self.reserved = 0

    @property
    def free_count(self) -> int:
        return len(self._free_set)

    @property
    def available(self) -> int:
        """Pages admittable to *new* tenants (free minus reservations)."""
        return len(self._free_set) - self.reserved

    def alloc(self, n: int, *, from_reserve: bool = False) -> Optional[List[int]]:
        if from_reserve:
            if n > self.reserved or n > len(self._free_set):
                return None
            self.reserved -= n
        elif n > self.available:
            return None
        pages = [heapq.heappop(self._heap) for _ in range(n)]
        self._free_set.difference_update(pages)
        return pages

    def reserve(self, n: int) -> bool:
        if n > self.available:
            return False
        self.reserved += n
        return True

    def unreserve(self, n: int) -> None:
        self.reserved = max(0, self.reserved - n)

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p in self._free_set:
                raise ValueError(f"page {p} double-freed")
            heapq.heappush(self._heap, p)
            self._free_set.add(p)


class CacheArena:
    """One bucket-shaped cache whose batch rows are individually leasable.

    ``cache`` is the live pytree threaded through the jitted decode step;
    the pool replaces it wholesale on handoff writes. Row bookkeeping
    (which rows are leased, which pages each row holds) is host-side — the
    device arrays never need to know, because free rows are simply masked
    out by their position vector and their outputs ignored.

    In paged mode (``page > 0``) the arena additionally owns a
    :class:`BlockAllocator` over ``n_pages`` physical pages and a device
    page-table ``tables`` of shape ``(batch, max_pages)`` int32 (sentinel
    ``n_pages`` marks unallocated entries; gathers through it are masked,
    scatters through it are dropped).
    """

    def __init__(self, batch: int, seq: int, cache: Dict[str, Any],
                 nbytes: float, *, page: int = 0, sc: int = 0,
                 n_pages: int = 0, page_nbytes: float = 0.0,
                 row_nbytes: float = 0.0, rotating: bool = False,
                 paged_keys: Sequence[str] = ()):
        self.batch = batch
        self.seq = seq
        self.cache = cache
        self.nbytes = nbytes            # full-capacity bytes (dense charge)
        self.generation = 0             # completed leases of this arena
        self._free: List[int] = list(range(batch))
        # -- paging state ---------------------------------------------------
        self.page = page
        self.sc = sc                    # logical cache slots per row
        self.n_pages = n_pages
        self.page_nbytes = page_nbytes  # bytes of one page across the stack
        self.row_nbytes = row_nbytes    # per-row bytes of non-paged entries
        self.rotating = rotating        # rotating-window slot semantics
        self.paged_keys = tuple(paged_keys)
        self.allocator = BlockAllocator(n_pages) if page else None
        self.max_pages = max(1, -(-sc // page)) if page else 0
        self._row_pages: Dict[int, List[int]] = {}
        self._row_reserved: Dict[int, int] = {}
        self._row_slots: Dict[int, int] = {}   # valid slots (frag metric)
        if page and n_pages:
            self._tables_np = np.full((batch, self.max_pages), n_pages,
                                      np.int32)
            self._tables = jnp.asarray(self._tables_np)
            self._tables_dirty = False
        else:
            # no paged entries (pure-recurrent families): rows are the only
            # granularity, accounting stays row-exact, tables are unused
            self._tables_np = None
            self._tables = None
            self._tables_dirty = False

    # -- row bookkeeping ---------------------------------------------------
    @property
    def rows_free(self) -> int:
        return len(self._free)

    @property
    def rows_used(self) -> int:
        return self.batch - len(self._free)

    def alloc_rows(self, n: int) -> Optional[List[int]]:
        """Lease ``n`` rows (lowest-index first); None if not enough free."""
        if n > len(self._free):
            return None
        self._free.sort()
        rows, self._free = self._free[:n], self._free[n:]
        return rows

    def free_rows(self, rows: Sequence[int]) -> None:
        for r in rows:
            if r in self._free:
                raise ValueError(f"row {r} double-freed")
            self._free.append(r)

    # -- donation handoff --------------------------------------------------
    def relinquish(self) -> Dict[str, Any]:
        """Hand the cache pytree to a (possibly donating) decode step: the
        arena drops its reference so the step argument is the only live
        handle — a donating jit then consumes the buffers in place, and no
        stale reference can read them mid-step. The tick must
        :meth:`adopt` the step's cache output before anything else touches
        the arena."""
        if self.cache is None:
            raise RuntimeError(
                "arena cache already relinquished and not re-adopted")
        cache, self.cache = self.cache, None
        return cache

    def adopt(self, cache: Dict[str, Any]) -> None:
        """Re-adopt the decode step's cache output as the arena's live
        pytree (the other half of :meth:`relinquish`)."""
        if self.cache is not None:
            raise RuntimeError("arena already holds a live cache pytree")
        self.cache = cache

    # -- paging ------------------------------------------------------------
    @property
    def pages_leased(self) -> int:
        return sum(len(p) for p in self._row_pages.values())

    @property
    def pages_committed(self) -> int:
        """Leased plus reserved pages — the arena's committed capacity."""
        if self.allocator is None:
            return 0
        return self.pages_leased + self.allocator.reserved

    def span_pages(self, span: int) -> int:
        """Pages a row occupying ``span`` logical slots needs end-to-end."""
        if not self.page or not self.n_pages:
            return 0
        return -(-min(max(1, span), self.sc) // self.page)

    def live_nbytes(self) -> float:
        """Page-exact committed bytes: leased+reserved pages plus the
        per-row (recurrent / cross) state of leased rows."""
        if not self.page:
            return self.nbytes
        return (self.pages_committed * self.page_nbytes
                + self.rows_used * self.row_nbytes)

    def used_slots(self) -> int:
        return sum(self._row_slots.values())

    @property
    def tables(self):
        """Device page-table array, re-uploaded lazily: row admissions and
        page grants mutate the host table and only mark it dirty, so a
        batch of per-row updates costs one host->device transfer at the
        next decode step instead of one per row."""
        if self._tables_dirty:
            self._tables = jnp.asarray(self._tables_np)
            self._tables_dirty = False
        return self._tables

    def _sync_tables(self) -> None:
        self._tables_dirty = True

    def admit_row(self, row: int, prompt: int, span: int,
                  eager: bool = False) -> List[int]:
        """Commit a row's paging state: lease pages covering its initial
        valid slots (the prompt plus the first decode write — or the whole
        span with ``eager``) and reserve the rest of its span so on-demand
        growth can never starve mid-decode. Returns the leased pages."""
        if not self.page or not self.n_pages:
            return []
        total = self.span_pages(span)
        init_slots = min(span, self.sc) if eager else min(prompt + 1, self.sc)
        init_pages = min(total, -(-init_slots // self.page))
        if self.allocator.available < total:
            raise RuntimeError(
                f"KV page invariant violated: row {row} needs {total} pages "
                f"but arena {self.batch}x{self.seq} has only "
                f"{self.allocator.available} available "
                f"({self.allocator.free_count} free, "
                f"{self.allocator.reserved} reserved)")
        pages = self.allocator.alloc(init_pages)
        self.allocator.reserve(total - init_pages)
        self._row_pages[row] = list(pages)
        self._row_reserved[row] = total - init_pages
        self._row_slots[row] = init_slots
        self._tables_np[row, :len(pages)] = pages
        self._sync_tables()
        return pages

    def ensure_slot(self, row: int, lslot: int) -> Optional[int]:
        """Grant the page covering logical slot ``lslot`` to ``row`` from
        its admission-time reservation (no-op when already granted).
        Returns the newly granted physical page, if any."""
        if not self.page or not self.n_pages:
            return None
        lp = lslot // self.page
        pages = self._row_pages.get(row)
        if pages is None:
            raise RuntimeError(f"row {row} decodes without page admission")
        self._row_slots[row] = min(self.sc, max(self._row_slots[row],
                                                lslot + 1))
        if lp < len(pages):
            return None
        if lp != len(pages):
            raise RuntimeError(
                f"row {row} skipped a page boundary: wants logical page "
                f"{lp}, holds {len(pages)}")
        got = self.allocator.alloc(1, from_reserve=True)
        if got is None:
            raise RuntimeError(
                f"KV page reservation invariant violated: row {row} has no "
                f"reserved page left for logical page {lp}")
        pages.append(got[0])
        self._row_reserved[row] -= 1
        self._tables_np[row, lp] = got[0]
        self._sync_tables()
        return got[0]

    def reserved_for(self, rows: Sequence[int]) -> int:
        """Undrawn span-reservation pages still held for ``rows`` — the
        capacity an early exit hands back without it ever being leased."""
        return sum(self._row_reserved.get(r, 0) for r in rows)

    def release_row_pages(self, rows: Sequence[int]) -> int:
        """Return rows' pages (and outstanding reservations) to the
        allocator; returns how many leased pages were freed."""
        if not self.page or not self.n_pages:
            return 0
        freed = 0
        for r in rows:
            pages = self._row_pages.pop(r, None)
            if pages is None:
                continue
            self.allocator.free(pages)
            self.allocator.unreserve(self._row_reserved.pop(r, 0))
            self._row_slots.pop(r, None)
            self._tables_np[r, :] = self.n_pages
            freed += len(pages)
        if freed:
            self._sync_tables()
        return freed

    def phys_slots(self, rows: Sequence[int], sc: Optional[int] = None
                   ) -> np.ndarray:
        """(len(rows), sc) physical slot index per logical slot, with the
        out-of-range sentinel for slots on unallocated pages (host-side;
        used by the handoff scatter and row zeroing)."""
        sc = self.sc if sc is None else sc
        tab = self._tables_np[np.asarray(list(rows), np.int32)]
        i = np.arange(sc)
        phys = tab[:, np.minimum(i // self.page, self.max_pages - 1)]
        return phys * self.page + (i % self.page)[None, :]


class KVCachePool:
    """Single owner of decode-cache construction for a serving session.

    ``max_arenas`` / ``max_bytes`` (0 = unbounded) cap the pool;
    ``acquire(..., force=True)`` overrides the cap so a scheduler with no
    in-flight work can always make progress. Fully-freed arenas are kept
    for recycling up to ``max_free`` buckets (LRU-evicted beyond that, and
    evicted early whenever their bytes stand between a new lease and the
    budget) — retired shape buckets cannot pin HBM forever.

    ``page_size > 0`` turns on block-granular paging: attention K/V becomes
    a flat per-arena slot stack, rows commit only the pages their span
    needs, and ``live_bytes`` (what the byte budget charges and the planner
    observes) is page-exact instead of bucket-shaped.
    """

    def __init__(self, model, *, max_arenas: int = 0, max_bytes: float = 0.0,
                 max_free: int = 4, page_size: int = 0):
        self.model = model
        self.max_arenas = max_arenas
        self.max_bytes = max_bytes
        self.max_free = max(1, max_free)
        self.page_size = max(0, int(page_size))
        self.metrics = PoolMetrics()
        self._leased: List[CacheArena] = []
        # LRU order: least-recently released first (eviction order)
        self._pooled: List[CacheArena] = []
        self._params: Dict[tuple, tuple] = {}   # (b, s) -> paging params

    @property
    def paged(self) -> bool:
        return self.page_size > 0

    # -- sizing ------------------------------------------------------------
    def arena_bytes(self, batch: int, seq: int) -> float:
        """Exact bytes of one dense (batch, seq) arena, from the model's
        cache entry specs (no array materialization)."""
        total = 0.0
        for shape, _axes, dt in self.model.cache_entries(batch, seq).values():
            total += math.prod(shape) * np.dtype(dt).itemsize
        return total

    def _arena_params(self, batch: int, seq: int):
        """(entries, sc, n_pages, page_nbytes, row_nbytes, nbytes) for a
        paged (batch, seq) arena — one cached spec walk, no array
        materialization."""
        key = (batch, seq)
        if key not in self._params:
            ent, n_pages, sc = self.model.paged_cache_entries(
                batch, seq, self.page_size)
            page_nbytes = 0.0
            row_nbytes = 0.0
            total = 0.0
            for k, (shape, _axes, dt) in ent.items():
                nb = math.prod(shape) * np.dtype(dt).itemsize
                total += nb
                if self.model.is_paged_cache_key(k):
                    page_nbytes += nb / max(1, n_pages)
                else:
                    row_nbytes += nb / batch
            self._params[key] = (ent, sc, n_pages, page_nbytes, row_nbytes,
                                 total)
        return self._params[key]

    def span_pages(self, seq: int, span: int) -> int:
        """Pages one row of a ``seq``-bucket arena needs for ``span``."""
        if not self.paged:
            return 0
        _ent, sc, n_pages, _pb, _rb, _total = self._arena_params(1, seq)
        if not n_pages:
            return 0
        return -(-min(max(1, span), sc) // self.page_size)

    def member_bytes(self, seq: int, batch_rows: int, span: int) -> float:
        """Page-exact bytes one member commits: its rows' recurrent state
        plus its span's pages per row (the admission/join budget unit)."""
        if not self.paged:
            return 0.0
        _ent, sc, _n, page_nbytes, row_nbytes = self._arena_params(1, seq)[:5]
        pages = self.span_pages(seq, span)
        return batch_rows * (row_nbytes + pages * page_nbytes)

    def live_bytes(self) -> float:
        """Bytes currently committed to request groups (page-exact when
        paged: leased+reserved pages plus leased rows' recurrent state)."""
        return sum(a.live_nbytes() for a in self._leased)

    def bytes_room(self) -> float:
        """Byte budget headroom for further commitments (inf: unbounded)."""
        if not self.max_bytes:
            return math.inf
        return max(0.0, self.max_bytes - self.live_bytes())

    def total_bytes(self) -> float:
        """Leased plus pooled-free bytes (what the pool actually charges:
        page-exact for paged arenas — a fully-freed paged arena holds no
        committed pages, so recycling it is free)."""
        return self.live_bytes() + sum(a.live_nbytes() for a in self._pooled
                                       if not a.page)

    @property
    def arena_count(self) -> int:
        return len(self._leased) + len(self._pooled)

    def occupancy(self) -> float:
        """Fraction of leased-arena rows holding live requests."""
        total = sum(a.batch for a in self._leased)
        used = sum(a.rows_used for a in self._leased)
        return used / total if total else 0.0

    def slot_utilization(self) -> float:
        """Fraction of leased page slots holding valid cache entries (the
        internal-fragmentation complement, at page-grant granularity)."""
        leased = sum(a.pages_leased for a in self._leased) * self.page_size
        used = sum(a.used_slots() for a in self._leased)
        return used / leased if leased else 1.0

    def pages_live(self) -> int:
        return sum(a.pages_committed for a in self._leased)

    # -- lease lifecycle ---------------------------------------------------
    def _evict_free(self, count: int = 1) -> int:
        """Drop up to ``count`` least-recently-released free arenas (their
        device buffers go with them). Returns how many were evicted."""
        n = min(count, len(self._pooled))
        if n:
            del self._pooled[:n]
            self.metrics.arenas_evicted += n
        return n

    def _budget_blocks(self, nbytes: float) -> bool:
        if self.max_arenas and self.arena_count >= self.max_arenas:
            return True
        if self.max_bytes and self.total_bytes() + nbytes > self.max_bytes:
            return True
        return False

    def can_acquire(self, batch: int, seq: int,
                    demand_bytes: Optional[float] = None) -> bool:
        pooled = any((a.batch, a.seq) == (batch, seq) for a in self._pooled)
        if self.paged:
            need = (demand_bytes if demand_bytes is not None
                    else self.member_bytes(seq, batch, seq))
            if self.max_bytes and self.live_bytes() + need > self.max_bytes:
                return False
            if pooled:
                return True
            if self.max_arenas and len(self._leased) >= self.max_arenas:
                return False
            return True
        if pooled:
            return True
        nbytes = self.arena_bytes(batch, seq)
        if not self._budget_blocks(nbytes):
            return True
        # free arenas of other buckets are evictable — only *leased* memory
        # can genuinely refuse a lease
        if self.max_arenas and len(self._leased) >= self.max_arenas:
            return False
        if self.max_bytes and self.live_bytes() + nbytes > self.max_bytes:
            return False
        return True

    def _build_arena(self, batch: int, seq: int) -> CacheArena:
        if not self.paged:
            return CacheArena(batch, seq,
                              self.model.init_cache(batch, seq),
                              self.arena_bytes(batch, seq))
        ent, sc, n_pages, page_nbytes, row_nbytes, nbytes = \
            self._arena_params(batch, seq)
        cache = {k: jnp.zeros(s, d) for k, (s, _a, d) in ent.items()}
        paged_keys = tuple(k for k in ent
                           if self.model.is_paged_cache_key(k))
        rotating = self.model.decode_window(seq) > 0
        return CacheArena(batch, seq, cache, nbytes, page=self.page_size,
                          sc=sc, n_pages=n_pages, page_nbytes=page_nbytes,
                          row_nbytes=row_nbytes, rotating=rotating,
                          paged_keys=paged_keys)

    def acquire(self, batch: int, seq: int, *, zero: bool = False,
                force: bool = False,
                demand_bytes: Optional[float] = None) -> Optional[CacheArena]:
        """Lease a (batch, seq) arena. A fully-freed arena of the same
        bucket is recycled without reallocation; otherwise a fresh one is
        built — evicting idle free arenas first if they stand between the
        lease and the budget (None when still refused and not ``force``).

        ``zero``: clear recycled state, for tenants that decode from a zero
        cache instead of overwriting their rows via a handoff write.
        ``demand_bytes``: the page-exact bytes the lease will immediately
        commit (paged pools charge admissions, not arena capacity)."""
        arena = next((a for a in self._pooled
                      if (a.batch, a.seq) == (batch, seq)), None)
        if self.paged and not force:
            # paged budget: charge the admission's committed bytes (rows +
            # span pages), never the arena's worst-case capacity
            need = demand_bytes if demand_bytes is not None else 0.0
            blocked = bool(self.max_bytes
                           and self.live_bytes() + need > self.max_bytes)
            if arena is None and self.max_arenas:
                while (self.arena_count >= self.max_arenas
                       and self._evict_free()):
                    pass
                blocked = blocked or self.arena_count >= self.max_arenas
            if blocked:
                self.metrics.arenas_denied += 1
                return None
        if arena is not None:
            self._pooled.remove(arena)
            if zero:
                arena.cache = jax.tree.map(jnp.zeros_like, arena.cache)
            self.metrics.arenas_reused += 1
        else:
            if not self.paged:
                nbytes = self.arena_bytes(batch, seq)
                while self._budget_blocks(nbytes) and self._evict_free():
                    pass
                if not force and self._budget_blocks(nbytes):
                    self.metrics.arenas_denied += 1
                    return None
            arena = self._build_arena(batch, seq)
            self.metrics.arenas_created += 1
        self._leased.append(arena)
        self.metrics.peak_bytes = max(self.metrics.peak_bytes,
                                      self.total_bytes())
        return arena

    def alloc_rows(self, arena: CacheArena, n: int) -> Optional[List[int]]:
        rows = arena.alloc_rows(n)
        if rows is not None:
            self.metrics.rows_leased += n
            if arena.generation:
                self.metrics.rows_reused += n
        return rows

    def admit_request_rows(self, arena: CacheArena, n_rows: int, *,
                           prompt: int, span: int, eager: bool = False,
                           where: str = "admit_request_rows") -> List[int]:
        """The one paged-row admission sequence: lease ``n_rows`` rows and
        commit each one's paging state (prompt-covering pages now, span
        reservation for the rest — everything with ``eager``). Every
        admission path goes through here; the PR-4 recycled-arena ``zero=``
        leak was exactly this sequence drifting between ``PlanServer.handle``
        and the scheduler. A ``None`` row lease means admission accounting
        upstream (free-row check, join predicate) is out of sync with the
        arena — fail loudly with context instead of letting a ``TypeError``
        surface deep inside the caller."""
        rows = self.alloc_rows(arena, n_rows)
        if rows is None:
            raise RuntimeError(
                f"KV pool row invariant violated in {where}: request needs "
                f"{n_rows} rows but arena {arena.batch}x{arena.seq} has only "
                f"{arena.rows_free} free ({arena.rows_used} leased)")
        for r in rows:
            self.admit_row(arena, r, prompt=prompt, span=span, eager=eager)
        return rows

    def admit_row(self, arena: CacheArena, row: int, *, prompt: int,
                  span: int, eager: bool = False) -> None:
        """Commit a leased row's pages: lease the prompt-covering pages now
        (everything with ``eager``) and reserve the rest of its span."""
        if not arena.page:
            return
        pages = arena.admit_row(row, prompt, span, eager=eager)
        self.metrics.pages_leased += len(pages)
        self.metrics.peak_pages = max(self.metrics.peak_pages,
                                      self.pages_live())
        self.metrics.peak_bytes = max(self.metrics.peak_bytes,
                                      self.total_bytes())

    def ensure_decode_slots(self, arena: CacheArena, rows: Sequence[int],
                            pos: int) -> None:
        """Grant the page covering the next write position to ``rows``
        (no-op off-page-boundary; draws from admission reservations)."""
        if not arena.page or not arena.n_pages:
            return
        if not arena.rotating and pos >= arena.sc:
            return  # out-of-capacity writes drop; nothing to grant
        lslot = pos % arena.sc if arena.rotating else pos
        granted = 0
        for r in rows:
            if arena.ensure_slot(r, lslot) is not None:
                granted += 1
        if granted:
            self.metrics.pages_leased += granted
            self.metrics.peak_pages = max(self.metrics.peak_pages,
                                          self.pages_live())

    def free_rows(self, arena: CacheArena, rows: Sequence[int], *,
                  early: bool = False) -> None:
        """Return rows (and their pages + undrawn span reservation) to the
        arena. ``early``: the tenant exited before its full span — cancel /
        eos / stop-sequence — so the released capacity is *reclaimed*
        headroom the byte budget and join admission see the same tick."""
        arena.free_rows(rows)
        undrawn = arena.reserved_for(rows) if early else 0
        freed = arena.release_row_pages(rows)
        self.metrics.pages_freed += freed
        if early:
            self.metrics.pages_reclaimed += freed + undrawn

    def release(self, arena: CacheArena) -> None:
        """Return a leased arena to the free pool (rows need not be freed
        individually first — a release ends the whole lease). The free pool
        is LRU-capped at ``max_free`` arenas."""
        self._leased.remove(arena)
        self.metrics.pages_freed += arena.release_row_pages(
            list(arena._row_pages))
        arena._free = list(range(arena.batch))
        arena.generation += 1
        self._pooled.append(arena)
        if len(self._pooled) > self.max_free:
            self._evict_free(len(self._pooled) - self.max_free)

    # -- the handoff write -------------------------------------------------
    def write_rows(self, arena: CacheArena, rows: Sequence[int],
                   cache: Dict[str, Any],
                   src_rows: Optional[Sequence[int]] = None) -> None:
        """Scatter ``cache`` rows (a prefill-populated *dense* cache at the
        same bucket shape) into ``rows`` of the arena — the prefill→decode
        handoff. Every dense cache leaf is layer-stacked ``(L, B, ...)``,
        so the batch row is axis 1. Rows are fully overwritten, which is
        why recycled arenas need no zeroing on this path. Paged entries
        scatter through the rows' page tables; slots on pages a row never
        committed (beyond its span) hold only zeros in the prefill output
        and are dropped."""
        rows_l = list(rows)
        rows_a = jnp.asarray(rows_l, jnp.int32)
        src_a = jnp.asarray(list(src_rows) if src_rows is not None
                            else list(range(len(rows_l))), jnp.int32)
        if set(cache) != set(arena.cache):
            raise ValueError(
                f"cache keys {sorted(cache)} != arena keys {sorted(arena.cache)}")
        out = {}
        phys, phys_sc = None, -1
        for k, v in arena.cache.items():
            src = jnp.take(cache[k], src_a, axis=1).astype(v.dtype)
            if arena.page and k in arena.paged_keys:
                sc = min(arena.sc, src.shape[2])
                if phys is None or phys_sc != sc:
                    phys = jnp.asarray(
                        arena.phys_slots(rows_l, sc).reshape(-1), jnp.int32)
                    phys_sc = sc
                flat = src[:, :, :sc].reshape(
                    src.shape[0], len(rows_l) * sc, *src.shape[3:])
                out[k] = v.at[:, phys].set(flat, mode="drop")
            else:
                out[k] = v.at[:, rows_a].set(src)
        arena.cache = out
        self.metrics.handoff_writes += 1

    def zero_rows(self, arena: CacheArena, rows: Sequence[int]) -> None:
        """Clear ``rows`` state in place — for tenants without a handoff
        write landing on rows recycled mid-lease (a completed member's
        rows/pages) whose recurrent state would otherwise leak into them."""
        rows_l = list(rows)
        rows_a = jnp.asarray(rows_l, jnp.int32)
        out = {}
        phys = None
        for k, v in arena.cache.items():
            if arena.page and k in arena.paged_keys:
                if phys is None:
                    phys = jnp.asarray(
                        arena.phys_slots(rows_l).reshape(-1), jnp.int32)
                zeros = jnp.zeros((v.shape[0], phys.shape[0], *v.shape[2:]),
                                  v.dtype)
                out[k] = v.at[:, phys].set(zeros, mode="drop")
            else:
                zeros = jnp.zeros((v.shape[0], len(rows_l), *v.shape[2:]),
                                  v.dtype)
                out[k] = v.at[:, rows_a].set(zeros)
        arena.cache = out
