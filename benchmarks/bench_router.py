"""EngineRouter benchmark: fleet throughput scaling and failover safety.

The router's claim is topological: N replicas behind the one
``EngineClient`` surface should serve a saturating workload ~N times
faster in *device time* (each replica's :class:`ReplicaClock` accrues
only its own compute, so co-simulated replicas genuinely overlap), and a
replica drain mid-flight must lose nothing — withdrawn requests finish
on the survivors with the exact token streams an undisturbed run
produces.

Scenario A (gated) — closed-burst throughput, 2 replicas vs 1 engine on
the same 16-request mixed-context trace. The burst maximizes coalescing
pressure and keeps the ratio stable; Poisson traces at moderate rates
leave both systems mostly idle and the ratio is dominated by scheduling
noise (measured: unusable spread), so rates are reported but not gated.
Both systems are warmed twice on the *identical* trace first so no plan
compile lands inside the measurement (gate: recompile delta == 0), and
trials are interleaved pairs with the gate on the median per-pair ratio.

Scenario B (gated) — failover: replica 1 is drained once it holds live
work that has streamed >= 2 tokens; every request must still complete,
with resubmissions > 0 and streamed tokens byte-identical to an
undisturbed single-engine decode of the same shapes.

Acceptance targets (CI-enforced):

- 2-replica fleet >= 1.8x single-engine throughput (median pair ratio);
- fleet TTFT p95 <= 1.05x single-engine TTFT p95 on the same trace;
- failover: zero requests lost, tokens byte-identical, resubmitted > 0;
- zero recompiles inside the measured region.

    PYTHONPATH=src python benchmarks/bench_router.py [--smoke]

Prints ``name,us_per_call,derived`` CSV rows (harness contract), writes
``BENCH_router.json`` (with scenario metadata: arch, replicas, arrival
rate, git revision), and exits non-zero below any gate.
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys

import numpy as np

from repro.configs import get_config
from repro.runtime.engine import ServingEngine
from repro.runtime.engine_config import EngineConfig
from repro.runtime.metrics import LatencyStats
from repro.runtime.router import EngineRouter
from repro.runtime.scheduler import (ContinuousBatchingScheduler,
                                     simulate_arrivals)
from repro.runtime.serve_loop import ServeRequest

try:
    from benchmarks.bench_meta import scenario_meta
except ImportError:  # run as a script from the benchmarks/ directory
    from bench_meta import scenario_meta

TARGET_SPEEDUP = 1.8
TTFT_TOLERANCE = 1.05
REPLICAS = 2
RESULTS_JSON = "BENCH_router.json"


def _trace(n: int, new_tokens: int = 8):
    reqs = [ServeRequest(1, 40 + 4 * (i % 5), new_tokens) for i in range(n)]
    return simulate_arrivals(reqs, 0.0)


def _makespan(results, arrivals) -> float:
    t_arr = {r.rid: t for t, r in arrivals}
    return max(t_arr[rec["rid"]] + rec["total_s"] for rec in results)


def _throughput(smoke: bool, model, cfg):
    """Scenario A: single engine vs 2-replica router, paired trials on
    the identical closed-burst trace."""
    n_req = 12 if smoke else 16
    trials = 4 if smoke else 6

    srv_single = cfg.build_server(model)
    servers = [cfg.build_server(model) for _ in range(REPLICAS)]

    # double warmup on the measurement trace: every plan the measured
    # region needs is compiled (and verified below via recompile delta)
    for _ in range(2):
        ServingEngine(srv_single, config=cfg).run(_trace(n_req))
        EngineRouter(servers, config=cfg).run(_trace(n_req))
    rc0 = (srv_single.metrics.recompiles
           + sum(s.metrics.recompiles for s in servers))

    ratios = []
    single_ttft, fleet_ttft = [], []
    single_ms = router_ms = None
    placements = {}
    for _ in range(trials):
        arr = _trace(n_req)
        eng = ServingEngine(srv_single, config=cfg)
        ms1 = _makespan(eng.run(arr), arr)
        single_ttft.extend(eng.metrics.ttft_latency.samples)
        arr = _trace(n_req)
        router = EngineRouter(servers, config=cfg)
        ms2 = _makespan(router.run(arr), arr)
        fleet_ttft.extend(router.metrics.ttft_latency.samples)
        placements = dict(router.router_metrics.placements)
        ratios.append(ms1 / ms2)
        single_ms = ms1 if single_ms is None else min(single_ms, ms1)
        router_ms = ms2 if router_ms is None else min(router_ms, ms2)
    speedup = statistics.median(ratios)
    recompiles = (srv_single.metrics.recompiles
                  + sum(s.metrics.recompiles for s in servers) - rc0)

    p95_single = LatencyStats(samples=single_ttft).percentile(95)
    p95_fleet = LatencyStats(samples=fleet_ttft).percentile(95)
    return {
        "n_requests": n_req, "trials": trials, "ratios": ratios,
        "speedup": speedup, "single_makespan_s": single_ms,
        "router_makespan_s": router_ms, "recompiles": recompiles,
        "ttft_p95_single_s": p95_single, "ttft_p95_fleet_s": p95_fleet,
        "placements": placements,
    }


def _failover(smoke: bool, model, cfg):
    """Scenario B: drain replica 1 while it holds streaming work; the
    survivors must finish everything, byte-identical to an undisturbed
    single-engine run of the same shapes."""
    shapes = [(1, 40, 10), (1, 44, 10), (1, 52, 10),
              (1, 40, 10), (1, 56, 10), (1, 48, 10)]
    if not smoke:
        shapes = shapes * 2

    # undisturbed reference decode per shape (params are seed-derived and
    # greedy decode is group-composition-invariant, so one clean run per
    # shape is the ground truth for every replica)
    ref_srv = cfg.build_server(model)
    reqs_ref = [ServeRequest(*s) for s in shapes]
    ref = {}
    for rec in ContinuousBatchingScheduler(ref_srv).run(
            simulate_arrivals(reqs_ref)):
        ref[rec["rid"]] = np.asarray(rec["tokens"])
    by_shape = {}
    for r, s in zip(reqs_ref, shapes):
        by_shape.setdefault(s, ref[r.rid])

    router = EngineRouter(
        [cfg.build_server(model) for _ in range(REPLICAS)], config=cfg)
    reqs = [ServeRequest(*s) for s in shapes]
    arr = simulate_arrivals(reqs, rate_per_s=200, seed=3)
    streamed = {}
    fired = {"done": False}

    def on_event(ev):
        # drain once replica 1 holds live work that has streamed tokens
        if (not fired["done"] and ev.token is not None and ev.index >= 2
                and any(h.replica.idx == 1
                        for h in router.handles.values() if h.replica)):
            router.drain_replica(1)
            fired["done"] = True
        if ev.token is not None:
            streamed.setdefault(ev.rid, []).append(np.asarray(ev.token))

    res = router.run(arr, on_event=on_event)
    equal = len(res) == len(reqs)
    for r, s in zip(reqs, shapes):
        toks = np.concatenate(streamed[r.rid], axis=1)
        rec = next(x for x in res if x["rid"] == r.rid)
        if (not np.array_equal(toks, by_shape[s])
                or not np.array_equal(toks, np.asarray(rec["tokens"]))):
            equal = False
    return {
        "n_requests": len(reqs), "completed": len(res),
        "drained": fired["done"],
        "resubmitted": router.router_metrics.resubmitted,
        "tokens_equal": equal,
        "placements": dict(router.router_metrics.placements),
    }


def _measure(smoke: bool, arch: str):
    model = get_config(arch)
    cfg = EngineConfig(replicas=REPLICAS)
    thr = _throughput(smoke, model, cfg)
    fo = _failover(smoke, model, cfg)

    n = thr["n_requests"]
    rows = [
        f"router_single,{thr['single_makespan_s'] / n * 1e6:.0f},"
        f"makespan_s={thr['single_makespan_s']:.3f}",
        f"router_fleet,{thr['router_makespan_s'] / n * 1e6:.0f},"
        f"makespan_s={thr['router_makespan_s']:.3f};"
        f"speedup_x={thr['speedup']:.2f};target>={TARGET_SPEEDUP};"
        f"replicas={REPLICAS}",
        f"router_ttft,{thr['ttft_p95_fleet_s'] * 1e6:.0f},"
        f"single_p95_us={thr['ttft_p95_single_s'] * 1e6:.0f};"
        f"tolerance_x={TTFT_TOLERANCE}",
        f"router_failover,{fo['resubmitted']},"
        f"completed={fo['completed']}/{fo['n_requests']};"
        f"tokens_equal={int(fo['tokens_equal'])}",
    ]
    return rows, thr, fo


def run(smoke: bool = False, arch: str = "yi-6b-smoke"):
    """Harness entry point (benchmarks/run.py contract): CSV rows only."""
    return _measure(smoke, arch)[0]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fewer requests/trials for CI")
    ap.add_argument("--arch", default="yi-6b-smoke")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    rows, thr, fo = _measure(args.smoke, args.arch)
    for row in rows:
        print(row, flush=True)

    ok = True
    if thr["speedup"] < TARGET_SPEEDUP:
        print(f"FAIL: {REPLICAS}-replica speedup {thr['speedup']:.2f}x < "
              f"{TARGET_SPEEDUP}x target", file=sys.stderr)
        ok = False
    ttft_limit = thr["ttft_p95_single_s"] * TTFT_TOLERANCE
    if thr["ttft_p95_fleet_s"] > ttft_limit:
        print(f"FAIL: fleet TTFT p95 {thr['ttft_p95_fleet_s'] * 1e3:.1f}ms >"
              f" {ttft_limit * 1e3:.1f}ms (single x{TTFT_TOLERANCE})",
              file=sys.stderr)
        ok = False
    if thr["recompiles"]:
        print(f"FAIL: {thr['recompiles']} recompiles inside the measured "
              f"region (warmup should have compiled every plan)",
              file=sys.stderr)
        ok = False
    if fo["completed"] != fo["n_requests"]:
        print(f"FAIL: failover lost requests "
              f"({fo['completed']}/{fo['n_requests']} completed)",
              file=sys.stderr)
        ok = False
    if not fo["tokens_equal"]:
        print("FAIL: failover token streams diverged from the undisturbed "
              "run", file=sys.stderr)
        ok = False
    if not fo["resubmitted"]:
        print("FAIL: drain moved nothing (scenario did not exercise "
              "failover)", file=sys.stderr)
        ok = False

    with open(RESULTS_JSON, "w") as f:
        json.dump({
            "bench": "router", "smoke": args.smoke, "arch": args.arch,
            "meta": scenario_meta(args.arch, replicas=REPLICAS,
                                  arrival_rate=0.0),
            "rows": rows, "ok": ok,
            "gates": {
                "fleet_speedup": {"value": thr["speedup"],
                                  "target": TARGET_SPEEDUP},
                "ttft_p95_ratio": {
                    "value": (thr["ttft_p95_fleet_s"]
                              / thr["ttft_p95_single_s"]
                              if thr["ttft_p95_single_s"] else 0.0),
                    "target": TTFT_TOLERANCE},
                "recompiles": {"value": thr["recompiles"], "target": 0},
                "failover_completed": {"value": fo["completed"],
                                       "target": fo["n_requests"]},
                "failover_tokens_equal": {"value": bool(fo["tokens_equal"]),
                                          "target": True},
                "failover_resubmitted": {"value": fo["resubmitted"],
                                         "target": ">0"},
            },
            "detail": {"throughput": thr, "failover": fo},
        }, f, indent=2)
        f.write("\n")
    print(f"# results -> {RESULTS_JSON}", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
