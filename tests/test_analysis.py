"""repro.analysis (PR 7): every lint rule vs. a seeded violation fixture
plus the clean-tree gate, the plan auditor's planted-violation self-test
and memory-statistics sandwich, EngineConfig validation errors, and the
runtime sanitizer — planted-corruption detection plus full engine/router
scenarios (submit / stream / cancel / EOS / drain / failover) run with
``sanitize=True`` across the serving families."""

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # minimal images: seeded deterministic fallback
    from repro.testing.hypothesis_compat import given, settings, st

from repro.analysis.cost_audit import (audit_cell, check_explain_axes,
                                       check_selection_monotonic,
                                       trace_closure_certificate)
from repro.analysis.lint import DEFAULT_ROOTS, lint_paths, lint_source
from repro.analysis.matrix import merge_report, smoke_cells
from repro.analysis.sanitize import (SanitizeError, check_engine, check_pool,
                                     recount_live_bytes)
from repro.config import InputShape, MeshConfig
from repro.configs import get_config
from repro.core.plan_cache import BucketPolicy, bucket_pow2
from repro.core.planner import PlanCompiler
from repro.core.strategies import PLAN_AXES
from repro.runtime.engine_config import EngineConfig
from repro.runtime.serve_loop import ServeRequest

FAMILIES = ["yi-6b-smoke", "mamba2-1.3b-smoke", "recurrentgemma-2b-smoke"]


# ---------------------------------------------------------------------------
# invariant linter: each rule detects its seeded violation
# ---------------------------------------------------------------------------


def _rules(findings):
    return {f.rule for f in findings}


def test_lint_local_import_seeded():
    src = "def f():\n    import os\n    return os.getpid()\n"
    assert _rules(lint_source(src, "src/repro/core/x.py")) == {"local-import"}


def test_lint_init_cache_outside_pool_seeded():
    src = "def f(model):\n    return model.init_cache(4, 128)\n"
    found = lint_source(src, "src/repro/runtime/rogue.py")
    assert _rules(found) == {"init-cache-outside-pool"}
    # the module that defines the pool is blessed
    assert lint_source(src, "src/repro/runtime/kv_cache.py") == []


def test_lint_admission_outside_pool_seeded():
    src = "def f(pool, arena):\n    return pool.alloc_rows(arena, 2)\n"
    found = lint_source(src, "src/repro/runtime/rogue.py")
    assert _rules(found) == {"admission-outside-pool"}


def test_lint_rid_mint_seeded():
    src = ("def f(req):\n"
           "    req.rid = 7\n"
           "def g():\n"
           "    global _NEXT_RID\n"
           "    _NEXT_RID += 1\n")
    found = lint_source(src, "src/repro/runtime/rogue.py")
    assert _rules(found) == {"rid-mint"}
    assert len(found) >= 2  # both the .rid assign and the counter touch
    # serve_loop itself constructs rids
    assert lint_source(src, "src/repro/runtime/serve_loop.py") == []


def test_lint_tracer_host_sync_seeded():
    src = ("import numpy as np\n"
           "def step(x):\n"
           "    a = x.item()\n"
           "    b = float(x)\n"
           "    c = np.asarray(x)\n"
           "    return a, b, c\n")
    found = lint_source(src, "src/repro/models/rogue.py")
    assert _rules(found) == {"tracer-host-sync"}
    assert len(found) == 3
    # only tick-path modules are in scope: host-side code may materialize
    assert lint_source(src, "src/repro/runtime/metrics.py") == []


def test_lint_plan_cache_mutation_seeded():
    src = "def f(cache, key, plan):\n    cache._entries[key] = plan\n"
    found = lint_source(src, "src/repro/runtime/rogue.py")
    assert _rules(found) == {"plan-cache-mutation"}
    assert lint_source(src, "src/repro/core/plan_cache.py") == []


def test_lint_waiver_suppresses_finding():
    src = ("def f():\n"
           "    import os  # lint: allow-local-import\n"
           "    return os.getpid()\n")
    assert lint_source(src, "src/repro/core/x.py") == []


def test_lint_clean_tree_is_green():
    """The CI gate: zero findings over the shipped tree (satellite: every
    pre-existing violation was fixed or explicitly waived)."""
    found = lint_paths(DEFAULT_ROOTS)
    assert found == [], "\n".join(str(f) for f in found)


# ---------------------------------------------------------------------------
# plan auditor: planted violations + memory sandwich (slow: traces plans)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", FAMILIES)
def test_plan_audit_clean_cell_with_memory_bound(arch):
    """Zero findings per family on the clean tree, and the compile-time
    estimate sits inside [certified floor, reuse-free ceiling]."""
    from repro.analysis.plan_audit import audit_cell

    rec, findings = audit_cell(arch, "bfloat16", "decode", 1, 64)
    assert findings == [], "\n".join(str(f) for f in findings)
    mem = rec["memory"]
    assert mem["covered"], mem
    assert mem["floor_bytes"] <= mem["estimate_bytes"] <= mem["ceiling_bytes"]


def test_plan_audit_flags_planted_violations():
    """The acceptance fixtures: an injected fp32 constant in a bf16 decode
    step and an injected host callback are both flagged; the un-tampered
    control cell stays clean."""
    from repro.analysis.plan_audit import selftest

    st = selftest()
    assert st["clean_control"], st
    assert st["fp32_const_flagged"], st
    assert st["host_callback_flagged"], st


# ---------------------------------------------------------------------------
# EngineConfig validation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw,match", [
    ({"dtype": "float16"}, "dtype"),
    ({"bucket_select": "lifo"}, "bucket_select"),
    ({"placement": "random"}, "placement"),
    ({"replicas": 0}, "replicas"),
    ({"cache_capacity": 0}, "cache_capacity"),
    ({"recompile_margin": -0.1}, "recompile_margin"),
    ({"page_size": -1}, "page_size"),
    ({"pool_arenas": 0}, "pool_arenas"),
    ({"pool_max_arenas": -1}, "pool caps"),
    ({"pool_max_bytes": -1.0}, "pool caps"),
    ({"max_group_batch": 0}, "max_group_batch"),
    ({"slo_ms": -5.0}, "slo_ms"),
])
def test_engine_config_rejects_invalid(kw, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(**kw)


def test_engine_config_sanitize_field_defaults_off():
    assert EngineConfig().sanitize is False
    assert EngineConfig(sanitize=True).sanitize is True


# ---------------------------------------------------------------------------
# runtime sanitizer: planted corruption is detected
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def live_engine():
    """A sanitized engine with one group mid-decode (module-scoped: the
    corruption tests below tamper and restore around it)."""
    cfg = get_config("yi-6b-smoke")
    ecfg = EngineConfig(sanitize=True, cache_capacity=8)
    eng = ecfg.build_engine(ecfg.build_server(cfg))
    eng.submit(ServeRequest(1, 60, 64))
    eng.step()
    eng.step()
    assert eng.active, "expected an in-flight group"
    return eng


def test_sanitizer_clean_mid_flight(live_engine):
    assert check_engine(live_engine) == []


def test_sanitizer_catches_page_double_lease(live_engine):
    arena = live_engine.active[0].arena
    row = next(iter(arena._row_pages))
    page = arena._row_pages[row][0]
    arena._row_pages[row].append(page)  # same page leased twice
    try:
        found = check_pool(live_engine.server.pool)
        assert "page-double-lease" in _rules(found)
        assert "page-leak" in _rules(found)  # conservation breaks too
        with pytest.raises(SanitizeError):
            live_engine._sanitize()
    finally:
        arena._row_pages[row].pop()
    assert check_engine(live_engine) == []


def test_sanitizer_catches_orphaned_page_lease(live_engine):
    arena = live_engine.active[0].arena
    row = next(iter(arena._row_pages))
    arena._free.append(row)  # row "freed" while still holding pages
    try:
        found = check_engine(live_engine)
        assert "page-orphan" in _rules(found)
        assert "row-lease-drift" in _rules(found)
    finally:
        arena._free.remove(row)
    assert check_engine(live_engine) == []


def test_sanitizer_catches_live_bytes_drift(live_engine):
    arena = live_engine.active[0].arena
    arena.allocator.reserved += 1  # incremental counter drifts from rows
    try:
        found = check_pool(live_engine.server.pool)
        assert "reserve-drift" in _rules(found)
        assert "live-bytes-drift" in _rules(found)
    finally:
        arena.allocator.reserved -= 1


def test_sanitizer_catches_handle_leak(live_engine):
    live_engine.handles[999_999] = object()  # retired-but-tracked handle
    try:
        found = check_engine(live_engine)
        assert "handle-leak" in _rules(found)
    finally:
        del live_engine.handles[999_999]
    assert check_engine(live_engine) == []


def test_sanitizer_recount_matches_live_bytes(live_engine):
    pool = live_engine.server.pool
    assert recount_live_bytes(pool) == pytest.approx(pool.live_bytes())


# ---------------------------------------------------------------------------
# sanitized scenarios: the existing engine/router flows, sanitize=True
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", FAMILIES)
def test_sanitized_engine_scenario(arch):
    """Submit / stream / cancel / EOS / drain with per-tick sanitizer
    assertions enabled: every transition must keep the invariants."""
    cfg = get_config(arch)
    ecfg = EngineConfig(sanitize=True, cache_capacity=8)
    eng = ecfg.build_engine(ecfg.build_server(cfg))
    reqs = [ServeRequest(1, 24, 6),
            ServeRequest(2, 28, 6),
            ServeRequest(1, 24, 6, eos_id=0),  # may stop early on EOS
            ServeRequest(1, 30, 8)]
    handles = [eng.submit(r) for r in reqs]
    seen = 0
    for ev in eng.events():
        if ev.token is not None:
            seen += 1
            if ev.rid == handles[3].rid and ev.index >= 1:
                eng.cancel(handles[3])  # client hangs up mid-decode
    recs = eng.drain()
    assert seen > 0
    assert len(recs) == len(reqs)
    by_rid = {r["rid"]: r for r in recs}
    assert by_rid[handles[3].rid]["finish_reason"] == "cancelled"
    assert eng.idle and not eng.handles  # nothing leaked past retirement
    assert eng.server.pool.live_bytes() == 0.0


@pytest.mark.parametrize("arch", ["yi-6b-smoke", "mamba2-1.3b-smoke"])
def test_sanitized_router_scenario_with_failover(arch):
    """Two sanitized replicas: placement, work stealing, a mid-run
    drain_replica failover, and fleet drain — per-tick assertions at both
    the replica and router levels."""
    cfg = get_config(arch)
    ecfg = EngineConfig(sanitize=True, replicas=2, cache_capacity=8)
    client = ecfg.build_client(cfg)
    handles = [client.submit(ServeRequest(1, 24, 5)) for _ in range(6)]
    client.step()
    client.step()
    moved = client.drain_replica(0)
    recs = client.drain()
    assert len(recs) == len(handles)
    assert all(r["tokens"].shape[1] > 0 for r in recs)
    # drained replica's live work moved, nobody was dropped
    assert {r["rid"] for r in recs} == {h.rid for h in handles}
    assert all(h.replica.idx == 1 for h in moved)
    for r in client.replicas:
        assert r.engine.server.pool.live_bytes() == 0.0


def test_serve_launcher_accepts_sanitize_flag():
    """--sanitize folds into EngineConfig.from_args (field-name match)."""
    import argparse

    ns = argparse.Namespace(sanitize=True, dtype="float32")
    assert EngineConfig.from_args(ns).sanitize is True


# ---------------------------------------------------------------------------
# PR 9: use-after-donation lint rule
# ---------------------------------------------------------------------------


_DONATION_BAD = """
def tick(group, srv):
    logits, new_cache = group.entry.step_fn(
        srv.params, group.arena.cache, group.toks, group.pos)
    stale = group.arena.cache["layer0.k"]
    group.arena.cache = new_cache
    return logits, stale
"""


def test_lint_use_after_donation_seeded():
    """A cache reference read after being passed to a donating step and
    before rebinding is flagged — but only in tick-path modules."""
    found = lint_source(_DONATION_BAD, "src/repro/runtime/engine_x.py")
    assert _rules(found) == {"use-after-donation"}
    # non-tick modules (analysis tooling, tests) are out of scope
    assert lint_source(_DONATION_BAD, "src/repro/analysis/fixture.py") == []


def test_lint_use_after_donation_clean_idioms():
    """The sanctioned shapes stay clean: rebind through the call's own
    assignment, rebind before any read, and untrackable (consumed at the
    call site) arguments."""
    rebind = ("def tick(entry, params, cache, toks, pos):\n"
              "    logits, cache = entry.step_fn(params, cache, toks, pos)\n"
              "    return logits, cache\n")
    assert lint_source(rebind, "src/repro/runtime/engine_x.py") == []
    consumed = ("def tick(group, srv):\n"
                "    logits, out = group.entry.step_fn(\n"
                "        srv.params, group.arena.relinquish(), group.toks,\n"
                "        group.pos)\n"
                "    group.arena.adopt(out)\n"
                "    return logits\n")
    assert lint_source(consumed, "src/repro/runtime/engine_x.py") == []
    rebound_first = ("def tick(group, srv, fresh):\n"
                     "    logits, out = group.entry.step_fn(\n"
                     "        srv.params, group.cache, group.toks, group.pos)\n"
                     "    group.cache = out\n"
                     "    return logits, group.cache\n")
    assert lint_source(rebound_first, "src/repro/runtime/engine_x.py") == []


def test_lint_use_after_donation_tracks_through_branch_join():
    """A donation inside an ``if`` branch is tracked past the join point
    into the parent block (the engine's paged/dense split)."""
    src = ("def tick(group, srv, paged):\n"
           "    if paged:\n"
           "        logits, out = group.entry.step_fn(\n"
           "            srv.params, group.cache, group.toks, group.pos,\n"
           "            group.tables)\n"
           "    else:\n"
           "        logits, out = group.entry.step_fn(\n"
           "            srv.params, group.cache, group.toks, group.pos)\n"
           "    leak = group.cache\n"
           "    group.cache = out\n"
           "    return logits, leak\n")
    found = lint_source(src, "src/repro/runtime/engine_x.py")
    assert _rules(found) == {"use-after-donation"}
    assert len(found) == 2  # both branches' donations reach the read


def test_lint_use_after_donation_waiver():
    """The explicit waiver suppresses the finding (host-side metadata
    probes like .is_deleted() are the sanctioned exception)."""
    waived = _DONATION_BAD.replace(
        'stale = group.arena.cache["layer0.k"]',
        'stale = group.arena.cache["layer0.k"]'
        '  # lint: allow-use-after-donation')
    assert lint_source(waived, "src/repro/runtime/engine_x.py") == []


# ---------------------------------------------------------------------------
# PR 9: donation-conditioned memory sandwich + memory auditor
# ---------------------------------------------------------------------------


def test_plan_audit_donated_ceiling_conditioned():
    """The reuse-free ceiling conditions on the plan's donation flags: an
    estimate that still carries the double-buffer term must overflow the
    donated (tighter) ceiling while fitting the un-donated one."""
    from repro.analysis.plan_audit import audit_memory
    import jax
    import jax.numpy as jnp

    def step(cache, x):
        return cache + x, cache * 2.0

    cache_spec = jax.ShapeDtypeStruct((1024,), jnp.float32)
    closed = jax.make_jaxpr(step)(cache_spec, cache_spec)
    donated = 1024 * 4
    # an estimate sitting just above the donated ceiling but under the
    # un-donated one (the two differ by exactly the donated bytes)
    _, under = audit_memory(closed, 4.0 * donated, 0.0, "t")
    _, over = audit_memory(closed, 4.0 * donated, 0.0, "t",
                           donated_bytes=donated)
    assert not any(f.rule == "memory-uncovered" for f in under)
    assert any(f.rule == "memory-uncovered" for f in over)
    # and the floor drops by the donated bytes too
    rec_d, _ = audit_memory(closed, 4.0 * donated, 0.0, "t",
                            donated_bytes=donated)
    rec_u, _ = audit_memory(closed, 4.0 * donated, 0.0, "t")
    assert rec_u["floor_bytes"] - rec_d["floor_bytes"] == donated


@pytest.mark.parametrize("arch", FAMILIES)
def test_memory_audit_certifies_aliasing(arch):
    """Tentpole acceptance: for each family the lowered decode executable
    aliases every cache leaf (slot stacks and/or recurrent state) onto
    its output, and the certified peak credits exactly those bytes."""
    from repro.analysis.memory_audit import DONATED_CLASSES, audit_cell

    rec, findings = audit_cell(arch, "bfloat16", 1, 64,
                               decode_kernel="paged")
    assert findings == [], "\n".join(str(f) for f in findings)
    assert rec["donate_cache"] is True
    cache_classes = [c for c in rec["classes"] if c in DONATED_CLASSES]
    assert cache_classes, rec["classes"]
    for c in cache_classes:
        assert rec["classes"][c]["lifetime"] == "aliased-in-place", rec
    cache_bytes = sum(rec["classes"][c]["bytes"] for c in cache_classes)
    assert rec["aliased_bytes"] == cache_bytes
    assert (rec["certified_peak_bytes"]
            == rec["input_bytes"] + rec["output_bytes"] - cache_bytes)


def test_memory_audit_flags_undonated_plan():
    """The planted fixture: a compiler forced to donate_cache=False
    produces a plan every cell of which is flagged cache-not-donated."""
    from repro.analysis.memory_audit import audit_cell

    rec, findings = audit_cell("yi-6b-smoke", "bfloat16", 1, 64,
                               decode_kernel="paged", donate=False)
    assert rec["donate_cache"] is False
    assert any(f.rule == "cache-not-donated" for f in findings)
    # nothing aliases without donation
    assert rec["aliased_bytes"] == 0


# ---------------------------------------------------------------------------
# PR 9: sanitized serving on donated buffers
# ---------------------------------------------------------------------------


def _run_scenario(arch, donate):
    """The full engine scenario (submit / stream / EOS / cancel / drain)
    under sanitize=True, returning records sorted by rid."""
    cfg = get_config(arch)
    ecfg = EngineConfig(sanitize=True, cache_capacity=8, donate=donate)
    eng = ecfg.build_engine(ecfg.build_server(cfg))
    reqs = [ServeRequest(1, 24, 6),
            ServeRequest(2, 28, 6),
            ServeRequest(1, 24, 6, eos_id=0),  # may stop early on EOS
            ServeRequest(1, 30, 8)]
    handles = [eng.submit(r) for r in reqs]
    for ev in eng.events():
        if (ev.token is not None and ev.rid == handles[3].rid
                and ev.index >= 1):
            eng.cancel(handles[3])  # same-tick reclamation of live rows
    recs = eng.drain()
    assert eng.idle and not eng.handles
    assert eng.server.pool.live_bytes() == 0.0
    return sorted(recs, key=lambda r: r["rid"])


@pytest.mark.parametrize("arch", FAMILIES)
def test_sanitized_donation_token_parity(arch):
    """Donated serving is byte-identical to the double-buffered path under
    the sanitizer, across the attention / SSD / hybrid families — incl.
    cancel and EOS reclaiming rows the same tick the step consumed the
    cache. XLA writing in place must not change a single logit."""
    import numpy as np

    donated = _run_scenario(arch, donate=True)
    plain = _run_scenario(arch, donate=False)
    assert len(donated) == len(plain)
    # rids are process-global mints — compare positionally (sorted order
    # is submission order in both runs)
    for d, p in zip(donated, plain):
        assert d["finish_reason"] == p["finish_reason"]
        assert (np.asarray(d["tokens"]).tobytes()
                == np.asarray(p["tokens"]).tobytes())
        # the donated run never holds the second arena copy
        assert d["watermark_bytes"] <= p["watermark_bytes"]


def test_bench_meta_artifact_revision_status(tmp_path):
    """The staleness checker: an artifact stamped with the current
    revision reads current, a different hash reads stale, a missing or
    unstamped file reads unknown (never an exception)."""
    import json
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "benchmarks"))
    try:
        import bench_meta
    finally:
        sys.path.pop(0)

    head = bench_meta.git_describe()
    current = tmp_path / "BENCH_current.json"
    current.write_text(json.dumps({"meta": {"git": head}}))
    stale = tmp_path / "BENCH_stale.json"
    stale.write_text(json.dumps({"meta": {"git": "deadbee-dirty"}}))
    unstamped = tmp_path / "BENCH_unstamped.json"
    unstamped.write_text(json.dumps({"rows": []}))

    assert bench_meta.artifact_revision_status(str(current))["status"] \
        in ("current", "unknown")  # unknown only outside a git checkout
    if head != "unknown":
        assert (bench_meta.artifact_revision_status(str(stale))["status"]
                == "stale")
        # -dirty suffixes are ignored: regenerating from the working tree
        # that becomes the next commit must not read as stale
        dirty = tmp_path / "BENCH_dirty.json"
        dirty.write_text(json.dumps(
            {"meta": {"git": bench_meta._base_rev(head) + "-dirty"}}))
        assert (bench_meta.artifact_revision_status(str(dirty))["status"]
                == "current")
    assert (bench_meta.artifact_revision_status(str(unstamped))["status"]
            == "unknown")
    assert (bench_meta.artifact_revision_status(str(tmp_path / "nope.json"))
            ["status"] == "unknown")


def test_serve_launcher_accepts_no_donate_flag():
    """--no-donate inverts into EngineConfig.donate (A/B escape hatch)."""
    import argparse

    ns = argparse.Namespace(no_donate=True, dtype="float32")
    assert EngineConfig.from_args(ns).donate is False
    ns = argparse.Namespace(no_donate=False, dtype="float32")
    assert EngineConfig.from_args(ns).donate is True


# ---------------------------------------------------------------------------
# PR 10: shared smoke matrix, cost certifier, selection-decision audits
# ---------------------------------------------------------------------------

_MESH1 = MeshConfig(shape=(1,), axis_names=("data",))


def test_matrix_smoke_cells_enumeration():
    """One authoritative cell enumeration: decode cells appear under both
    forced kernels, prefill cells only for handoff-capable archs (kernel
    pinned to auto), and ``where`` renders the canonical cell id."""
    cells = list(smoke_cells(archs=("yi-6b-smoke",), dtypes=("bfloat16",),
                             buckets=((1, 64),)))
    decode = [c for c in cells if c.kind == "decode"]
    assert sorted(c.forced_kernel for c in decode) == ["gather", "paged"]
    assert all(c.where == f"yi-6b-smoke/bfloat16/decode/b1s64/"
               f"{c.forced_kernel}" for c in decode)
    prefill = [c for c in cells if c.kind == "prefill"]
    assert [c.forced_kernel for c in prefill] == ["auto"]
    assert prefill[0].where == "yi-6b-smoke/bfloat16/prefill/b1s64"


def test_matrix_merge_report_preserves_foreign_sections(tmp_path):
    """The report is shared by three auditors: merging one section must
    not clobber the others (the historical memory_audit bug), and a
    non-dict or corrupt prior file is replaced, never crashed on."""
    import json

    path = str(tmp_path / "R.json")
    with open(path, "w") as f:
        json.dump({"memory": {"cells": 3}, "findings": []}, f)
    merged = merge_report(path, {"cost": {"ok": True}})
    with open(path) as f:
        on_disk = json.load(f)
    assert on_disk["memory"] == {"cells": 3}
    assert on_disk["findings"] == []
    assert on_disk["cost"] == {"ok": True}
    assert merged == on_disk
    # non-dict prior JSON (a bare list) is replaced wholesale
    with open(path, "w") as f:
        json.dump([1, 2], f)
    merge_report(path, {"cost": 1})
    with open(path) as f:
        assert json.load(f) == {"cost": 1}
    # corrupt JSON likewise
    with open(path, "w") as f:
        f.write("{not json")
    merge_report(path, {"memory": 2})
    with open(path) as f:
        assert json.load(f) == {"memory": 2}


def test_cost_audit_cell_sandwich_and_planted_flops():
    """A clean cell certifies (floor <= analytic <= ceiling on both the
    FLOP and traffic statistics); a drifted cost-model constant — FLOPs
    inflated past the jaxpr-derived ceiling, or deflated under the
    certified MAC floor — is flagged."""
    rec, findings = audit_cell("yi-6b-smoke", "bfloat16", "decode", 1, 64,
                               decode_kernel="gather")
    assert findings == []
    fl, tr = rec["flops"], rec["traffic"]
    assert fl["floor"] <= fl["analytic"] <= fl["ceiling"]
    assert fl["traced_macs"] > 0
    assert tr["floor_bytes"] <= tr["analytic_bytes"] <= tr["ceiling_bytes"]
    _, inflated = audit_cell("yi-6b-smoke", "bfloat16", "decode", 1, 64,
                             decode_kernel="gather", flop_scale=64.0)
    assert _rules(inflated) == {"flop-over-estimate"}
    _, deflated = audit_cell("yi-6b-smoke", "bfloat16", "decode", 1, 64,
                             decode_kernel="gather", flop_scale=1 / 64.0)
    assert _rules(deflated) == {"flop-under-estimate"}
    _, bloated = audit_cell("yi-6b-smoke", "bfloat16", "decode", 1, 64,
                            decode_kernel="gather", traffic_scale=64.0)
    assert _rules(bloated) == {"traffic-over-estimate"}


def test_cost_audit_monotonicity_checker():
    """At most one paged/gather flip along a swept statistic; the
    committed-frac axis additionally admits only paged -> gather."""
    assert check_selection_monotonic(
        [(16, "gather"), (32, "paged"), (64, "paged")], "t") == []
    doctored = [(16, "gather"), (32, "paged"), (64, "gather"),
                (128, "paged")]
    found = check_selection_monotonic(doctored, "t")
    assert _rules(found) == {"crossover-inversion"}
    # directional: raising committed pages only raises the paged cost
    wrong_way = [(0.1, "gather"), (0.9, "paged")]
    assert _rules(check_selection_monotonic(
        wrong_way, "t", axis="committed_frac")) == {"crossover-inversion"}
    right_way = [(0.1, "paged"), (0.9, "gather")]
    assert check_selection_monotonic(
        right_way, "t", axis="committed_frac") == []


def test_cost_audit_explain_completeness():
    """explain_axes() must record every PLAN_AXES entry; dropping one is
    exactly the planted violation the checker flags."""
    plan = PlanCompiler(cache_page_size=64, cache_pool_arenas=4).compile(
        get_config("yi-6b-smoke"), InputShape("t", 64, 1, "decode"),
        _MESH1, dtype="bfloat16")
    axes = plan.explain_axes()
    assert set(axes) == set(PLAN_AXES)
    assert check_explain_axes(axes, "t") == []
    dropped = dict(axes)
    dropped.pop("decode_kernel")
    found = check_explain_axes(dropped, "t")
    assert _rules(found) == {"explain-axis-missing"}
    assert "decode_kernel" in found[0].detail


def test_planner_selection_trace_matches_choice():
    """The introspection hook reproduces the compiler's actual kernel
    choice and records the statistics it was made from."""
    cfg = get_config("yi-6b-smoke")
    compiler = PlanCompiler(cache_page_size=64, cache_pool_arenas=4)
    shape = InputShape("t", 256, 4, "decode")
    trace = compiler.selection_trace(cfg, shape)
    assert trace["kernel"] in ("paged", "gather", "ref", "none")
    assert trace["reason"]
    plan = compiler.compile(cfg, shape, _MESH1, dtype="bfloat16")
    assert plan.config.decode_kernel == trace["kernel"]
    # forced compilers report the forced operator with costs untouched
    forced = PlanCompiler(cache_page_size=64, cache_pool_arenas=4,
                          decode_kernel="ref").selection_trace(cfg, shape)
    assert forced["kernel"] == "ref" and forced["forced"]


def test_cost_audit_trace_closure_certificate():
    """The jit-signature set reachable from an EngineConfig is finite:
    pow2 bucket ladders closed under re-bucketing, signature count within
    the log-product bound."""
    rec, findings = trace_closure_certificate()
    assert findings == []
    assert rec["finite"]
    assert rec["signatures"] <= rec["bound"]
    policy = BucketPolicy()
    for b in rec["batch_buckets"]:
        assert bucket_pow2(b, policy.min_batch) == b
    for s in rec["seq_buckets"]:
        assert bucket_pow2(s, policy.min_seq) == s


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=1, max_value=4096),
                          st.integers(min_value=1, max_value=8192)),
                min_size=1, max_size=64))
def test_bucket_policy_finite_for_bounded_streams(stream):
    """Any bounded request stream collapses onto a finite, idempotent
    bucket set: each bucket is a fixed point of re-bucketing (so
    recompiles mint no new jit signatures), no bucket overshoots 2x the
    request dimension (or the policy minimum), and the distinct-bucket
    count stays within the log2 product bound."""
    policy = BucketPolicy()
    buckets = {(bucket_pow2(b, policy.min_batch),
                bucket_pow2(s, policy.min_seq)) for b, s in stream}
    for bb, sb in buckets:
        assert bucket_pow2(bb, policy.min_batch) == bb
        assert bucket_pow2(sb, policy.min_seq) == sb
    for b, s in stream:
        assert bucket_pow2(b, policy.min_batch) <= 2 * max(
            b, policy.min_batch)
        assert bucket_pow2(s, policy.min_seq) <= 2 * max(s, policy.min_seq)
    # 13 batch ladder rungs (1..4096) x 10 seq rungs (16..8192)
    assert len(buckets) <= 13 * 10


def test_lint_plan_axis_in_explain_seeded():
    """The lint rule flags a PlanConfig field no explain renderer reads,
    a PlanConfig module with no renderer at all, and stays quiet when
    every axis is rendered (``notes`` exempt)."""
    dropped = (
        "class PlanConfig:\n"
        "    strategy: str = 'local'\n"
        "    decode_kernel: str = 'gather'\n"
        "    notes: tuple = ()\n"
        "class ExecutionPlan:\n"
        "    def explain_axes(self):\n"
        "        return {'strategy': self.config.strategy}\n")
    found = [f for f in lint_source(dropped)
             if f.rule == "plan-axis-in-explain"]
    assert len(found) == 1 and "decode_kernel" in found[0].detail
    no_renderer = "class PlanConfig:\n    strategy: str = 'local'\n"
    assert "plan-axis-in-explain" in _rules(lint_source(no_renderer))
    clean = (
        "class PlanConfig:\n"
        "    strategy: str = 'local'\n"
        "    decode_kernel: str = 'gather'\n"
        "    notes: tuple = ()\n"
        "class ExecutionPlan:\n"
        "    def explain_axes(self):\n"
        "        c = self.config\n"
        "        return {'strategy': c.strategy,\n"
        "                'decode_kernel': c.decode_kernel}\n")
    assert "plan-axis-in-explain" not in _rules(lint_source(clean))


def test_bench_meta_parent_revision_is_current(tmp_path):
    """An artifact stamped with HEAD's parent (the usual
    ``<parent>-dirty`` regeneration stamp — that working tree became this
    commit) reads current; anything older stays stale."""
    import json
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "benchmarks"))
    try:
        import bench_meta
    finally:
        sys.path.pop(0)

    parent = bench_meta._parent_rev()
    if not parent or bench_meta.git_describe() == "unknown":
        pytest.skip("needs a git checkout with a parent commit")
    fresh = tmp_path / "BENCH_fresh.json"
    fresh.write_text(json.dumps({"meta": {"git": parent + "-dirty"}}))
    assert (bench_meta.artifact_revision_status(str(fresh))["status"]
            == "current")
    old = tmp_path / "BENCH_old.json"
    old.write_text(json.dumps({"meta": {"git": "0000bad-dirty"}}))
    assert (bench_meta.artifact_revision_status(str(old))["status"]
            == "stale")
