"""Kernel micro-benchmarks (paper §3 "Native BLAS Exploitation"/"GPU
Backend") plus the PR-8 ``paged_decode`` scenario: end-to-end decode-step
time with the plan-selectable paged-attention operator vs the legacy
gather materialization, across context lengths and page sizes.

On this CPU container the Pallas path runs interpreted (not timed); we
time the XLA fallback operator — for ``paged`` that is
:func:`repro.kernels.paged_attention.paged_attention_xla`, which reads the
flat slot stack once and contracts grouped GQA einsums directly, where the
gather path materializes gathered K/V *and* their ``q_per_kv``-repeated
expansions every step (≈ ``(2 + 2g)x`` cache traffic). The same traffic
asymmetry is what the analytic cost model banks on when the plan compiler
picks the kernel per bucket, so the measured ratio doubles as a check on
the selection rule.

Acceptance targets (CI-enforced under ``--smoke``):

- paged decode step >= 1.5x faster than gather at the long-context cells;
- logits equivalence paged == gather == ref at every measured cell;
- zero recompiles: each jitted step traces exactly once (trace counter).

    PYTHONPATH=src python benchmarks/bench_kernels.py [--smoke]

Prints ``name,us_per_call,derived`` CSV rows (harness contract), writes the
full result set to ``BENCH_kernels.json`` (the perf-trajectory artifact CI
uploads), and exits non-zero below the gate.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TPU_V5E
from repro.configs import get_config
from repro.kernels import ref
from repro.models.model import build_model
from repro.runtime.kv_cache import KVCachePool

try:
    from benchmarks.bench_meta import scenario_meta
except ImportError:  # run as a script from the benchmarks/ directory
    from bench_meta import scenario_meta

TARGET_SPEEDUP = 1.5
RESULTS_JSON = "BENCH_kernels.json"
KEY = jax.random.PRNGKey(0)


def _time(fn, *args, reps=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


# ---------------------------------------------------------------------------
# micro-kernels (paper §3): structural roofline of the Pallas blocks
# ---------------------------------------------------------------------------


def _micro_rows():
    rows = []
    key = jax.random.PRNGKey(0)

    # matmul 1024^3, MXU tile 128: per-block VMEM = bm*bk + bk*bn + bm*bn(f32)
    a = jax.random.normal(key, (1024, 1024), jnp.bfloat16)
    b = jax.random.normal(key, (1024, 1024), jnp.bfloat16)
    us = _time(jax.jit(ref.matmul_ref), a, b)
    vmem = (128 * 128 * 2) * 2 + 128 * 128 * 4
    ai = (2 * 1024**3) / (2 * 2 * 1024 * 1024)
    rows.append(f"kernel_matmul_1024,{us:.1f},vmem_block={vmem};intensity={ai:.0f};"
                f"vmem_ok={vmem < TPU_V5E.vmem_bytes}")

    # flash attention 2x8x1024x64
    q = jax.random.normal(key, (2, 8, 1024, 64), jnp.bfloat16)
    us = _time(jax.jit(lambda q: ref.attention_ref(q, q, q)), q)
    vmem = (128 * 64 * 2) * 3 + 128 * 128 * 4 + 128 * 64 * 4
    rows.append(f"kernel_flash_attn_1k,{us:.1f},vmem_block={vmem};"
                f"vmem_ok={vmem < TPU_V5E.vmem_bytes}")

    # ssd scan: mamba2-like (chunked BLAS-3 form)
    B, S, H, P, N = 2, 512, 8, 64, 128
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    av = -jnp.exp(jax.random.normal(ks[2], (H,)))
    bm = jax.random.normal(ks[3], (B, S, N))
    cm = jax.random.normal(ks[4], (B, S, N))
    d = jnp.ones((H,))
    seq = jax.jit(lambda *a: ref.ssd_ref(*a)[0])
    chk = jax.jit(lambda *a: ref.ssd_chunked_ref(*a, chunk=64)[0])
    us_seq = _time(seq, x, dt, av, bm, cm, d, reps=3)
    us_chk = _time(chk, x, dt, av, bm, cm, d, reps=3)
    rows.append(f"kernel_ssd_sequential,{us_seq:.1f},form=scan")
    rows.append(f"kernel_ssd_chunked,{us_chk:.1f},form=blas3;"
                f"speedup={us_seq / us_chk:.2f}x")

    # conv2d im2col (the paper's lowering)
    x = jax.random.normal(key, (8, 16, 32, 32), jnp.float32)
    w = jax.random.normal(key, (32, 16, 3, 3), jnp.float32)
    us = _time(jax.jit(lambda x, w: ref.conv2d_ref(x, w, 1, 1)), x, w)
    rows.append(f"kernel_conv2d_im2col,{us:.1f},lowering=im2col")
    return rows


# ---------------------------------------------------------------------------
# paged_decode scenario (PR 8): plan-selectable operator vs legacy gather
# ---------------------------------------------------------------------------


def _counted_step(model, page, seq, kernel):
    """Jitted decode step with the kernel baked in (exactly what
    ``serve_loop.make_decode_step`` produces) plus a trace counter: the
    closure body runs once per XLA trace, so ``traces["n"]`` past the
    warmup call counts spurious recompiles."""
    traces = {"n": 0}

    def step(params, cache, tok, pos, tables):
        traces["n"] += 1
        return model.decode_step(params, cache, tok, pos, tables=tables,
                                 page=page, seq_len=seq,
                                 decode_kernel=kernel)

    return jax.jit(step), traces


def _paged_cell(cfg, b, ctx, page, reps):
    """One (batch, context, page) cell: identical paged arena, per-kernel
    jitted steps, timed back-to-back with a logits-equivalence check."""
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(KEY)
    prompt = 8  # timing is depth-independent: both operators walk all slots
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, prompt), 0,
                              cfg.vocab_size)
    lengths = jnp.full((b,), prompt, jnp.int32)
    logits, dense = model.prefill(params, toks, lengths=lengths,
                                  cache_len=ctx)
    pool = KVCachePool(model, page_size=page)
    arena = pool.acquire(b, ctx)
    rows = pool.admit_request_rows(arena, b, prompt=prompt, span=prompt + 4)
    pool.write_rows(arena, rows, dense)
    for r in rows:
        pool.ensure_decode_slots(arena, [r], prompt)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    pos = lengths

    out, us, traces = {}, {}, {}
    for kern in ("gather", "paged", "ref"):
        step, tr = _counted_step(model, page, ctx, kern)
        out[kern], _ = step(params, arena.cache, tok, pos, arena.tables)
        jax.block_until_ready(out[kern])
        if kern != "ref":  # ref is the oracle, not a production operator
            us[kern] = _time(lambda *a: step(*a)[0], params, arena.cache,
                             tok, pos, arena.tables, reps=reps)
        traces[kern] = tr

    equal = all(
        np.allclose(np.asarray(out[k]), np.asarray(out["gather"]),
                    rtol=1e-5, atol=1e-5) for k in ("paged", "ref"))
    recompiles = sum(t["n"] - 1 for t in traces.values())
    return {
        "batch": b, "ctx": ctx, "page": page,
        "paged_us": us["paged"], "gather_us": us["gather"],
        "speedup": us["gather"] / us["paged"],
        "logits_equal": bool(equal), "recompiles": recompiles,
    }


def _paged_cells(smoke: bool):
    """(batch, ctx, page, reps, gated) sweep. The gated rows are the
    long-context cells — where the gather path's materialized expansions
    dominate the step and the plan compiler flips to ``paged``."""
    if smoke:
        return [(2, 256, 64, 20, False),
                (4, 2048, 64, 10, True),
                (4, 2048, 16, 10, True)]
    return [(2, 256, 64, 30, False),
            (4, 1024, 64, 20, False),
            (4, 4096, 64, 10, True),
            (4, 4096, 16, 10, True),
            (8, 4096, 64, 5, True)]


def _paged_rows(smoke: bool, arch: str):
    cfg = get_config(arch)
    cells, rows = [], []
    for b, ctx, page, reps, gated in _paged_cells(smoke):
        cell = _paged_cell(cfg, b, ctx, page, reps)
        cell["gated"] = gated
        cells.append(cell)
        rows.append(
            f"kernel_paged_decode_b{b}_c{ctx}_p{page},{cell['paged_us']:.1f},"
            f"gather_us={cell['gather_us']:.1f};"
            f"speedup={cell['speedup']:.2f}x;"
            f"logits_equal={int(cell['logits_equal'])};"
            f"recompiles={cell['recompiles']};gated={int(gated)}")
    return rows, cells


def run(smoke: bool = True, arch: str = "yi-6b-smoke"):
    """Harness entry point (benchmarks/run.py contract): CSV rows only."""
    return _micro_rows() + _paged_rows(smoke, arch)[0]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep for CI (seconds, not minutes)")
    ap.add_argument("--arch", default="yi-6b-smoke")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    for row in _micro_rows():
        print(row, flush=True)
    rows, cells = _paged_rows(args.smoke, args.arch)
    for row in rows:
        print(row, flush=True)

    gated = [c for c in cells if c["gated"]]
    worst = min(c["speedup"] for c in gated)
    equal = all(c["logits_equal"] for c in cells)
    recompiles = sum(c["recompiles"] for c in cells)
    ok = True
    if worst < TARGET_SPEEDUP:
        print(f"FAIL: paged decode speedup {worst:.2f}x < "
              f"{TARGET_SPEEDUP}x target at long-context cells",
              file=sys.stderr)
        ok = False
    if not equal:
        print("FAIL: paged/ref logits diverged from the gather path",
              file=sys.stderr)
        ok = False
    if recompiles:
        print(f"FAIL: decode steps burned {recompiles} extra traces "
              f"(kernel choice is static per plan; steps must trace once)",
              file=sys.stderr)
        ok = False
    with open(RESULTS_JSON, "w") as f:
        json.dump({
            "bench": "kernels", "smoke": args.smoke, "arch": args.arch,
            "meta": scenario_meta(args.arch),
            "rows": rows, "ok": ok,
            "gates": {
                "paged_decode_speedup": {"value": worst,
                                         "target": TARGET_SPEEDUP},
                "logits_equal": {"value": bool(equal), "target": True},
                "recompiles": {"value": recompiles, "target": 0},
            },
            "cells": cells,
        }, f, indent=2)
        f.write("\n")
    print(f"# results -> {RESULTS_JSON}", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
