"""Softmax classifier — the paper's Section 2 worked example (affine →
softmax → cross-entropy trained with minibatch SGD)."""


def make_spec(num_features=784, num_classes=10):
    return [
        {"kind": "affine", "units": num_classes},
        {"kind": "softmax"},
    ], {"input_shape": (num_features,), "num_classes": num_classes}
