"""Plan cache + dynamic recompilation (the SystemML §2 mechanism on the
serving path): bucket rounding, LRU eviction order, hit/miss counters, and
estimate-breach-triggered recompilation that converges after one pass."""

import dataclasses

import jax.numpy as jnp
import pytest

from repro.config import InputShape, SINGLE_DEVICE_MESH, SINGLE_POD_MESH
from repro.configs import get_config
from repro.core.plan_cache import (BucketPolicy, CacheEntry, PlanCache,
                                   PlanKey, bucket_pow2, recompile_reasons)
from repro.core.planner import PlanCompiler, compile_plan
from repro.core.strategies import RuntimeStats
from repro.runtime.serve_loop import PlanServer, ServeRequest

CFG = get_config("yi-6b-smoke")


def _key(batch=2, seq=128, kind="decode"):
    shape = InputShape("t", seq, batch, kind)
    return PlanKey.for_request(CFG, SINGLE_DEVICE_MESH, "float32", shape)


def _entry(key):
    plan = compile_plan(CFG, key.bucket_shape(), SINGLE_DEVICE_MESH)
    return CacheEntry(key=key, plan=plan)


# ---------------------------------------------------------------------------
# bucket rounding
# ---------------------------------------------------------------------------


def test_bucket_pow2_rounds_up():
    assert bucket_pow2(1) == 1
    assert bucket_pow2(2) == 2
    assert bucket_pow2(3) == 4
    assert bucket_pow2(4) == 4
    assert bucket_pow2(5) == 8
    assert bucket_pow2(1000) == 1024


def test_bucket_pow2_minimum():
    assert bucket_pow2(1, minimum=16) == 16
    assert bucket_pow2(17, minimum=16) == 32
    assert bucket_pow2(0) == 1


def test_plan_key_buckets_request_shapes():
    k = PlanKey.for_request(CFG, SINGLE_DEVICE_MESH, "float32",
                            InputShape("r", 100, 3, "decode"),
                            BucketPolicy(min_batch=1, min_seq=16))
    assert (k.batch_bucket, k.seq_bucket) == (4, 128)
    bs = k.bucket_shape()
    assert (bs.global_batch, bs.seq_len, bs.kind) == (4, 128, "decode")
    # one key per shape family: any (3..4, 65..128) request maps identically
    assert _key(4, 65) == _key(3, 128)
    # different mesh/dtype/kind never collide
    assert k != PlanKey.for_request(CFG, SINGLE_POD_MESH, "float32",
                                    InputShape("r", 100, 3, "decode"))
    assert k != dataclasses.replace(k, dtype="bfloat16")


# ---------------------------------------------------------------------------
# LRU + counters
# ---------------------------------------------------------------------------


def test_lru_eviction_order():
    cache = PlanCache(capacity=2)
    ka, kb, kc = _key(1, 64), _key(2, 128), _key(4, 256)
    cache.put(ka, _entry(ka))
    cache.put(kb, _entry(kb))
    cache.get(ka)                      # A is now most-recently used
    cache.put(kc, _entry(kc))          # evicts B (least-recently used)
    assert kb not in cache and ka in cache and kc in cache
    assert cache.metrics.evictions == 1
    assert len(cache) == 2


def test_hit_miss_counters():
    cache = PlanCache(capacity=4)
    k = _key()
    assert cache.get(k) is None
    cache.put(k, _entry(k))
    assert cache.get(k) is not None
    assert cache.get(k) is not None
    m = cache.metrics
    assert (m.hits, m.misses) == (2, 1)
    assert m.hit_rate == pytest.approx(2 / 3)


def test_get_or_compile_compiles_once():
    cache = PlanCache(capacity=4)
    k = _key()
    calls = []

    def compile_fn():
        calls.append(1)
        return _entry(k)

    e1 = cache.get_or_compile(k, compile_fn)
    e2 = cache.get_or_compile(k, compile_fn)
    assert e1 is e2 and len(calls) == 1
    assert cache.metrics.compiles == 1


# ---------------------------------------------------------------------------
# dynamic recompilation
# ---------------------------------------------------------------------------


def test_memory_breach_triggers_exactly_one_recompile():
    cache = PlanCache(capacity=4)
    compiler = PlanCompiler()
    k = _key(2, 128)
    old = cache.put(k, _entry(k))
    # observed watermark 2x the compile-time estimate: breach at 25% margin
    stats = RuntimeStats(shape=k.bucket_shape(),
                         watermark_bytes=2.0 * old.plan.memory.total)

    new, reasons = cache.refresh(k, stats, compiler, margin=0.25)
    assert reasons and "watermark" in reasons[0]
    assert cache.metrics.recompiles == 1
    assert new is not old
    # the new plan is installed in the cache under the same bucket
    cache.metrics.hits = 0
    assert cache.get(k) is new
    # runtime-corrected statistics now cover the observation ...
    assert new.plan.memory.total >= stats.watermark_bytes
    # ... so the identical follow-up request does NOT recompile again
    again, reasons2 = cache.refresh(k, stats, compiler, margin=0.25)
    assert reasons2 == () and again is new
    assert cache.metrics.recompiles == 1


def test_no_recompile_within_margin():
    cache = PlanCache(capacity=4)
    k = _key(2, 128)
    e = cache.put(k, _entry(k))
    stats = RuntimeStats(shape=k.bucket_shape(),
                         watermark_bytes=1.1 * e.plan.memory.total)
    same, reasons = cache.refresh(k, stats, PlanCompiler(), margin=0.25)
    assert same is e and reasons == ()
    assert cache.metrics.recompiles == 0


def test_shape_outgrowing_bucket_recompiles_into_larger_bucket():
    cache = PlanCache(capacity=4)
    k = _key(2, 128)
    cache.put(k, _entry(k))
    grown = InputShape("grown", 300, 2, "decode")  # context outgrew 128
    new, reasons = cache.refresh(k, RuntimeStats(shape=grown), PlanCompiler())
    assert reasons and "exceeds compiled bucket" in reasons[0]
    assert new.key.seq_bucket == 512
    assert new.plan.shape.seq_len >= 512  # plan covers the whole new bucket
    cache.metrics.misses = 0
    assert cache.get(new.key) is new
    # the invalidated entry is gone; re-refreshing the old key is a no-op
    # rather than a repeated recompile
    assert k not in cache
    none, reasons2 = cache.refresh(k, RuntimeStats(shape=grown),
                                   PlanCompiler())
    assert none is None and reasons2 == ()
    assert cache.metrics.recompiles == 1


def test_rebucket_reuses_existing_target_entry():
    """Growing into a bucket that already holds a compiled plan reuses that
    entry (and its traced executable) instead of clobbering it."""
    cache = PlanCache(capacity=4)
    small = _key(2, 128)
    big = _key(2, 512)
    cache.put(small, _entry(small))
    target = cache.put(big, _entry(big))
    target.step_fn = object()  # stands in for the traced executable
    grown = InputShape("grown", 300, 2, "decode")
    got, reasons = cache.refresh(small, RuntimeStats(shape=grown),
                                 PlanCompiler())
    assert reasons and got is target and got.step_fn is target.step_fn
    assert small not in cache
    assert cache.metrics.recompiles == 0  # no planner walk happened


def test_recompile_converges_even_when_strategy_escalates():
    """If the scaled estimate pushes the walk to a more-sharded candidate
    with a smaller base estimate, the corrected statistics must still cover
    the observed watermark — else the same request breaches forever."""
    compiler = PlanCompiler()
    prior = compiler.compile(get_config("granite-8b"),
                             InputShape("t", 2048, 32, "decode"),
                             SINGLE_POD_MESH)
    watermark = 50.0 * prior.memory.total  # huge breach: forces escalation
    stats = RuntimeStats(shape=prior.shape, watermark_bytes=watermark)
    new = compiler.recompile(prior, stats)
    assert new.memory.total >= watermark
    assert recompile_reasons(new, stats) == ()


def test_recompile_reasons_predicate():
    plan = compile_plan(CFG, InputShape("t", 128, 2, "decode"),
                        SINGLE_DEVICE_MESH)
    ok = RuntimeStats(shape=plan.shape,
                      watermark_bytes=plan.memory.total)
    assert recompile_reasons(plan, ok) == ()
    breach = RuntimeStats(shape=plan.shape,
                          watermark_bytes=plan.memory.total * 3)
    assert len(recompile_reasons(plan, breach)) == 1


def test_recompile_scales_estimates_monotonically():
    """PlanCompiler.recompile inflates every candidate estimate by the
    observed correction factor (runtime stats replace compile-time stats)."""
    compiler = PlanCompiler()
    prior = compiler.compile(CFG, InputShape("t", 128, 2, "decode"),
                             SINGLE_DEVICE_MESH)
    stats = RuntimeStats(shape=prior.shape,
                         watermark_bytes=4.0 * prior.memory.total)
    new = compiler.recompile(prior, stats)
    assert new.memory.total == pytest.approx(4.0 * prior.memory.total, rel=0.3)
    assert any("dynamic recompilation" in n for n in new.config.notes)


# ---------------------------------------------------------------------------
# PlanServer end-to-end (tiny model, CPU)
# ---------------------------------------------------------------------------


def test_plan_server_mixed_stream_end_to_end():
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=8)
    r1 = srv.handle(ServeRequest(2, 100, new_tokens=2))
    assert r1["tokens"].shape == (2, 2)
    assert r1["bucket"] == (2, 128)
    # same bucket: a hit, no new compile
    compiles_before = srv.metrics.compiles
    r2 = srv.handle(ServeRequest(1, 90, new_tokens=2))
    assert r2["bucket"] == (1, 128)   # different batch bucket -> miss
    r3 = srv.handle(ServeRequest(2, 120, new_tokens=2))
    assert r3["bucket"] == (2, 128)
    assert srv.metrics.hits >= 1
    assert srv.metrics.compiles == compiles_before + 1  # only the (1,128) miss
    assert srv.summary()  # renders


def test_plan_server_cache_off_always_compiles():
    srv = PlanServer(CFG, dtype=jnp.float32, enable_cache=False)
    srv.handle(ServeRequest(1, 40, new_tokens=1))
    srv.handle(ServeRequest(1, 40, new_tokens=1))
    assert srv.metrics.compiles == 2
    assert srv.metrics.hits == 0
