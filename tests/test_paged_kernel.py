"""Paged-attention decode kernel (PR 8): 3-way logits equivalence of the
fused kernel vs the jnp gather path vs the ref oracle — kernel-level (flat
slot stacks, shuffled tables, sentinel pages, rotating writes) and
model-level per family (page-boundary prompts, prompts longer than the
window, rows at mixed decode depths) — plus planner-side kernel selection:
deterministic, cost-backed, recorded in ``ExecutionPlan.explain()``, forced
by ``EngineConfig.decode_kernel``, and re-run with observed page counts on
dynamic recompilation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SINGLE_DEVICE_MESH, InputShape
from repro.configs import get_config
from repro.core.planner import LONG_CONTEXT_THRESHOLD, PlanCompiler
from repro.core.strategies import RuntimeStats
from repro.kernels import ops
from repro.kernels.paged_attention import paged_attention_xla, paged_decode_attention
from repro.kernels.ref import paged_decode_ref
from repro.models import attention as ATT
from repro.models.model import build_model
from repro.runtime.engine_config import EngineConfig
from repro.runtime.kv_cache import KVCachePool

KEY = jax.random.PRNGKey(0)
CFG = get_config("yi-6b-smoke")


# ---------------------------------------------------------------------------
# kernel-level: pallas (interpret) == xla form == oracle
# ---------------------------------------------------------------------------


def _flat_case(b=3, hq=4, hkv=2, d=32, page=4, sc=16, seed=0, sentinel=True):
    rng = np.random.default_rng(seed)
    n_pages = -(-sc // page)
    n_phys = b * n_pages
    q = jnp.asarray(rng.normal(size=(b, 1, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(n_phys * page, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n_phys * page, hkv, d)), jnp.float32)
    tables = rng.permutation(n_phys).reshape(b, n_pages).astype(np.int32)
    if sentinel:
        tables[-1, -1] = n_phys  # unallocated page on the last row
    return q, k, v, jnp.asarray(tables)


@pytest.mark.parametrize("pos", [[15, 5, 9], [0, 0, 0], [11, 11, 11]])
def test_paged_kernel_three_way_equivalence(pos):
    """Mixed decode depths, shuffled tables, one sentinel page: the Pallas
    kernel (interpret), the XLA form, and the literal-mask oracle agree."""
    q, k, v, tables = _flat_case()
    posv = jnp.asarray(pos, jnp.int32)
    o_ref = paged_decode_ref(q, k, v, tables, posv, page=4, sc=16)
    o_xla = paged_attention_xla(q, k, v, tables, posv, page=4, sc=16)
    o_pl = paged_decode_attention(q, k, v, tables, posv, page=4, sc=16,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(o_xla), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_rotating_mask_reduction():
    """Rows decoded past a rotating window: the oracle applies the literal
    ``pos - mod(pos - i, sc)`` validity rule, the kernel the reduced
    committed-slot mask — proving the reduction they must share. Cache
    contents are written through the real rotating paged write path."""
    b, hkv, d, page, sc = 2, 2, 32, 4, 8  # sc == window: rotating modulus
    q, k0, v0, tables = _flat_case(b=b, hq=4, hkv=hkv, d=d, page=page, sc=sc,
                                   sentinel=False)
    kc, vc = k0, v0
    rng = np.random.default_rng(3)
    for p in range(13):  # decode depth wraps the window
        posv = jnp.full((b,), p, jnp.int32)
        kn = jnp.asarray(rng.normal(size=(b, 1, hkv, d)), jnp.float32)
        vn = jnp.asarray(rng.normal(size=(b, 1, hkv, d)), jnp.float32)
        kc, vc = ATT.paged_cache_write(kc, vc, kn, vn, posv, tables, page, sc,
                                       window=sc)
    posv = jnp.full((b,), 12, jnp.int32)
    o_ref = paged_decode_ref(q, kc, vc, tables, posv, page=page, sc=sc,
                             window=sc)
    o_pl = paged_decode_attention(q, kc, vc, tables, posv, page=page, sc=sc,
                                  interpret=True)
    o_xla = paged_attention_xla(q, kc, vc, tables, posv, page=page, sc=sc)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(o_xla), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_gather_kv_masks_uncommitted_slots():
    """Satellite fix: with ``pos``, the gather pins uncommitted slots to
    slot 0 and zeroes their values instead of wandering through clamped
    sentinel garbage — and committed slots are untouched."""
    _, k, v, tables = _flat_case(sentinel=True)
    posv = jnp.asarray([15, 5, 9], jnp.int32)
    ke, ve = ATT.paged_gather_kv(k, v, tables, 4, 16, pos=posv)
    ke_legacy, _ = ATT.paged_gather_kv(k, v, tables, 4, 16)
    for r, p in enumerate([15, 5, 9]):
        committed = min(p + 1, 16)
        np.testing.assert_array_equal(np.asarray(ke[r, :committed]),
                                      np.asarray(ke_legacy[r, :committed]))
        assert np.all(np.asarray(ke[r, committed:]) == 0.0)
        assert np.all(np.asarray(ve[r, committed:]) == 0.0)


# ---------------------------------------------------------------------------
# model-level: per-family decode_kernel equivalence through real arenas
# ---------------------------------------------------------------------------


def _kernel_equiv(cfg, lengths, seq, page, steps=3):
    """Decode the same handoff under all three physical operators and
    require matching logits at every step (mixed depths come free from the
    per-row prompt lengths)."""
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(KEY)
    b = len(lengths)
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, max(lengths)), 0,
                              cfg.vocab_size)
    lengths_a = jnp.asarray(lengths, jnp.int32)
    logits, dense = model.prefill(params, toks, lengths=lengths_a,
                                  cache_len=seq)
    pool = KVCachePool(model, page_size=page)
    arena = pool.acquire(b, seq)
    rows = pool.alloc_rows(arena, b)
    for r, ln in zip(rows, lengths):
        pool.admit_row(arena, r, prompt=ln, span=ln + steps + 1)
    pool.write_rows(arena, rows, dense)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    pos = lengths_a
    caches = {k: arena.cache for k in ("paged", "gather", "ref")}
    for step in range(steps):
        for r, p in zip(rows, np.asarray(pos)):
            pool.ensure_decode_slots(arena, [r], int(p))
        out = {}
        for kern in ("gather", "paged", "ref"):
            out[kern], caches[kern] = model.decode_step(
                params, caches[kern], tok, pos, tables=arena.tables,
                page=page, seq_len=seq, decode_kernel=kern)
        for kern in ("paged", "ref"):
            np.testing.assert_allclose(
                np.asarray(out[kern]), np.asarray(out["gather"]),
                rtol=1e-5, atol=1e-5, err_msg=f"{kern} step {step}")
        tok = jnp.argmax(out["gather"][:, -1:], axis=-1).astype(jnp.int32)
        pos = pos + 1


def test_kernel_equiv_attention_family_page_boundary():
    # prompt of exactly page size + mixed depths across rows
    _kernel_equiv(CFG, [16, 32, 7], seq=64, page=16)


def test_kernel_equiv_hybrid_family_prompt_longer_than_window():
    cfg = get_config("recurrentgemma-2b-smoke").replace(block_pattern="ra")
    # window_size=32: prompts 45/38 land pre-rotated across pages
    _kernel_equiv(cfg, [45, 38], seq=64, page=16)


def test_kernel_equiv_hybrid_rotating_wrap():
    cfg = get_config("recurrentgemma-2b-smoke").replace(
        block_pattern="ra", window_size=8)
    _kernel_equiv(cfg, [5, 3], seq=32, page=4, steps=10)


def test_paged_kernel_forced_pallas_through_model():
    """ops.BACKEND='pallas' routes the paged operator through the Pallas
    kernel in interpret mode — full model decode still matches gather."""
    prev = ops.BACKEND
    ops.BACKEND = "pallas"
    try:
        _kernel_equiv(CFG, [12, 9], seq=32, page=8, steps=2)
    finally:
        ops.BACKEND = prev


# ---------------------------------------------------------------------------
# planner: selection is deterministic, recorded, forcible, flippable
# ---------------------------------------------------------------------------


def _decode_shape(batch, seq):
    return InputShape(name="d", seq_len=seq, global_batch=batch, kind="decode")


def test_planner_selects_paged_and_records_choice():
    pc = PlanCompiler(cache_pool_arenas=4, cache_page_size=64)
    plans = [pc.compile(CFG, _decode_shape(4, 128), SINGLE_DEVICE_MESH,
                        dtype="float32") for _ in range(2)]
    assert plans[0].config.decode_kernel == plans[1].config.decode_kernel
    assert plans[0].config.decode_kernel == "paged"
    assert "decode kernel:       paged" in plans[0].explain()


def test_planner_selects_paged_on_long_context_bucket():
    pc = PlanCompiler(cache_pool_arenas=4, cache_page_size=64)
    plan = pc.compile(get_config("yi-6b"),
                      _decode_shape(8, LONG_CONTEXT_THRESHOLD + 1),
                      SINGLE_DEVICE_MESH)
    assert plan.config.decode_kernel == "paged"


def test_planner_forced_kernel_and_attention_free_family():
    forced = PlanCompiler(cache_pool_arenas=4, cache_page_size=64,
                          decode_kernel="gather")
    plan = forced.compile(CFG, _decode_shape(4, 128), SINGLE_DEVICE_MESH)
    assert plan.config.decode_kernel == "gather"
    # attention-free family: no decode-attention operator, even when forced
    plan = forced.compile(get_config("mamba2-1.3b-smoke"),
                          _decode_shape(4, 128), SINGLE_DEVICE_MESH)
    assert plan.config.decode_kernel == "none"
    with pytest.raises(ValueError):
        PlanCompiler(decode_kernel="fused")


def test_planner_unpaged_compiler_keeps_gather():
    plan = PlanCompiler().compile(CFG, _decode_shape(4, 128),
                                  SINGLE_DEVICE_MESH)
    assert plan.config.decode_kernel == "gather"  # dense (non-paged) serving


def test_recompile_reruns_kernel_selection_with_observed_pages():
    pc = PlanCompiler(cache_pool_arenas=4, cache_page_size=64)
    prior = pc.compile(CFG, _decode_shape(4, 128), SINGLE_DEVICE_MESH,
                       dtype="float32")
    stats = RuntimeStats(shape=_decode_shape(4, 256),
                         committed_pages_per_row=1.0)
    plan = pc.recompile(prior, stats)
    # observed commitment only cheapens the fused kernel: choice holds and
    # the recompiled plan still records it
    assert plan.config.decode_kernel == "paged"
    assert "decode kernel:       paged" in plan.explain()


def test_engine_config_decode_kernel_knob():
    assert EngineConfig().decode_kernel == "auto"
    assert EngineConfig(decode_kernel="ref").decode_kernel == "ref"
    with pytest.raises(ValueError):
        EngineConfig(decode_kernel="flash")
