"""repro.kernels — Pallas TPU kernels for the paper's compute hot-spots
(matmul, im2col conv, attention, paged decode, SSD scan) + the VMEM-fit
dispatch layer (DESIGN.md C7). ``ref.py`` holds the pure-jnp oracles."""

from repro.kernels import ops, ref
from repro.kernels.matmul import matmul
from repro.kernels.conv2d_im2col import conv2d_im2col
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention_xla, paged_decode_attention
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["ops", "ref", "matmul", "conv2d_im2col", "flash_attention",
           "paged_attention_xla", "paged_decode_attention", "ssd_scan"]
