"""The cost-based plan compiler — the paper's core contribution, on TPU.

SystemML: "for the given DML script, SystemML's cost-based compiler
automatically generates hybrid runtime execution plans ... depending on data
and cluster characteristics such as data size, data sparsity, cluster size
and memory configurations."

:class:`PlanCompiler` does exactly that for a JAX mesh. Given
(model config x input shape x mesh x hardware budget) it walks the plan
lattice (DESIGN.md §4) from the cheapest strategy to the most distributed
one and returns the first plan whose **worst-case memory estimate** fits the
per-chip HBM budget, scored by the analytic cost model. The same escalation
SystemML performs between "driver JVM single-node plan" and "distributed
RDD plan" happens here between LOCAL / DATA_PARALLEL / +TP / FSDP /
opt-state-compression / gradient-accumulation.
"""

from __future__ import annotations

from typing import Iterator

from repro.config import (
    TPU_V5E,
    HardwareSpec,
    InputShape,
    MeshConfig,
    ModelConfig,
    TrainConfig,
)
from repro.core.cost import analytic_cost, decode_kernel_seconds
from repro.core.memory import ACT_BYTES, cache_page_count, estimate_memory
from repro.core.strategies import ExecutionPlan, PlanConfig, RuntimeStats, Strategy

LONG_CONTEXT_THRESHOLD = 262_144  # beyond this, full attention must window


class PlanCompiler:
    def __init__(self, hw: HardwareSpec = TPU_V5E, headroom: float = 0.9,
                 cache_pool_arenas: int = 1, cache_page_size: int = 0,
                 decode_kernel: str = "auto", donate_cache: bool = True):
        self.hw = hw
        self.headroom = headroom
        # decode statistics are sized for a KV-cache pool provisioned for
        # this many concurrent bucket arenas (repro.runtime.kv_cache);
        # 1 keeps the single-blob seed behaviour for dryruns/tests.
        # cache_page_size > 0 sizes the attention K/V term at block
        # granularity (pages the paged pool can physically commit) and is
        # what the pool's page-exact live bytes are compared against.
        self.cache_pool_arenas = cache_pool_arenas
        self.cache_page_size = cache_page_size
        # "auto": pick the physical decode-attention operator per bucket
        # from the analytic cost terms; anything else forces that operator
        # on every decode plan (the --decode-kernel escape hatch).
        if decode_kernel not in ("auto", "paged", "gather", "ref"):
            raise ValueError(f"unknown decode_kernel {decode_kernel!r}")
        self.decode_kernel = decode_kernel
        # decode steps donate their cache argument (in-place KV update);
        # False is the --no-donate A/B escape hatch, and the statistics
        # then charge the transient second arena copy honestly
        self.donate_cache = bool(donate_cache)

    def selection_trace(
        self, model: ModelConfig, shape: InputShape,
        committed_frac: float = 1.0,
    ) -> dict:
        """Every input and intermediate of decode-kernel selection, as a
        record: the chosen kernel plus *why* — forced knob, attention-free
        short-circuit, the VMEM block-fit test, and both candidate analytic
        seconds when the cost comparison actually ran. This is the
        introspection surface ``repro.analysis.cost_audit`` sweeps to
        certify selection invariants (crossover monotonicity in context
        length and committed pages, forced-kernel consistency,
        donation-independence) without re-deriving the compiler's logic."""
        page = self.cache_page_size
        rec = {
            "kernel": "gather",
            "forced": self.decode_kernel,
            "attention_free": model.layer_pattern().count("a") == 0,
            "page": page,
            "committed_frac": committed_frac,
            "vmem_fit": None,       # None = fit test not reached
            "paged_s": None,
            "gather_s": None,
            "reason": "",
        }
        if rec["attention_free"]:
            rec.update(kernel="none",
                       reason="attention-free family: no decode-attention op")
            return rec
        if self.decode_kernel != "auto":
            rec.update(kernel=self.decode_kernel, reason="forced by compiler")
            return rec
        if shape.kind != "decode" or page <= 0:
            rec.update(reason="dense (non-paged) serving path")
            return rec
        # device-memory fit of the kernel's per-block set: one K and one V
        # physical page + the (g, D) query group + f32 accumulator scratch
        d = model.head_dim
        g = model.q_per_kv
        blk = 2 * page * d * ACT_BYTES + g * d * ACT_BYTES + g * (d + 2) * 4
        rec["vmem_fit"] = blk <= self.hw.vmem_bytes * 0.8
        if not rec["vmem_fit"]:
            rec.update(reason=f"page block {blk}B exceeds VMEM budget")
            return rec
        paged_s = decode_kernel_seconds(model, shape, self.hw, "paged", page,
                                        committed_frac)
        gather_s = decode_kernel_seconds(model, shape, self.hw, "gather", page,
                                         committed_frac)
        rec.update(paged_s=paged_s, gather_s=gather_s,
                   kernel="paged" if paged_s < gather_s else "gather",
                   reason="analytic cost comparison")
        return rec

    def _select_decode_kernel(
        self, model: ModelConfig, shape: InputShape,
        committed_frac: float = 1.0,
    ) -> str:
        """SystemML-style operator selection for the decode hot path.

        Data characteristics decide: page count and window (via the
        effective cached sequence), batch, and head dims enter through the
        analytic cost terms in :mod:`repro.core.cost`; the VMEM fit of one
        physical page plays SystemML's device-memory-fit test. Worst-case
        commitment (``committed_frac=1``) at compile time; dynamic
        recompilation re-enters with the observed fraction.
        """
        return self.selection_trace(model, shape, committed_frac)["kernel"]

    def _cache_kwargs(self, model: ModelConfig, shape: InputShape) -> dict:
        kw = {"cache_pool_arenas": self.cache_pool_arenas}
        if shape.kind == "decode":
            kw["donate_cache"] = self.donate_cache
        if self.cache_page_size and shape.kind == "decode":
            kw["cache_page_size"] = self.cache_page_size
            kw["cache_pages"] = self.cache_pool_arenas * cache_page_count(
                model, shape.seq_len, shape.global_batch,
                self.cache_page_size)
        return kw

    # ------------------------------------------------------------------
    def compile(
        self,
        model: ModelConfig,
        shape: InputShape,
        mesh: MeshConfig,
        train: TrainConfig = TrainConfig(),
        mem_scale: float = 1.0,
        dtype: str = "bfloat16",
    ) -> ExecutionPlan:
        """Walk the plan lattice and return the first fitting plan.

        ``mem_scale`` is the dynamic-recompilation hook: when a plan's
        observed memory watermark exceeded its compile-time estimate, the
        recompile pass re-enters here with the observed/estimated correction
        factor, so every candidate is judged (and the chosen plan is
        annotated) with runtime-corrected statistics. ``dtype`` is the actual
        compute dtype — compile-time statistics are sized for it.
        """
        chosen = None
        candidates = list(self._candidates(model, shape, mesh, train))
        if train.force_strategy:
            candidates = [
                c for c in candidates if c.strategy.value == train.force_strategy
            ] or candidates
        for cand in candidates:
            mem = estimate_memory(model, shape, mesh, cand, train, self.hw, dtype,
                                  **self._cache_kwargs(model, shape))
            if mem_scale != 1.0:
                mem = mem.scaled(mem_scale)
            if mem.fits(self.headroom):
                chosen, chosen_mem = cand, mem
                break
        else:
            # nothing fits: emit the most distributed plan with a warning,
            # exactly like SystemML emitting a distributed plan that spills.
            chosen = candidates[-1].replace(
                notes=candidates[-1].notes
                + ("WARNING: worst-case estimate exceeds HBM budget",)
            )
            chosen_mem = estimate_memory(model, shape, mesh, chosen, train, self.hw,
                                         dtype,
                                         **self._cache_kwargs(model, shape))
            if mem_scale != 1.0:
                chosen_mem = chosen_mem.scaled(mem_scale)
        if shape.kind == "decode":
            chosen = chosen.replace(
                decode_kernel=self._select_decode_kernel(model, shape),
                donate_cache=self.donate_cache)
        cost = analytic_cost(model, shape, mesh, chosen, self.hw,
                             page=self.cache_page_size, dtype=dtype)
        return ExecutionPlan(
            model=model, shape=shape, mesh=mesh, config=chosen,
            memory=chosen_mem, cost=cost, dtype=dtype,
        )

    # ------------------------------------------------------------------
    def recompile(
        self,
        prior: ExecutionPlan,
        stats: RuntimeStats,
        train: TrainConfig = TrainConfig(),
    ) -> ExecutionPlan:
        """Dynamic recompilation (SystemML §2): re-enter the compiler with
        *observed* runtime characteristics replacing the compile-time
        worst-case assumptions of ``prior``.

        Two divergences are corrected: (1) the actual request shape grew
        beyond the compiled shape — the plan is recompiled for the larger
        shape; (2) the measured memory watermark exceeded the compile-time
        estimate — every candidate estimate is inflated by the observed
        correction factor so the lattice walk escalates honestly.
        """
        shape = prior.shape
        if (stats.shape.seq_len > shape.seq_len
                or stats.shape.global_batch > shape.global_batch):
            shape = InputShape(
                name=f"{shape.kind}_recompiled",
                seq_len=max(shape.seq_len, stats.shape.seq_len),
                global_batch=max(shape.global_batch, stats.shape.global_batch),
                kind=shape.kind,
            )
        scale = 1.0
        if (stats.watermark_bytes
                and prior.memory is not None and prior.memory.total > 0):
            scale = max(1.0, stats.watermark_bytes / prior.memory.total)
        plan = self.compile(prior.model, shape, prior.mesh, train,
                            mem_scale=scale, dtype=prior.dtype)
        # Corrected statistics must cover the observation even when the
        # lattice walk escalated to a candidate with a smaller base
        # estimate — otherwise the same watermark breaches again on the
        # next request and recompilation never converges. Worst-case
        # estimates never under-estimate (core.memory contract).
        if (stats.watermark_bytes and plan.memory is not None
                and 0 < plan.memory.total < stats.watermark_bytes):
            plan.memory = plan.memory.scaled(
                stats.watermark_bytes / plan.memory.total)
        # KV-cache pool breach: the pool outgrew the compile-time cache
        # statistic — correct it to cover the observation so an identical
        # pool occupancy does not re-trigger recompilation (same
        # converge-after-one contract as the watermark correction above).
        if stats.cache_pool_bytes and plan.memory is not None:
            kv_est = plan.memory.per_device.get("kv_cache", 0.0)
            if 0 < kv_est < stats.cache_pool_bytes:
                plan.memory.per_device["kv_cache"] = float(stats.cache_pool_bytes)
        # Decode-kernel re-selection with *observed* page commitment: the
        # compile-time choice assumed every row at bucket depth; if the
        # observed committed pages per row diverge, the cost comparison is
        # re-run with the real fraction and can flip the physical operator
        # (the fused kernel skips uncommitted pages, the gather cannot).
        if (shape.kind == "decode" and stats.committed_pages_per_row
                and self.cache_page_size):
            worst = cache_page_count(
                prior.model, shape.seq_len, shape.global_batch,
                self.cache_page_size) / max(1, shape.global_batch)
            frac = min(1.0, stats.committed_pages_per_row / max(1.0, worst))
            kernel = self._select_decode_kernel(prior.model, shape, frac)
            if kernel != plan.config.decode_kernel:
                plan.config = plan.config.replace(
                    decode_kernel=kernel,
                    notes=plan.config.notes + (
                        f"decode kernel flipped to {kernel}: observed "
                        f"{stats.committed_pages_per_row:.1f}/{worst:.0f} "
                        "pages/row",
                    ),
                )
                plan.cost = analytic_cost(prior.model, shape, prior.mesh,
                                          plan.config, self.hw,
                                          page=self.cache_page_size,
                                          dtype=prior.dtype)
        plan.config = plan.config.replace(
            notes=plan.config.notes
            + (f"dynamic recompilation: runtime stats correction x{scale:.2f}",)
        )
        return plan

    # ------------------------------------------------------------------
    def _attention_variant(self, model: ModelConfig, shape: InputShape) -> str:
        if model.family == "ssm":
            return "none"
        if model.window_size:
            return "window"
        if shape.seq_len > LONG_CONTEXT_THRESHOLD:
            return "window"  # sliding-window serving variant (DESIGN §5)
        return "full"

    def _candidates(
        self,
        model: ModelConfig,
        shape: InputShape,
        mesh: MeshConfig,
        train: TrainConfig,
    ) -> Iterator[PlanConfig]:
        variant = self._attention_variant(model, shape)
        data_axes = mesh.data_axes
        batch_axes = data_axes if shape.global_batch % max(1, _size(mesh, data_axes)) == 0 else ()
        is_moe = model.num_experts > 0

        if mesh.num_devices == 1:
            # single-node plan — SystemML's driver-JVM case
            yield PlanConfig(
                strategy=Strategy.LOCAL,
                batch_axes=(),
                attention_variant=variant,
                remat=train.remat,
                microbatches=1,
                opt_state_dtype=train.opt_state_dtype or "float32",
            )
            return

        if shape.kind == "train":
            yield from self._train_candidates(
                model, shape, mesh, train, variant, batch_axes, is_moe
            )
        else:
            yield from self._serve_candidates(
                model, shape, mesh, variant, batch_axes, is_moe
            )

    def _train_candidates(self, model, shape, mesh, train, variant, batch_axes, is_moe):
        base = PlanConfig(
            strategy=Strategy.DATA_PARALLEL,
            batch_axes=batch_axes,
            attention_variant=variant,
            remat=train.remat,
            opt_state_dtype=train.opt_state_dtype or "float32",
            notes=("paper-faithful data-parallel plan",),
        )
        yield base
        tp = base.replace(
            strategy=Strategy.DP_TP,
            tensor_parallel=True,
            expert_parallel=is_moe,
            notes=(),
        )
        yield tp
        fsdp = tp.replace(strategy=Strategy.FSDP_TP, params_over_data=True)
        yield fsdp
        if (train.opt_state_dtype or "float32") == "float32":
            # plan-chosen optimizer-state compression (DESIGN §4)
            fsdp_bf16 = fsdp.replace(
                opt_state_dtype="bfloat16",
                notes=("opt-state compressed to bf16 by planner",),
            )
            yield fsdp_bf16
        else:
            fsdp_bf16 = fsdp
        # Megatron-style sequence-parallel residual checkpoints (beyond-paper)
        if shape.seq_len % mesh.model_parallelism == 0:
            fsdp_bf16 = fsdp_bf16.replace(
                seq_shard_checkpoints=True,
                notes=fsdp_bf16.notes + ("seq-parallel remat checkpoints",),
            )
            yield fsdp_bf16
        # escalating gradient accumulation to shrink activations
        b_dev = max(1, shape.global_batch // max(1, _size(mesh, batch_axes)))
        micro = 2
        while micro <= b_dev:
            yield fsdp_bf16.replace(
                microbatches=micro,
                notes=fsdp_bf16.notes + (f"grad-accum x{micro}",),
            )
            micro *= 2

    def _serve_candidates(self, model, shape, mesh, variant, batch_axes, is_moe):
        mp = mesh.model_parallelism
        kv = model.num_kv_heads
        heads_ok = kv >= mp and kv % mp == 0
        # long-context: also spread cached sequence over idle axes
        seq_axes_all = tuple(
            a for a in mesh.axis_names if not batch_axes or a not in batch_axes
        )
        base = PlanConfig(
            strategy=Strategy.DATA_PARALLEL,
            batch_axes=batch_axes,
            cache_batch_axes=batch_axes,
            attention_variant=variant,
            remat=False,
            microbatches=1,
            notes=("paper-faithful data-parallel plan (weights replicated)",),
        )
        yield base
        # + tensor parallel on weights; cache sharded on heads if divisible,
        # else on sequence over the model axis
        tp = base.replace(
            strategy=Strategy.DP_TP,
            tensor_parallel=True,
            expert_parallel=is_moe,
            cache_heads_over_model=heads_ok,
            cache_seq_axes=() if heads_ok else ("model",),
            notes=(),
        )
        if model.family == "ssm":
            tp = tp.replace(cache_heads_over_model=True, cache_seq_axes=())
        yield tp
        # prefill context parallelism: seq sharded over "model", K/V
        # all-gathered per layer (beyond-paper escalation)
        cp = None
        if shape.kind == "prefill" and shape.seq_len % mp == 0:
            cp = tp.replace(
                seq_axes=("model",),
                notes=("context-parallel prefill: seq over model axis",),
            )
            yield cp
        # long-context escalation: sequence over every non-batch axis
        if shape.seq_len > LONG_CONTEXT_THRESHOLD or shape.global_batch == 1:
            yield tp.replace(
                cache_heads_over_model=False,
                cache_seq_axes=seq_axes_all,
                notes=("cache sequence spread over all idle mesh axes",),
            )
        # last resorts: weights over data too (per-layer all-gather at serve)
        yield tp.replace(
            strategy=Strategy.FSDP_TP,
            params_over_data=True,
            notes=("serve-time FSDP: params all-gathered per layer",),
        )
        if cp is not None:
            yield cp.replace(
                strategy=Strategy.FSDP_TP,
                params_over_data=True,
                notes=cp.notes + ("serve-time FSDP: params all-gathered per layer",),
            )


def _size(mesh: MeshConfig, axes) -> int:
    n = 1
    for nm, sz in zip(mesh.axis_names, mesh.shape):
        if nm in axes:
            n *= sz
    return n


def compile_plan(model, shape, mesh, train=TrainConfig(), hw=TPU_V5E,
                 dtype="bfloat16") -> ExecutionPlan:
    return PlanCompiler(hw).compile(model, shape, mesh, train, dtype=dtype)
