"""Batched serving example: prefill a batch of prompts, then greedy-decode
continuation tokens with the plan-chosen KV-cache layout. Uses the
attention-free mamba2 family by default (constant-memory state).

    PYTHONPATH=src python examples/serve_batched.py --arch yi-6b-smoke
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import InputShape, MeshConfig
from repro.configs import get_config
from repro.core.planner import compile_plan
from repro.models.model import build_model
from repro.runtime.kv_cache import KVCachePool
from repro.runtime.serve_loop import greedy_decode, make_decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))

    mesh_cfg = MeshConfig(shape=(len(jax.devices()),), axis_names=("data",))
    context = args.prompt_len + args.gen
    shape = InputShape("serve", context, args.batch, "decode")
    plan = compile_plan(cfg, shape, mesh_cfg)
    print(plan.explain())

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    # one full-sequence prefill pass scores the prompt AND populates the
    # decode cache (prefill→decode handoff): decode continues at the
    # prompt's position instead of restarting from zeros
    step = jax.jit(make_decode_step(model, plan.config, mesh_cfg))
    t0 = time.perf_counter()
    if model.supports_handoff:
        last_logits, cache = model.prefill(params, prompts, cache_len=context)
    else:
        # enc-dec / modality frontends: no handoff — step the decode path
        # over the prompt (correct for all families incl. recurrent state).
        # The cache comes from the pool (the one blessed construction
        # path), same as the serving engine's arenas.
        pool = KVCachePool(model)
        cache = pool.acquire(args.batch, context, force=True).cache
        for t in range(args.prompt_len):
            logits, cache = step(params, cache, prompts[:, t:t + 1],
                                 jnp.int32(t))
        last_logits = logits[:, -1]
    jax.block_until_ready(last_logits)
    prefill_s = time.perf_counter() - t0

    first = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
    t0 = time.perf_counter()
    toks, cache = greedy_decode(model, params, cache, first,
                                args.prompt_len, args.gen, decode_step=step)
    jax.block_until_ready(toks)
    decode_s = time.perf_counter() - t0

    print(f"prefill: {args.prompt_len * args.batch / prefill_s:.1f} tok/s   "
          f"decode: {args.gen * args.batch / decode_s:.1f} tok/s")
    print("generated:", toks[0].tolist()[:16], "...")
    print("OK")


if __name__ == "__main__":
    main()
