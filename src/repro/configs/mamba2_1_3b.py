"""mamba2-1.3b [ssm] — 48L, d_model=2048, attention-free SSD
(state-space duality), ssm_state=128, vocab=50280. [arXiv:2405.21060]
"""

from repro.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_conv_width=4,
        tie_embeddings=True,
        citation="arXiv:2405.21060",
    )
