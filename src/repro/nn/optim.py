"""The paper's six optimizers (§2: "6 optimizers, namely Adagrad, Adam,
RMSprop, SGD, SGD with momentum, and SGD with Nesterov momentum").

Each optimizer follows the SystemML ``nn/optim/*.dml`` interface:

    init(param)                          -> state
    update(param, grad, state, hypers)   -> new_param, new_state

and operates leaf-wise; :func:`tree_update` maps over pytrees. A state leaf
may live in a reduced dtype when the plan compiler chose opt-state
compression (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def _zeros_like(p, dtype=None):
    return jnp.zeros_like(p, dtype=dtype or p.dtype)


class sgd:
    slots = 0

    @staticmethod
    def init(p, dtype=None):
        return ()

    @staticmethod
    def update(p, g, state, lr=0.01, **_):
        return p - lr * g, ()


class sgd_momentum:
    slots = 1

    @staticmethod
    def init(p, dtype=None):
        return (_zeros_like(p, dtype),)

    @staticmethod
    def update(p, g, state, lr=0.01, mu=0.9, **_):
        (v,) = state
        v = (mu * v - lr * g).astype(v.dtype)
        return p + v, (v,)


class sgd_nesterov:
    slots = 1

    @staticmethod
    def init(p, dtype=None):
        return (_zeros_like(p, dtype),)

    @staticmethod
    def update(p, g, state, lr=0.01, mu=0.9, **_):
        (v,) = state
        v_prev = v
        v = (mu * v - lr * g).astype(v.dtype)
        return p - mu * v_prev + (1 + mu) * v, (v,)


class adagrad:
    slots = 1

    @staticmethod
    def init(p, dtype=None):
        return (_zeros_like(p, dtype),)

    @staticmethod
    def update(p, g, state, lr=0.01, eps=1e-6, **_):
        (c,) = state
        c = (c + g * g).astype(c.dtype)
        return p - lr * g / (jnp.sqrt(c.astype(g.dtype)) + eps), (c,)


class rmsprop:
    slots = 1

    @staticmethod
    def init(p, dtype=None):
        return (_zeros_like(p, dtype),)

    @staticmethod
    def update(p, g, state, lr=0.01, decay=0.99, eps=1e-8, **_):
        (c,) = state
        c = (decay * c + (1 - decay) * g * g).astype(c.dtype)
        return p - lr * g / (jnp.sqrt(c.astype(g.dtype)) + eps), (c,)


class adam:
    slots = 2

    @staticmethod
    def init(p, dtype=None):
        return (_zeros_like(p, dtype), _zeros_like(p, dtype))

    @staticmethod
    def update(p, g, state, lr=0.001, beta1=0.9, beta2=0.999, eps=1e-8, t=1, **_):
        m, v = state
        m = (beta1 * m + (1 - beta1) * g).astype(m.dtype)
        v = (beta2 * v + (1 - beta2) * g * g).astype(v.dtype)
        mhat = m.astype(g.dtype) / (1 - beta1**t)
        vhat = v.astype(g.dtype) / (1 - beta2**t)
        return p - lr * mhat / (jnp.sqrt(vhat) + eps), (m, v)


OPTIMIZERS: Dict[str, Any] = {
    "sgd": sgd,
    "sgd_momentum": sgd_momentum,
    "sgd_nesterov": sgd_nesterov,
    "adagrad": adagrad,
    "rmsprop": rmsprop,
    "adam": adam,
}


OPTIMIZER_SLOTS: Dict[str, int] = {k: v.slots for k, v in OPTIMIZERS.items()}


def get_optimizer(name: str):
    if name not in OPTIMIZERS:
        raise KeyError(f"unknown optimizer {name!r}; known: {list(OPTIMIZERS)}")
    return OPTIMIZERS[name]


# ---------------------------------------------------------------------------
# pytree-level helpers (used by runtime.train_loop for the big models)
# ---------------------------------------------------------------------------


def tree_init(name: str, params, dtype=None):
    opt = get_optimizer(name)
    return jax.tree.map(lambda p: opt.init(p, dtype=dtype), params,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))


def tree_update(name: str, params, grads, state, **hypers):
    opt = get_optimizer(name)
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state)
    new_p, new_s = [], []
    for p, g, s in zip(flat_p, flat_g, flat_s):
        np_, ns = opt.update(p, g, s, **hypers)
        new_p.append(np_.astype(p.dtype))
        new_s.append(ns)
    return treedef.unflatten(new_p), treedef.unflatten(new_s)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), tree), n
