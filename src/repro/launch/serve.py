"""Serving launcher: three configurations of the one ServingEngine.

Every mode is the same engine (``repro.runtime.engine.ServingEngine``) —
the single request-lifecycle API — differing only in how requests are fed
and consumed:

Single-shot mode (streams the one request's tokens as they decode):

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b-smoke \
        --batch 4 --context 128 --tokens 32

Mixed-shape request-stream mode — the sequential front door
(``PlanServer.handle``, itself a submit-and-drain engine adapter):
requests of varying (batch, context) round up to power-of-two buckets,
steady-state requests hit cached compiled plans, and estimate breaches
trigger recompilation:

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b-smoke \
        --stream --requests 24 --tokens 4
    # explicit shape mix, cache disabled for A/B:
    PYTHONPATH=src python -m repro.launch.serve --stream \
        --shapes 2x100,1x40,4x60 --no-cache

Continuous-batching mode — the engine driven with simulated arrivals:
pending requests coalesce into shared shape buckets, prefill populates each
request's KV-cache pool rows, and ``--join-mid-decode`` (default on)
absorbs newly arrived same-bucket requests into free rows of in-flight
groups between decode steps. The new lifecycle knobs ride here: ``--eos-id``
stamps an end-of-sequence stop condition on every request, and
``--cancel-after N`` cancels each request after its N-th streamed token —
both release the request's cache rows/pages the same tick:

    PYTHONPATH=src python -m repro.launch.serve --scheduler \
        --requests 24 --arrival-rate 20 --slo-ms 2000
    # early termination exercises: EOS stops + client disconnects
    PYTHONPATH=src python -m repro.launch.serve --scheduler \
        --requests 24 --eos-id 450 --cancel-after 6
"""

from __future__ import annotations

import argparse
import random

import jax.numpy as jnp

from repro.configs import get_config
from repro.runtime.engine import ServingEngine
from repro.runtime.scheduler import (ContinuousBatchingScheduler,
                                     simulate_arrivals)
from repro.runtime.serve_loop import PlanServer, ServeRequest

DEFAULT_SHAPE_MIX = ((1, 40), (2, 100), (4, 60), (1, 200), (2, 250))


def _parse_shapes(spec: str):
    """``"2x100,1x40"`` -> ((2, 100), (1, 40))."""
    out = []
    for part in spec.split(","):
        try:
            b, c = part.lower().split("x")
            out.append((int(b), int(c)))
        except ValueError:
            raise SystemExit(
                f"--shapes: bad entry {part!r} (expected BATCHxCONTEXT, "
                f'e.g. "2x100,1x40")')
    return tuple(out)


def _build_server(args) -> PlanServer:
    cfg = get_config(args.arch)
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16
    # seed + recompile margin plumbed through so streams are reproducible
    # A/B runs (same model init, same recompilation predicate)
    return PlanServer(cfg, dtype=dtype, enable_cache=not args.no_cache,
                      capacity=args.cache_capacity, seed=args.seed,
                      recompile_margin=args.recompile_margin,
                      prefill=getattr(args, "prefill", False),
                      pool_arenas=args.pool_arenas,
                      pool_max_arenas=args.pool_max_arenas,
                      pool_max_bytes=args.pool_max_bytes,
                      page_size=args.page_size)


def _request_mix(args):
    mix = _parse_shapes(args.shapes) if args.shapes else DEFAULT_SHAPE_MIX
    rng = random.Random(args.seed)
    return mix, [ServeRequest(*mix[rng.randrange(len(mix))], args.tokens,
                              eos_id=args.eos_id)
                 for _ in range(args.requests)]


def serve_stream(args) -> None:
    """Sequential front door: one submit-and-drain engine pass per request
    (the plan cache + dynamic recompilation A/B harness)."""
    srv = _build_server(args)
    mix, reqs = _request_mix(args)
    print(f"# stream: {args.requests} requests over shape mix {mix} "
          f"cache={'off' if args.no_cache else 'on'}")
    for i, req in enumerate(reqs):
        out = srv.handle(req)
        flag = " RECOMPILED" if out["recompiled"] else ""
        fin = ("" if out["finish_reason"] == "length"
               else f" [{out['finish_reason']}]")
        print(f"req[{i:03d}] batch={req.batch} ctx={req.context} "
              f"-> bucket={out['bucket']} "
              f"{out['latency_s'] * 1e3:8.1f}ms{flag}{fin}")
        for r in out["recompile_reasons"]:
            print(f"         reason: {r}")
    print(srv.summary())


def serve_scheduled(args) -> None:
    """Continuous-batching mode: the engine driven with Poisson arrivals
    through the trace-replay adapter, consuming the token-event stream
    (and cancelling mid-decode when ``--cancel-after`` says the client
    hung up)."""
    srv = _build_server(args)
    mix, reqs = _request_mix(args)
    sched = ContinuousBatchingScheduler(
        srv, max_group_batch=args.max_group_batch, slo_ms=args.slo_ms,
        join_mid_decode=args.join_mid_decode)
    eng = sched.engine
    arrivals = simulate_arrivals(reqs, args.arrival_rate, seed=args.seed)
    print(f"# scheduler: {args.requests} requests over shape mix {mix} "
          f"arrival_rate={args.arrival_rate}/s "
          f"max_group_batch={args.max_group_batch} "
          f"join_mid_decode={args.join_mid_decode} "
          f"eos_id={args.eos_id} cancel_after={args.cancel_after}")

    def on_event(ev):
        if (args.cancel_after and ev.token is not None
                and ev.index + 1 >= args.cancel_after):
            handle = eng.handles.get(ev.rid)
            if handle is not None:
                eng.cancel(handle)

    sched.run(arrivals, on_event=on_event if args.cancel_after else None)
    for rec in eng.results:
        joined = (f" joined@{rec['joined_at_step']}"
                  if rec["joined_at_step"] > 0 else "")
        fin = ("" if rec["finish_reason"] == "length"
               else f" [{rec['finish_reason']}]")
        print(f"req[{rec['rid']:03d}] batch={rec['batch']} "
              f"ctx={rec['context']} -> bucket={rec['bucket']} "
              f"group={rec['group_size']}{joined} "
              f"tokens={rec['tokens'].shape[1]}{fin} "
              f"queue={rec['queue_s'] * 1e3:7.1f}ms "
              f"exec={rec['exec_s'] * 1e3:7.1f}ms")
    print(eng.summary())


def serve_once(args) -> None:
    """Single-shot mode: one request submitted into the engine, its tokens
    printed as the event stream produces them."""
    srv = _build_server(args)
    eng = ServingEngine(srv)
    req = ServeRequest(args.batch, args.context, args.tokens,
                       eos_id=args.eos_id)
    handle = eng.submit(req)
    toks = []
    t_first = None
    for ev in handle.stream():
        if ev.token is None:
            print(f"\n# finished: {ev.finish_reason}")
            break
        if t_first is None:
            t_first = ev.t
            print(f"# first token after {t_first * 1e3:.1f}ms")
        toks.append(int(ev.token[0, 0]))
        print(f"{toks[-1]}", end=" ", flush=True)
    rec = handle.result
    dt = max(1e-9, rec["exec_s"])
    n = rec["tokens"].shape[1]
    print(f"decoded {n} tokens x {req.batch} seqs in {dt:.2f}s "
          f"= {n * req.batch / dt:.1f} tok/s (bucket={rec['bucket']})")
    print(eng.summary())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--dtype", default="float32")
    # mixed-shape request-stream mode (plan cache + dynamic recompilation)
    ap.add_argument("--stream", action="store_true",
                    help="serve a mixed-shape request stream via PlanServer")
    ap.add_argument("--requests", type=int, default=16,
                    help="stream mode: number of requests")
    ap.add_argument("--shapes", default="",
                    help='stream mode: request mix as "BxC,BxC,..." '
                         "(default: built-in 5-shape mix)")
    ap.add_argument("--no-cache", action="store_true",
                    help="stream mode: disable the plan cache (A/B baseline)")
    ap.add_argument("--prefill", action="store_true",
                    help="stream mode: full prefill+decode requests with "
                         "KV-cache handoff (scheduler mode always prefills)")
    ap.add_argument("--cache-capacity", type=int, default=16)
    ap.add_argument("--pool-arenas", type=int, default=4,
                    help="KV-cache pool arenas the compile-time memory "
                         "statistics are provisioned for (pool growth past "
                         "them triggers dynamic recompilation)")
    ap.add_argument("--pool-max-arenas", type=int, default=0,
                    help="hard KV-cache pool budget in arenas (0 = "
                         "unbounded); a full pool queues new groups while "
                         "mid-decode joins keep absorbing work")
    ap.add_argument("--pool-max-bytes", type=float, default=0.0,
                    help="hard KV-cache pool budget in bytes (0 = "
                         "unbounded); with paged arenas the budget charges "
                         "page-exact committed bytes, so the same budget "
                         "admits more concurrently-resident requests")
    ap.add_argument("--page-size", type=int, default=64,
                    help="KV-cache page size in sequence slots: arenas "
                         "page the sequence dimension and rows commit only "
                         "the pages their span needs (vLLM-style); 0 "
                         "restores row-granular bucket-shaped leases")
    ap.add_argument("--recompile-margin", type=float, default=0.25,
                    help="dynamic-recompilation watermark margin")
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds model init, the request mix, and arrivals")
    # continuous-batching scheduler mode
    ap.add_argument("--scheduler", action="store_true",
                    help="coalesce requests into shared shape buckets "
                         "(continuous batching) instead of serving one-by-one")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="scheduler mode: Poisson arrivals per second "
                         "(0 = closed burst, everything arrives at t=0)")
    ap.add_argument("--max-group-batch", type=int, default=8,
                    help="scheduler mode: batch-row capacity per group")
    ap.add_argument("--join-mid-decode", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="scheduler mode: absorb newly arrived same-bucket "
                         "requests into free cache-pool rows of in-flight "
                         "groups between decode steps (token-level "
                         "continuous batching); --no-join-mid-decode "
                         "falls back to admission-time coalescing only")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="scheduler mode: per-request latency objective "
                         "(0 disables SLO accounting)")
    # request-lifecycle knobs (engine stop conditions + cancellation)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stamp an end-of-sequence stop condition on every "
                         "request: a row stops at its first eos token and "
                         "its cache rows/pages free the same tick")
    ap.add_argument("--cancel-after", type=int, default=0,
                    help="scheduler mode: cancel each request after its "
                         "N-th streamed token (simulated client disconnect; "
                         "0 disables)")
    args = ap.parse_args()

    if args.scheduler:
        serve_scheduled(args)
    elif args.stream:
        serve_stream(args)
    else:
        serve_once(args)


if __name__ == "__main__":
    main()
