"""Tensor linearization (paper §3, "Tensor Representation").

SystemML's primary data structure is a 2-D matrix; a tensor of shape
[N, C, H, W] is represented as a matrix with N rows and C*H*W columns.
The ``repro.nn`` library consumes linearized matrices exactly like
SystemML's NN library, so every layer's forward/backward is a matrix
program and all 2-D physical optimizations (sparse formats, blocking,
broadcasting) apply unchanged.
"""

from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax.numpy as jnp


def linearize(x: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    """[N, d1, ..., dk] -> ((N, d1*...*dk), trailing_shape)."""
    n = x.shape[0]
    trailing = tuple(x.shape[1:])
    return x.reshape(n, -1) if x.ndim != 2 else x, trailing


def delinearize(x2d: jnp.ndarray, trailing: Sequence[int]) -> jnp.ndarray:
    """(N, prod(trailing)) -> [N, *trailing]."""
    n, cols = x2d.shape
    expect = math.prod(trailing)
    if cols != expect:
        raise ValueError(f"cannot delinearize {x2d.shape} into {tuple(trailing)}")
    return x2d.reshape((n, *trailing))


def linearized_cols(trailing: Sequence[int]) -> int:
    return math.prod(trailing)


def conv2d_out_hw(h: int, w: int, kernel: int, stride: int, pad: int) -> Tuple[int, int]:
    """Output spatial dims for a square-kernel conv on linearized input."""
    ho = (h + 2 * pad - kernel) // stride + 1
    wo = (w + 2 * pad - kernel) // stride + 1
    return ho, wo
