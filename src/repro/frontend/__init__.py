from repro.frontend.keras2plan import Keras2Plan, generate_dml

__all__ = ["Keras2Plan", "generate_dml"]
