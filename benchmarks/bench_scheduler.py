"""Continuous-batching benchmark: coalesced scheduler throughput vs.
sequential per-request ``PlanServer.handle``, the mid-decode-join
tail-latency gate, and the paged-vs-row-granular residency gate, on the
same mixed-shape streams.

Sequential serving pads every request up to its own power-of-two bucket and
decodes it alone; the scheduler fills a bucket's batch dimension with
compatible pending requests, so the same number of decode-step launches
serves several requests at once. With the row-addressable KV-cache pool,
requests arriving behind a long decode additionally *join* free rows of the
in-flight group mid-decode instead of queueing for an arena of their own.
Block-granular paged arenas charge a byte budget only for the pages a
request's span commits — not the bucket-shaped capacity row-granular
leases pin — so the same ``--pool-max-bytes`` holds more concurrently
resident requests.

Acceptance targets (CI-enforced):

- >= 1.7x request throughput for the coalesced path over sequential;
- >= 1.3x p95 queueing-latency improvement for mid-decode joins over
  admission-only coalescing on a budget-bound pool (one arena);
- >= 1.5x peak concurrently-resident requests for paged arenas over
  row-granular under the same fixed byte budget;
- zero recompiles anywhere — dtype-, pool- and page-aware estimates mean
  no stream ever breaches its compile-time cache statistic.

    PYTHONPATH=src python benchmarks/bench_scheduler.py [--smoke]

Prints ``name,us_per_call,derived`` CSV rows (harness contract), writes the
full result set to ``BENCH_scheduler.json`` (the perf-trajectory artifact
CI uploads), and exits non-zero below any gate or on a spurious recompile.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from dataclasses import replace

import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import build_model
from repro.runtime.engine_config import EngineConfig
from repro.runtime.kv_cache import KVCachePool
from repro.runtime.scheduler import (ContinuousBatchingScheduler,
                                     simulate_arrivals)
from repro.runtime.serve_loop import ServeRequest

try:
    from benchmarks.bench_meta import scenario_meta
except ImportError:  # run as a script from the benchmarks/ directory
    from bench_meta import scenario_meta


# The coalesced-vs-sequential target was 2.0x when sequential serving
# re-decoded the prompt's first token against a zero cache and allocated a
# fresh cache blob per request. The KV-pool handoff made that *baseline*
# legitimately faster (prefill's token opens the output — one decode step
# fewer — and arenas are recycled), compressing the coalescing margin to
# ~2.0-2.4x observed; the gate sits below that floor with headroom.
TARGET_SPEEDUP = 1.7
TARGET_JOIN_P95 = 1.3
TARGET_RESIDENCY = 1.5
RESULTS_JSON = "BENCH_scheduler.json"


def _stream(smoke: bool):
    """Default mixed-shape stream: single-sequence requests (one user query
    each) over two context buckets. Sequential serving decodes each at a
    batch-1 bucket; the scheduler coalesces 8 of them into one group."""
    mix = [(1, 40), (1, 90), (1, 60), (1, 100), (1, 50), (1, 120),
           (1, 40), (1, 100), (1, 60), (1, 90), (1, 50), (1, 100),
           (1, 40), (1, 120), (1, 60), (1, 90)]
    if smoke:
        return mix, 8, 4
    return mix * 2, 8, 6


def _join_arrivals(smoke: bool):
    """Join scenario: a wide long-decode head occupies the only arena the
    pool budget allows; single-row requests arrive just behind it in the
    *same* span bucket (128). With joins they ride the head group's free
    rows mid-decode; without, they queue until the head drains."""
    head_tokens = 48 if smoke else 64            # span 60+48 -> bucket 128
    head = (0.0, (5, 60, head_tokens))
    tail = [(0.001, (1, 90 + 2 * i, 4)) for i in range(6)]   # spans ≤ 128
    return [head] + tail


def _residency(smoke: bool, arch: str):
    """Paged-vs-row-granular fragmentation scenario: batch-5 requests whose
    80-slot span sits inside a (8, 128) bucket arena, under one fixed byte
    budget. Row-granular leases charge the whole bucket arena (1024 slots)
    per group; 16-slot pages charge 5 rows x 80 slots — so the same budget
    keeps ~2.5x more requests concurrently resident. Returns
    (rows, gain, recompiles, detail)."""
    cfg = get_config(arch)
    n_req = 8 if smoke else 12
    reqs = [ServeRequest(5, 68, 12) for _ in range(n_req)]
    # budget: ~2.2 row-granular arenas' worth of bytes, from the cache spec
    # alone (no PlanServer probe — that would materialize a parameter tree)
    probe = KVCachePool(build_model(cfg, dtype=jnp.float32))
    budget = 2.2 * probe.arena_bytes(8, 128)

    peaks, recompiles, pools = {}, 0, {}
    for name, page in (("row_granular", 0), ("paged", 16)):
        ecfg = EngineConfig(cache_capacity=16, page_size=page,
                            pool_max_bytes=budget)
        srv = ecfg.build_server(cfg)
        sched = ContinuousBatchingScheduler(srv, config=ecfg)
        results = sched.run(simulate_arrivals(reqs))
        assert len(results) == n_req, (name, len(results))
        peaks[name] = sched.metrics.peak_resident
        recompiles += srv.metrics.recompiles
        pools[name] = srv.pool.metrics
    gain = peaks["paged"] / peaks["row_granular"] if peaks["row_granular"] \
        else 0.0
    pm = pools["paged"]
    rows = [
        f"paged_residency,{peaks['paged']},"
        f"row_granular={peaks['row_granular']};x={gain:.1f};"
        f"target={TARGET_RESIDENCY};pool_max_bytes={budget:.0f}",
        f"paged_page_churn,{pm.pages_leased},"
        f"freed={pm.pages_freed};denied={pm.pages_denied};"
        f"peak_pages={pm.peak_pages};"
        f"arenas_denied={pm.arenas_denied}",
    ]
    detail = {"paged_peak_resident": peaks["paged"],
              "row_granular_peak_resident": peaks["row_granular"],
              "residency_gain": gain, "pool_max_bytes": budget,
              "paged_pool": pm.as_dict()}
    return rows, gain, recompiles, detail


def _measure(smoke: bool, arch: str):
    """Returns (rows, speedup, join_gain, recompiles): CSV rows plus the
    numeric gates so CI doesn't re-parse its own formatting. All paths run
    from warm plan caches; each is timed over several trials and the best
    trial is compared (noise floor, not luck)."""
    cfg = get_config(arch)
    ecfg = EngineConfig(cache_capacity=16)
    shapes, new_tokens, trials = _stream(smoke)
    reqs = [ServeRequest(b, c, new_tokens) for b, c in shapes]

    # warm both paths: compile + trace every bucket outside measurement
    srv_seq = EngineConfig(cache_capacity=16, prefill=True).build_server(cfg)
    for b, c in sorted(set(shapes)):
        srv_seq.handle(ServeRequest(b, c, new_tokens))
    srv = ecfg.build_server(cfg)
    ContinuousBatchingScheduler(srv, config=ecfg).run(
        simulate_arrivals(reqs))

    # interleave trials so transient box load penalizes both paths alike;
    # compare best-of-trials (the noise floor, not the luck of one run)
    seq_s, coal_s, sched = None, None, None
    for _ in range(trials):
        dt = _time_trial(lambda: [srv_seq.handle(r) for r in reqs])
        if seq_s is None or dt < seq_s:
            seq_s = dt
        trial = ContinuousBatchingScheduler(srv, config=ecfg)
        dt = _time_trial(lambda: trial.run(simulate_arrivals(reqs)))
        if coal_s is None or dt < coal_s:
            coal_s, sched = dt, trial
    seq_rps = len(reqs) / seq_s
    coal_rps = len(reqs) / coal_s
    speedup = coal_rps / seq_rps if seq_rps else 0.0

    # mid-decode joins vs admission-only on a one-arena pool budget
    jcfg = EngineConfig(cache_capacity=16, pool_max_arenas=1)
    srv_join = jcfg.build_server(cfg)
    arrivals = [(t, ServeRequest(*r)) for t, r in _join_arrivals(smoke)]
    # warm every plan (incl. the batch-1 join prefill bucket) off the clock
    ContinuousBatchingScheduler(srv_join, config=jcfg).run(arrivals)
    p95 = {}
    joins = 0
    for mode in (True, False):
        best = None
        for _ in range(trials):
            trial = ContinuousBatchingScheduler(
                srv_join, config=replace(jcfg, join_mid_decode=mode))
            trial.run(arrivals)
            q95 = trial.metrics.queue_latency.percentile(95)
            if best is None or q95 < best:
                best = q95
                if mode:
                    joins = trial.metrics.joins
        p95[mode] = best
    join_gain = p95[False] / p95[True] if p95[True] else 0.0

    recompiles = (srv.metrics.recompiles + srv_seq.metrics.recompiles
                  + srv_join.metrics.recompiles)
    m = sched.metrics
    rows = [
        f"scheduler_sequential,{seq_s / len(reqs) * 1e6:.0f},"
        f"rps={seq_rps:.2f};recompiles={srv_seq.metrics.recompiles}",
        f"scheduler_coalesced,{coal_s / len(reqs) * 1e6:.0f},"
        f"rps={coal_rps:.2f};groups={m.groups};"
        f"bucket_fill={m.bucket_fill:.2f};recompiles={srv.metrics.recompiles}",
        f"scheduler_speedup,{coal_s / len(reqs) * 1e6:.0f},"
        f"x={speedup:.1f};target={TARGET_SPEEDUP}",
        f"join_p95_queue,{p95[True] * 1e6:.0f},"
        f"admission_only_us={p95[False] * 1e6:.0f};joins={joins};"
        f"x={join_gain:.1f};target={TARGET_JOIN_P95}",
    ]
    return rows, speedup, join_gain, recompiles


def _time_trial(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(smoke: bool = False, arch: str = "yi-6b-smoke"):
    """Harness entry point (benchmarks/run.py contract): CSV rows only."""
    rows = _measure(smoke, arch)[0]
    rows += _residency(smoke, arch)[0]
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream for CI (seconds, not minutes)")
    ap.add_argument("--arch", default="yi-6b-smoke")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    rows, speedup, join_gain, recompiles = _measure(args.smoke, args.arch)
    res_rows, res_gain, res_recompiles, res_detail = _residency(
        args.smoke, args.arch)
    rows += res_rows
    recompiles += res_recompiles
    for row in rows:
        print(row, flush=True)
    ok = True
    if speedup < TARGET_SPEEDUP:
        print(f"FAIL: coalesced speedup {speedup:.1f}x < "
              f"{TARGET_SPEEDUP}x target", file=sys.stderr)
        ok = False
    if join_gain < TARGET_JOIN_P95:
        print(f"FAIL: mid-decode join p95 queueing gain {join_gain:.2f}x < "
              f"{TARGET_JOIN_P95}x target", file=sys.stderr)
        ok = False
    if res_gain < TARGET_RESIDENCY:
        print(f"FAIL: paged residency gain {res_gain:.2f}x < "
              f"{TARGET_RESIDENCY}x target", file=sys.stderr)
        ok = False
    if recompiles:
        print(f"FAIL: fp32 streams burned {recompiles} recompiles "
              f"(dtype-, pool- and page-aware estimates should need zero)",
              file=sys.stderr)
        ok = False
    with open(RESULTS_JSON, "w") as f:
        json.dump({
            "bench": "scheduler", "smoke": args.smoke, "arch": args.arch,
            "meta": scenario_meta(args.arch),
            "rows": rows, "ok": ok,
            "gates": {
                "coalesced_speedup": {"value": speedup,
                                      "target": TARGET_SPEEDUP},
                "join_p95_gain": {"value": join_gain,
                                  "target": TARGET_JOIN_P95},
                "paged_residency_gain": {"value": res_gain,
                                         "target": TARGET_RESIDENCY},
                "recompiles": {"value": recompiles, "target": 0},
            },
            "residency": res_detail,
        }, f, indent=2)
        f.write("\n")
    print(f"# results -> {RESULTS_JSON}", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
