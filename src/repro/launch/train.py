"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b-smoke \
        --steps 100 --batch 8 --seq 64 --optimizer adam --lr 1e-2

Runs on whatever devices exist (CPU here, a TPU slice in production): the
plan compiler picks the execution strategy for the *actual* mesh, exactly
like SystemML picking single-node vs distributed plans per deployment.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.config import InputShape, MeshConfig, TrainConfig
from repro.configs import ARCH_IDS, get_config
from repro.core.planner import compile_plan
from repro.data import make_batch
from repro.models.model import build_model
from repro.runtime.metrics import StepTimer, format_metrics
from repro.runtime.train_loop import init_opt_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b-smoke",
                    help=f"one of {ARCH_IDS} (append -smoke for reduced)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16
    model = build_model(cfg, dtype=dtype)

    n_dev = len(jax.devices())
    mesh_cfg = MeshConfig(shape=(n_dev,), axis_names=("data",))
    shape = InputShape("cli", args.seq, args.batch, "train")
    train = TrainConfig(optimizer=args.optimizer, learning_rate=args.lr)
    plan = compile_plan(cfg, shape, mesh_cfg, train)
    print(plan.explain())

    params = model.init_params(jax.random.PRNGKey(0))
    opt = init_opt_state(args.optimizer, params, plan.config)
    step_fn = jax.jit(make_train_step(model, plan.config, mesh_cfg, train))

    timer = StepTimer(model=cfg, shape=shape, mesh=mesh_cfg)
    for i in range(args.steps):
        batch = make_batch(cfg, shape, step=i, dtype=dtype)
        timer.start()
        params, opt, metrics = step_fn(params, opt, batch, jnp.int32(i))
        rec = timer.stop(i, metrics)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(format_metrics(rec), flush=True)

    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, step=args.steps)
        print(f"saved checkpoint to {args.checkpoint}")
    summary = timer.summary()
    print("summary:", format_metrics(summary))
    assert np.isfinite(summary.get("loss", 0.0))


if __name__ == "__main__":
    main()
