"""Sparsity machinery (paper §3 "Sparse Operations") + tensor linearization
(paper §3 "Tensor Representation")."""

import jax
import jax.numpy as jnp
import numpy as np

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # minimal images: seeded deterministic fallback
    from repro.testing.hypothesis_compat import given, settings, st

from repro.core import sparsity as S
from repro.core.linearize import delinearize, linearize

KEY = jax.random.PRNGKey(0)


def _random_sparse(shape, density, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32)
    mask = rng.random(shape) < density
    return jnp.asarray(x * mask)


def test_format_selection_threshold():
    dense = S.MatrixCharacteristics(100, 100, 9000)   # density .9
    sparse = S.MatrixCharacteristics(100, 100, 1000)  # density .1
    tiny = S.MatrixCharacteristics(4, 4, 1)
    assert S.select_format(dense) == "dense"
    assert S.select_format(sparse) == "sparse"
    assert S.select_format(tiny) == "dense"  # too small to matter


def test_conv_operator_variants():
    """The paper's four physical convolution operators."""
    d = S.MatrixCharacteristics(100, 100, 10000)
    s = S.MatrixCharacteristics(100, 100, 100)
    assert S.select_conv_operator(d, d) == "conv2d_dense_dense"
    assert S.select_conv_operator(s, d) == "conv2d_sparse_dense"
    assert S.select_conv_operator(d, s) == "conv2d_dense_sparse"
    assert S.select_conv_operator(s, s) == "conv2d_sparse_sparse"


def test_spmm_matches_dense():
    a = _random_sparse((64, 80), 0.1)
    b = jnp.asarray(np.random.default_rng(1).standard_normal((80, 32)),
                    jnp.float32)
    got = S.spmm(S.to_csr(a), b)
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)


def test_csr_roundtrip():
    a = _random_sparse((33, 47), 0.2)
    np.testing.assert_array_equal(S.csr_to_dense(S.to_csr(a)), a)


@given(density=st.floats(0.01, 0.99), m=st.integers(8, 64),
       k=st.integers(8, 64), n=st.integers(4, 32))
@settings(max_examples=25, deadline=None)
def test_matmul_auto_correct_any_density(density, m, k, n):
    """Operator selection never changes the result (SystemML's contract:
    physical operators are semantics-preserving)."""
    a = _random_sparse((m, k), density, seed=m * k)
    b = jnp.asarray(np.random.default_rng(7).standard_normal((k, n)),
                    jnp.float32)
    got, op = S.matmul_auto(a, b)
    np.testing.assert_allclose(got, a @ b, rtol=2e-3, atol=2e-3)
    assert op.startswith("matmul_")


def test_sparse_flops_reduction():
    """The paper's claim: sparse-safe operations reduce FLOPs."""
    a_sparse = S.MatrixCharacteristics(1000, 1000, 10000)  # 1% dense
    b = S.MatrixCharacteristics(1000, 512, -1)
    dense_flops = 2 * 1000 * 1000 * 512
    assert S.sparse_flops_matmul(a_sparse, b) < dense_flops / 10


@given(st.lists(st.integers(1, 8), min_size=1, max_size=4),
       st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_linearize_roundtrip(trailing, n):
    shape = (n, *trailing)
    x = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
    x2d, tr = linearize(x)
    assert x2d.ndim == 2 and x2d.shape[0] == n
    np.testing.assert_array_equal(delinearize(x2d, tr), x)
