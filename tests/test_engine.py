"""ServingEngine request lifecycle (PR 5): online submission into a live
engine, per-token streaming byte-identical to batch-mode results per family
(attention / SSD / hybrid), cancellation with same-tick pool reclamation
whose freed pages become mid-decode join capacity, EOS / stop-sequence
early exits that are prefixes of the full-length decode, and the
construction-stamped request id shared by handles, results, and metrics."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.runtime.engine import ServingEngine, WallClock
from repro.runtime.scheduler import (ContinuousBatchingScheduler,
                                     simulate_arrivals)
from repro.runtime.serve_loop import PlanServer, ServeRequest

CFG = get_config("yi-6b-smoke")


# ---------------------------------------------------------------------------
# streaming == batch, per family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-1.3b", "recurrentgemma-2b"])
def test_streamed_tokens_match_batch_mode(arch):
    """Consuming per-token events yields byte-identical tokens to reading
    the completion records of a batch run — streaming is observation, not
    a different execution path."""
    cfg = get_config(arch + "-smoke")
    srv = PlanServer(cfg, dtype=jnp.float32, capacity=16)
    reqs = [ServeRequest(1, 20, 3), ServeRequest(2, 28, 3),
            ServeRequest(1, 24, 4)]
    batch = ContinuousBatchingScheduler(srv, max_group_batch=8).run(
        simulate_arrivals(reqs))
    batch_toks = {r["rid"]: np.asarray(r["tokens"]) for r in batch}

    # same server (same params, warm plans), fresh engine, event consumers
    eng = ServingEngine(srv)
    again = [ServeRequest(r.batch, r.context, r.new_tokens) for r in reqs]
    handles = [eng.submit(r) for r in again]
    streamed = {h.rid: [] for h in handles}
    for ev in eng.events():
        if ev.token is not None:
            streamed[ev.rid].append(np.asarray(ev.token))
    for orig, h in zip(reqs, handles):
        got = np.concatenate(streamed[h.rid], axis=1)
        np.testing.assert_array_equal(got, batch_toks[orig.rid])
        # the completion record agrees with the event stream
        np.testing.assert_array_equal(got, np.asarray(h.result["tokens"]))
        assert h.result["finish_reason"] == "length"


def test_handle_is_engine_adapter_with_same_tokens():
    """PlanServer.handle (sequential front door) and the engine (batch
    front door) produce identical tokens for the same request shape."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16, prefill=True)
    out = srv.handle(ServeRequest(2, 20, 4))
    assert out["finish_reason"] == "length"
    eng = ServingEngine(srv)
    h = eng.submit(ServeRequest(2, 20, 4))
    eng.drain()
    np.testing.assert_array_equal(np.asarray(out["tokens"]),
                                  np.asarray(h.result["tokens"]))


# ---------------------------------------------------------------------------
# online submission (no pre-sorted trace)
# ---------------------------------------------------------------------------


def test_online_submission_joins_live_engine():
    """Requests submitted while the engine is mid-decode are absorbed into
    in-flight groups — the scenario the run(arrivals) API could not
    express (it demanded the whole trace up front)."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    eng = ServingEngine(srv, clock=WallClock())
    a = eng.submit(ServeRequest(5, 100, 6))    # (8, 128) bucket: 3 free rows
    eng.step()                                 # a's group is now in flight
    b = eng.submit(ServeRequest(1, 90, 2))     # same span bucket (128)
    eng.drain()
    assert a.result is not None and b.result is not None
    assert b.result["joined_at_step"] >= 1
    assert eng.metrics.joins == 1
    # streaming latency accounting ran for both requests
    assert eng.metrics.ttft_latency.count == 2
    assert eng.metrics.itl_latency.count > 0
    assert "ttft" in eng.summary()


def test_stream_yields_incrementally_and_cancels():
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    eng = ServingEngine(srv)
    h = eng.submit(ServeRequest(1, 40, 16))
    it = h.stream()
    evs = [next(it), next(it), next(it)]
    assert [e.index for e in evs] == [0, 1, 2]
    assert h.result is None                    # still mid-decode
    assert h.tokens().shape[1] >= 3            # partial output visible
    assert h.cancel()
    rest = list(it)
    assert rest and rest[-1].done
    assert rest[-1].finish_reason == "cancelled"
    assert h.state == "cancelled"
    # the partial output is what was streamed
    n = np.asarray(h.result["tokens"]).shape[1]
    assert n == 3 + sum(1 for e in rest if e.token is not None)
    eng.drain()


# ---------------------------------------------------------------------------
# cancellation frees pool capacity the same tick
# ---------------------------------------------------------------------------


def test_cancel_mid_decode_reclaims_pool_and_admits_join():
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16, pool_max_arenas=1)
    eng = ServingEngine(srv)
    a = eng.submit(ServeRequest(4, 100, 24))
    b = eng.submit(ServeRequest(2, 100, 24))
    for _ in range(3):
        eng.step()                       # one group: a + b, long decode
    c = eng.submit(ServeRequest(4, 90, 3))
    eng.step()
    # c fits neither the group's 2 free rows nor a second arena (pool cap)
    assert c.state == "queued"
    live = srv.pool.live_bytes()
    assert eng.cancel(a)
    assert a.state == "cancelled"
    assert a.result["finish_reason"] == "cancelled"
    # rows, committed pages, and the undrawn span reservation came back
    # the moment cancel() ran — no tick in between
    assert srv.pool.live_bytes() < live
    assert srv.pool.metrics.pages_reclaimed > 0
    assert np.asarray(a.result["tokens"]).shape[1] >= 1   # partial output
    eng.drain()
    # the freed rows admitted c mid-decode into the surviving group
    assert c.result["finish_reason"] == "length"
    assert c.result["joined_at_step"] >= 1
    assert eng.metrics.joins >= 1
    assert eng.metrics.cancelled == 1
    assert b.result["finish_reason"] == "length"


def test_cancel_queued_request_never_runs():
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16, pool_max_arenas=1)
    eng = ServingEngine(srv)
    a = eng.submit(ServeRequest(4, 100, 6))
    eng.step()
    b = eng.submit(ServeRequest(8, 100, 4))    # 8 rows: can't join or form
    eng.step()
    assert b.state == "queued"
    assert eng.cancel(b)
    assert b.state == "cancelled"
    assert np.asarray(b.result["tokens"]).shape == (8, 0)
    eng.drain()
    assert a.result["finish_reason"] == "length"
    assert eng.metrics.cancelled == 1 and eng.metrics.completed == 1
    assert not eng.cancel(b)                   # already finished


# ---------------------------------------------------------------------------
# stop conditions: eos + stop sequences
# ---------------------------------------------------------------------------


def _full_decode(srv, req):
    rec = ContinuousBatchingScheduler(srv, max_group_batch=8).run(
        simulate_arrivals([req]))[0]
    return np.asarray(rec["tokens"])[0]


def test_eos_early_exit_is_prefix_of_full_decode():
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    full = _full_decode(srv, ServeRequest(1, 30, 8))
    eos = int(full[2])
    j = int(np.argmax(full == eos))            # first occurrence wins
    eng = ServingEngine(srv)
    h = eng.submit(ServeRequest(1, 30, 8, eos_id=eos))
    eng.drain()
    out = np.asarray(h.result["tokens"])[0]
    assert h.result["finish_reason"] == "eos"
    assert out.tolist() == full[: j + 1].tolist()
    assert eng.metrics.early_exits == 1
    # early exit reclaimed the row's remaining capacity
    assert srv.pool.metrics.pages_reclaimed > 0


def test_stop_sequence_early_exit_is_prefix_of_full_decode():
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    full = _full_decode(srv, ServeRequest(1, 30, 8))
    stop = (int(full[1]), int(full[2]))
    j = next(i for i in range(len(full))
             if i + 1 >= len(stop)
             and full[i - 1: i + 1].tolist() == list(stop))
    eng = ServingEngine(srv)
    h = eng.submit(ServeRequest(1, 30, 8, stop=(stop,)))
    eng.drain()
    out = np.asarray(h.result["tokens"])[0]
    assert h.result["finish_reason"] == "stop"
    assert out.tolist() == full[: j + 1].tolist()


def test_eos_with_max_tokens_still_bounded():
    """eos that never fires: the request completes at new_tokens with
    reason 'length' (stop conditions never extend a decode)."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    full = _full_decode(srv, ServeRequest(1, 30, 4))
    eos = int(max(full)) + 1                   # not a token it emits
    eng = ServingEngine(srv)
    h = eng.submit(ServeRequest(1, 30, 4, eos_id=eos))
    eng.drain()
    assert h.result["finish_reason"] == "length"
    assert np.asarray(h.result["tokens"])[0].tolist() == full.tolist()


# ---------------------------------------------------------------------------
# stable request ids
# ---------------------------------------------------------------------------


def test_rid_stamped_at_construction():
    r1 = ServeRequest(1, 40, 2)
    r2 = ServeRequest(1, 40, 2)
    assert r2.rid == r1.rid + 1                # monotone, stamped at birth
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    eng = ServingEngine(srv)
    h = eng.submit(r2)
    eng.drain()
    # handle, queue record, completion record, and request all agree
    assert h.rid == r2.rid == h.result["rid"] == h.qr.rid
    out = srv.handle(r1)
    assert out["rid"] == r1.rid
