"""EngineRouter + EngineConfig/EngineClient (PR 6): one config surface and
one client protocol over N replicas — replicas=1 is the bare engine with
identical tokens; placement is deterministic for identical traces;
no replica idles while another holds queued work (work stealing); a
drained replica's in-flight requests finish on the survivors with token
streams byte-identical to an undisturbed run; and the legacy per-class
kwargs still work but warn."""

import argparse

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.runtime.engine import (EngineClient, RequestQueue, ServingEngine,
                                  WallClock)
from repro.runtime.engine_config import EngineConfig
from repro.runtime.router import EngineRouter
from repro.runtime.scheduler import (ContinuousBatchingScheduler,
                                     simulate_arrivals)
from repro.runtime.serve_loop import PlanServer, ServeRequest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    from repro.testing.hypothesis_compat import given, settings, st

CFG = get_config("yi-6b-smoke")
ECFG = EngineConfig(replicas=2)


@pytest.fixture(scope="module")
def fleet_servers():
    """Two replica servers shared by the decode-heavy tests (plan caches
    warm up across tests; params are seed-identical by construction)."""
    return [ECFG.build_server(CFG) for _ in range(2)]


# ---------------------------------------------------------------------------
# EngineConfig: one surface, legacy kwargs as deprecated shims
# ---------------------------------------------------------------------------


def test_legacy_kwargs_fold_into_config_and_warn():
    # conftest's autouse fixture resets the once-per-process registry
    with pytest.warns(DeprecationWarning, match="PlanServer"):
        srv = PlanServer(CFG, dtype=jnp.float32, capacity=4)
    assert srv.config.cache_capacity == 4
    assert srv.config.dtype == "float32"
    with pytest.warns(DeprecationWarning, match="ServingEngine"):
        eng = ServingEngine(srv, max_group_batch=4)
    assert eng.config.max_group_batch == 4
    # the config the server carries seeds the engine's unless overridden
    assert eng.config.cache_capacity == 4


def test_config_from_args_maps_argparse_spellings():
    ns = argparse.Namespace(dtype="bfloat16", no_cache=True, replicas=3,
                            placement="load", bucket_select="arrival",
                            max_group_batch=4, seed=7)
    cfg = EngineConfig.from_args(ns)
    assert cfg.dtype == "bfloat16"
    assert cfg.enable_cache is False
    assert cfg.replicas == 3 and cfg.placement == "load"
    assert cfg.bucket_select == "arrival" and cfg.max_group_batch == 4
    assert cfg.seed == 7
    # partial namespaces keep defaults
    assert EngineConfig.from_args(argparse.Namespace()).replicas == 1


def test_config_validates_choices():
    with pytest.raises(ValueError):
        EngineConfig(dtype="float16")
    with pytest.raises(ValueError):
        EngineConfig(placement="random")
    with pytest.raises(ValueError):
        EngineConfig(bucket_select="lifo")
    with pytest.raises(ValueError):
        EngineConfig(replicas=0)


# ---------------------------------------------------------------------------
# EngineClient: one protocol, engine and router both satisfy it
# ---------------------------------------------------------------------------


def test_engine_client_protocol_both_implementations(fleet_servers):
    eng = ServingEngine(fleet_servers[0], config=ECFG)
    router = EngineRouter(fleet_servers, config=ECFG)
    assert isinstance(eng, EngineClient)
    assert isinstance(router, EngineClient)
    # build_client is the topology switch: 1 -> bare engine, N -> router
    assert isinstance(EngineConfig().build_client(
        CFG, servers=[fleet_servers[0]]), ServingEngine)
    assert isinstance(ECFG.build_client(CFG, servers=fleet_servers),
                      EngineRouter)


def test_replicas_one_is_the_bare_engine_with_identical_tokens():
    """--replicas 1 through build_client must be indistinguishable from
    constructing the engine directly: same type, same tokens."""
    cfg = EngineConfig()
    client = cfg.build_client(CFG)
    assert isinstance(client, ServingEngine)
    reqs = [ServeRequest(1, 20, 3), ServeRequest(2, 28, 3)]
    via_client = {r["rid"] - reqs[0].rid: np.asarray(r["tokens"])
                  for r in client.run(simulate_arrivals(reqs))}
    eng = cfg.build_engine(cfg.build_server(CFG))
    again = [ServeRequest(r.batch, r.context, r.new_tokens) for r in reqs]
    direct = {r["rid"] - again[0].rid: np.asarray(r["tokens"])
              for r in eng.run(simulate_arrivals(again))}
    assert via_client.keys() == direct.keys()
    for k in via_client:
        np.testing.assert_array_equal(via_client[k], direct[k])


# ---------------------------------------------------------------------------
# router lifecycle: completion, balance, summary
# ---------------------------------------------------------------------------


def test_router_completes_all_and_uses_both_replicas(fleet_servers):
    router = EngineRouter(fleet_servers, config=ECFG)
    reqs = [ServeRequest(4, 48, 4) for _ in range(8)]
    recs = router.run(simulate_arrivals(reqs))
    assert len(recs) == len(reqs)
    assert {r["rid"] for r in recs} == {r.rid for r in reqs}
    per = [r.engine.metrics.admitted for r in router.replicas]
    assert all(n > 0 for n in per), per
    assert router.metrics.completed >= len(reqs)
    s = router.summary()
    assert "replica[0]" in s and "replica[1]" in s and "fleet:" in s


def test_router_stream_and_cancel(fleet_servers):
    router = EngineRouter(fleet_servers, config=ECFG)
    keep = router.submit(ServeRequest(1, 40, 4))
    victim = router.submit(ServeRequest(1, 40, 12))
    seen = 0
    for ev in victim.stream():
        if ev.token is not None:
            seen += 1
            if seen == 2:
                assert victim.cancel()
        if ev.done:
            assert ev.finish_reason == "cancelled"
    router.drain()
    assert keep.done and keep.result["finish_reason"] == "length"
    assert victim.result["tokens"].shape[1] == 2


# ---------------------------------------------------------------------------
# placement: deterministic for identical traces (property)
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.lists(st.tuples(st.sampled_from([1, 2, 4]),
                          st.sampled_from([40, 52, 100, 112])),
                min_size=2, max_size=6))
def test_placement_determinism_property(shapes):
    """Identical request sequences into identically-built fleets place
    identically: the affinity score reads only discrete replica state,
    never the wall clock."""
    decisions = []
    for _ in range(2):
        router = EngineRouter([ECFG.build_server(CFG) for _ in range(2)],
                              config=ECFG)
        for b, c in shapes:
            router.submit(ServeRequest(b, c, 4), arrival_s=0.0)
        decisions.append([(d.replica, d.reason) for d in router.decisions])
    assert decisions[0] == decisions[1]


# ---------------------------------------------------------------------------
# starvation-freedom: no replica idles while another holds queued work
# ---------------------------------------------------------------------------


@settings(max_examples=5, deadline=None)
@given(st.lists(st.sampled_from([(1, 40, 4), (1, 100, 4), (2, 44, 4)]),
                min_size=3, max_size=7))
def test_starvation_freedom_property(shapes, _fleet=[]):
    """At every tick boundary (after the tick's rebalance), no replica
    sits idle while another replica still holds queued work — placement
    prefers idle replicas and work stealing migrates leftover backlog."""
    if not _fleet:  # warm fleet shared across examples (plan caches fill)
        _fleet.append(EngineRouter(
            [ECFG.build_server(CFG) for _ in range(2)], config=ECFG))
    router = _fleet[0]
    for b, c, n in shapes:
        router.submit(ServeRequest(b, c, n))
    while not router.idle:
        router.step()
        router._rebalance()  # what the next tick would apply first
        for r in router.replicas:
            queued_elsewhere = any(len(d.engine.queue)
                                   for d in router.replicas if d is not r)
            assert not (r.engine.idle and queued_elsewhere), (
                f"replica {r.idx} idle while another replica has "
                f"queued work")
    assert not router.handles


# ---------------------------------------------------------------------------
# failover: drain moves live work, zero loss, byte-identical streams
# ---------------------------------------------------------------------------


def test_drain_replica_failover_zero_loss_token_equality():
    shapes = [(1, 40, 8), (1, 44, 8), (1, 52, 8),
              (1, 40, 8), (1, 56, 8), (1, 48, 8)]

    # undisturbed reference decode per shape: replicas share seed-derived
    # params and greedy decode is group-composition-invariant, so one
    # clean run is ground truth for any replica
    ref_srv = ECFG.build_server(CFG)
    reqs_ref = [ServeRequest(*s) for s in shapes]
    ref = {}
    for rec in ContinuousBatchingScheduler(ref_srv).run(
            simulate_arrivals(reqs_ref)):
        ref[rec["rid"]] = np.asarray(rec["tokens"])
    by_shape = {}
    for r, s in zip(reqs_ref, shapes):
        by_shape.setdefault(s, ref[r.rid])

    router = EngineRouter([ECFG.build_server(CFG) for _ in range(2)],
                          config=ECFG)
    reqs = [ServeRequest(*s) for s in shapes]
    streamed = {}
    fired = {"done": False}

    def on_event(ev):
        if (not fired["done"] and ev.token is not None and ev.index >= 2
                and any(h.replica is not None and h.replica.idx == 1
                        for h in router.handles.values())):
            moved = router.drain_replica(1)
            assert moved, "drain found no live work to move"
            fired["done"] = True
        if ev.token is not None:
            streamed.setdefault(ev.rid, []).append(np.asarray(ev.token))

    res = router.run(simulate_arrivals(reqs, rate_per_s=200, seed=3),
                     on_event=on_event)
    assert fired["done"], "drain trigger never fired"
    assert len(res) == len(reqs)                      # zero loss
    assert router.router_metrics.resubmitted > 0
    for r, s in zip(reqs, shapes):
        toks = np.concatenate(streamed[r.rid], axis=1)
        # gapless, byte-identical stream despite the mid-decode move
        np.testing.assert_array_equal(toks, by_shape[s])
        rec = next(x for x in res if x["rid"] == r.rid)
        np.testing.assert_array_equal(toks, np.asarray(rec["tokens"]))
    # the drained replica took no further placements
    assert all(d.replica != 1 for d in router.decisions
               if d.t > 0 and d.reason == "failover")


def test_cannot_drain_last_replica_and_restore_rejoins(fleet_servers):
    router = EngineRouter(fleet_servers, config=ECFG)
    router.drain_replica(1)
    with pytest.raises(ValueError):
        router.drain_replica(0)
    assert router.router_metrics.drained == 1
    router.restore_replica(1)
    assert router.router_metrics.drained == 0
    assert not router.replicas[1].draining


# ---------------------------------------------------------------------------
# arrival-aware bucket selection (RequestQueue select="arrival")
# ---------------------------------------------------------------------------


def test_arrival_select_prefers_most_coalescable_bucket():
    q = RequestQueue(select="arrival", max_group_batch=8)
    head = ServeRequest(1, 50, 8)          # span 58  -> bucket 64
    q.admit(head)
    wide = [ServeRequest(1, 100, 8) for _ in range(3)]   # bucket 128
    for r in wide:
        q.admit(r)
    g1 = q.next_group()
    assert {qr.rid for qr in g1} == {r.rid for r in wide}
    g2 = q.next_group()                    # deferred head forms next
    assert [qr.rid for qr in g2] == [head.rid]

    # strict head-of-line forms the oldest request's bucket first
    q_hol = RequestQueue(select="hol", max_group_batch=8)
    q_hol.admit(ServeRequest(1, 50, 8))
    for _ in range(3):
        q_hol.admit(ServeRequest(1, 100, 8))
    assert len(q_hol.next_group()) == 1    # the lone bucket-64 head


def test_arrival_select_bounded_deferral_forces_head():
    q = RequestQueue(select="arrival", max_group_batch=8, max_defer=3)
    head = ServeRequest(1, 50, 8)          # bucket 64: a one-row minority
    q.admit(head)
    served_head_after = None
    for i in range(10):
        q.admit(ServeRequest(1, 100, 8))   # bucket 128 keeps arriving
        q.admit(ServeRequest(1, 100, 8))
        g = q.next_group()
        if head.rid in {qr.rid for qr in g}:
            served_head_after = i
            break
    # the head bucket is passed over at most max_defer times
    assert served_head_after is not None and served_head_after <= 3
