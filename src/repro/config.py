"""Configuration system.

Mirrors SystemML's separation of *script* (model definition), *data
characteristics* (input shapes), and *cluster characteristics* (mesh +
hardware budgets): the plan compiler in ``repro.core.planner`` consumes all
three and emits an execution plan, exactly as SystemML's optimizer consumes
DML + data + cluster configs.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Hardware characteristics (TPU v5e target; the runtime here is CPU-only and
# these constants feed the cost model / roofline, not execution).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bandwidth: float = 819e9        # bytes/s per chip
    ici_bandwidth: float = 50e9         # bytes/s per ICI link
    hbm_bytes: int = 16 * 1024**3       # per-chip HBM capacity
    vmem_bytes: int = 128 * 1024 * 1024  # per-core VMEM (v5e ~128 MiB)
    mxu_dim: int = 128                  # systolic array tile edge


TPU_V5E = HardwareSpec()


# ---------------------------------------------------------------------------
# Mesh configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    """Logical device mesh. ``data_axes`` are the axes batch is sharded over;
    ``model_axis`` carries tensor/expert parallelism."""

    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axis_names if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model"

    @property
    def data_parallelism(self) -> int:
        n = 1
        for s, a in zip(self.shape, self.axis_names):
            if a in ("pod", "data"):
                n *= s
        return n

    @property
    def model_parallelism(self) -> int:
        for s, a in zip(self.shape, self.axis_names):
            if a == "model":
                return s
        return 1


SINGLE_POD_MESH = MeshConfig(shape=(16, 16), axis_names=("data", "model"))
MULTI_POD_MESH = MeshConfig(shape=(2, 16, 16), axis_names=("pod", "data", "model"))
SINGLE_DEVICE_MESH = MeshConfig(shape=(1,), axis_names=("data",))


def mesh_config(multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH


# ---------------------------------------------------------------------------
# Input shapes ("data characteristics")
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4

    # Hybrid (recurrentgemma): per-block pattern; "r"=RG-LRU, "a"=local attn.
    block_pattern: str = ""        # e.g. "rra" repeated
    window_size: int = 0           # local/sliding attention window (0 = full)
    lru_width: int = 0             # RG-LRU recurrent width (0 = d_model)

    # Encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0           # fixed encoder sequence (1500 audio frames)

    # Modality frontend stub: embeddings supplied by input_specs()
    frontend: str = "none"         # none | audio | vision
    num_frontend_tokens: int = 0   # vision: prefix patch tokens

    # Common
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act_dtype: str = "bfloat16"
    # Sliding-window serving variant for full-attention archs on long_500k
    # (DESIGN.md §5). 0 means "arch is natively sub-quadratic or full".
    serve_window: int = 8_192

    citation: str = ""

    # ----- derived -------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can serve long_500k natively (SSM / hybrid local-attn)."""
        return self.family in ("ssm", "hybrid")

    def layer_pattern(self) -> str:
        """Per-layer block kinds: 'a' attention, 'r' RG-LRU, 's' SSD."""
        if self.family == "ssm":
            return "s" * self.num_layers
        if self.block_pattern:
            pat = (self.block_pattern * (self.num_layers // len(self.block_pattern) + 1))
            return pat[: self.num_layers]
        return "a" * self.num_layers

    def param_count(self) -> int:
        """Analytic parameter count (used by the memory estimator + the
        6·N·D MODEL_FLOPS roofline term)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        n = 0
        # embeddings (+ untied head)
        n += v * d
        if not self.tie_embeddings:
            n += v * d
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d  # q,k,v,o
        # SwiGLU (gate,up,down) everywhere except whisper's 2-matrix GELU MLP
        dense_ffn = (2 if self.family == "audio" else 3) * d * f
        per_layer = {
            "a": attn + dense_ffn,
            "s": self._ssd_layer_params(),
            "r": self._rglru_layer_params() ,
        }
        for kind in self.layer_pattern():
            blk = per_layer[kind]
            if kind == "a" and self.num_experts:
                blk = attn + self.num_experts * dense_ffn + d * self.num_experts
            n += blk + 2 * d  # two norms
        if self.is_encdec:
            enc_layer = attn + dense_ffn + 2 * d
            cross = attn + d
            n += self.encoder_layers * enc_layer + self.num_layers * cross
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_ffn = 3 * d * f
        inactive = (self.num_experts - self.experts_per_token) * dense_ffn
        return self.param_count() - self.num_layers * inactive

    def _ssd_layer_params(self) -> int:
        d, di = self.d_model, self.d_inner
        nh, st = self.ssm_num_heads, self.ssm_state
        # in_proj (z,x,B,C,dt), conv, A, D, norm, out_proj
        conv_dim = di + 2 * st
        return (
            d * (2 * di + 2 * st + nh)
            + self.ssm_conv_width * conv_dim
            + 2 * nh
            + di
            + di * d
        )

    def _rglru_layer_params(self) -> int:
        d = self.d_model
        w = self.lru_width or d
        # gates + in/out proj + conv, following RG-LRU (Griffin) block shape
        return 2 * d * w + 2 * w * w + w * d + 4 * w

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family / block structure, tiny dims."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=min(cfg.d_model, 128),
        num_heads=min(cfg.num_heads, 4),
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=32,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        serve_window=64,
    )
    if cfg.num_experts:
        # dropless capacity (cap >= tokens/group): smoke correctness tests
        # must not depend on which tokens a full forward capacity-drops
        kw.update(num_experts=4, experts_per_token=2, moe_capacity_factor=2.0)
    if cfg.family == "ssm":
        kw.update(ssm_state=16, ssm_head_dim=16)
    if cfg.family == "hybrid":
        kw.update(window_size=32, lru_width=128)
    if cfg.is_encdec:
        kw.update(encoder_layers=2, encoder_seq=64)
    if cfg.frontend == "vision":
        kw.update(num_frontend_tokens=16)
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
# Training / serving run configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adam"          # one of repro.nn.optim.OPTIMIZERS
    learning_rate: float = 3e-4
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    microbatch: Optional[int] = None  # per-step microbatch (grad accumulation)
    remat: bool = True
    seed: int = 0
    steps: int = 100
    log_every: int = 10
    # planner knobs (None = let the compiler decide, SystemML-style)
    force_strategy: Optional[str] = None
    opt_state_dtype: Optional[str] = None  # "float32" | "bfloat16" | None=auto


@dataclass(frozen=True)
class RunSpec:
    """One (model × shape × mesh) work item — the planner's unit of input."""

    model: ModelConfig
    shape: InputShape
    mesh: MeshConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    hardware: HardwareSpec = TPU_V5E
