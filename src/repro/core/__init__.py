"""repro.core — the paper's compiler: memory-estimate-driven, cost-based
generation of distributed execution plans (see DESIGN.md §1 C1)."""

from repro.core.planner import PlanCompiler, compile_plan
from repro.core.strategies import ExecutionPlan, PlanConfig, RuntimeStats, Strategy
from repro.core.memory import MemoryEstimate, estimate_memory
from repro.core.cost import CostEstimate, analytic_cost, roofline_terms
from repro.core.sharding import spec_for, tree_specs
from repro.core.parfor import parfor, choose_parfor_plan, count_collectives
from repro.core.plan_cache import (BucketPolicy, CacheEntry, PlanCache,
                                   PlanCacheMetrics, PlanKey, bucket_pow2,
                                   recompile_reasons)

__all__ = [
    "PlanCompiler", "compile_plan", "ExecutionPlan", "PlanConfig", "Strategy",
    "RuntimeStats", "MemoryEstimate", "estimate_memory", "CostEstimate",
    "analytic_cost", "roofline_terms", "spec_for", "tree_specs", "parfor",
    "choose_parfor_plan", "count_collectives", "BucketPolicy", "CacheEntry",
    "PlanCache", "PlanCacheMetrics", "PlanKey", "bucket_pow2",
    "recompile_reasons",
]
