"""Sharding-aware checkpointing (npz-based; no external deps).

Saves a flat {path: array} mapping plus a manifest. On restore, arrays are
``jax.device_put`` with the *target plan's* shardings — so a checkpoint
written under one execution plan restores under another (the resharding
rides on device_put), which is exactly how a SystemML-style compiler lets
the same program move between cluster shapes.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save_checkpoint(path: str, params: Any, step: int = 0,
                    extra: Optional[Dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, like: Any, shardings: Optional[Any] = None):
    """``like``: pytree with the same structure (arrays or
    ShapeDtypeStructs). ``shardings``: optional matching tree of
    NamedShardings applied at restore."""
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    like_flat = _flatten(like)
    if set(like_flat) != set(flat):
        missing = set(like_flat) - set(flat)
        extra = set(flat) - set(like_flat)
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")

    shard_flat = _flatten(shardings) if shardings is not None else {}
    restored = {}
    for k, template in like_flat.items():
        arr = flat[k]
        if tuple(arr.shape) != tuple(template.shape):
            raise ValueError(f"{k}: shape {arr.shape} != {template.shape}")
        arr = arr.astype(template.dtype)
        if k in shard_flat and shard_flat[k] is not None:
            restored[k] = jax.device_put(arr, shard_flat[k])
        else:
            restored[k] = jnp.asarray(arr)

    # rebuild the original structure
    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            seq = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(seq)
        return restored[prefix[:-1]]

    return rebuild(like), manifest["step"]
