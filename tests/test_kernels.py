"""Per-kernel allclose sweeps against the pure-jnp oracles (ref.py),
executed in Pallas interpret mode (TPU is the deploy target; interpret
runs the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv2d_im2col import conv2d_im2col
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128), (256, 384, 128), (100, 70, 50), (17, 33, 9),
    (512, 128, 256), (8, 8, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul(m, k, n, dtype):
    a = jax.random.normal(KEY, (m, k), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    got = matmul(a, b, interpret=True)
    want = ref.matmul_ref(a, b)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,c,h,w,f,kern,stride,pad", [
    (2, 3, 8, 8, 4, 3, 1, 1),
    (1, 1, 12, 12, 8, 5, 2, 2),
    (3, 4, 16, 16, 16, 3, 1, 0),
    (2, 2, 10, 10, 6, 3, 2, 1),
])
def test_conv2d_im2col(n, c, h, w, f, kern, stride, pad):
    x = jax.random.normal(KEY, (n, c, h, w), jnp.float32)
    wt = jax.random.normal(jax.random.PRNGKey(1), (f, c, kern, kern), jnp.float32)
    got = conv2d_im2col(x, wt, stride=stride, pad=pad, interpret=True)
    want = ref.conv2d_ref(x, wt, stride=stride, pad=pad)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,causal,window", [
    (2, 4, 2, 64, 64, 32, True, 0),
    (1, 8, 2, 128, 128, 64, True, 0),
    (2, 4, 4, 64, 64, 32, False, 0),
    (2, 4, 2, 64, 64, 32, True, 16),   # sliding window
    (1, 2, 1, 1, 96, 32, True, 0),     # decode: single query
    (1, 2, 1, 100, 100, 32, True, 0),  # non-tile-aligned
    (2, 4, 1, 64, 64, 32, True, 0),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, hq, hkv, sq, sk, d, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          bq=32, bk=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 64, 3, 8, 16, 16),
    (1, 32, 2, 16, 8, 8),
    (2, 128, 4, 8, 32, 32),
    (1, 64, 1, 32, 64, 16),
])
def test_ssd_scan(b, s, h, p, n, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    cm = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    d = jnp.full((h,), 0.5)
    got = ssd_scan(x, dt, a, bm, cm, d, chunk=chunk, interpret=True)
    want, _ = ref.ssd_ref(x, dt, a, bm, cm, d)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_ssd_chunked_ref_matches_sequential():
    ks = jax.random.split(KEY, 5)
    B, S, H, P, N = 2, 64, 3, 8, 16
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    bm = jax.random.normal(ks[3], (B, S, N))
    cm = jax.random.normal(ks[4], (B, S, N))
    d = jnp.ones((H,))
    y1, s1 = ref.ssd_ref(x, dt, a, bm, cm, d)
    y2, s2 = ref.ssd_chunked_ref(x, dt, a, bm, cm, d, chunk=16)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


def test_ops_dispatch_fallback():
    """On CPU (auto backend) ops fall back to XLA; forcing pallas uses
    interpret mode — both match the oracle (the C7 dispatch contract)."""
    a = jax.random.normal(KEY, (64, 64))
    b = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    want = ref.matmul_ref(a, b)
    old = ops.BACKEND
    try:
        ops.BACKEND = "xla"
        np.testing.assert_allclose(ops.matmul(a, b), want, rtol=1e-5)
        ops.BACKEND = "pallas"
        np.testing.assert_allclose(ops.matmul(a, b), want, rtol=1e-5)
    finally:
        ops.BACKEND = old
