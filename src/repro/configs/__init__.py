"""Architecture registry: ``--arch <id>`` resolves through here."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig, reduced_config

# arch id -> module name
_ARCH_MODULES = {
    "whisper-medium": "whisper_medium",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "llama3-405b": "llama3_405b",
    "yi-6b": "yi_6b",
    "mamba2-1.3b": "mamba2_1_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "dbrx-132b": "dbrx_132b",
    "internvl2-2b": "internvl2_2b",
    "granite-8b": "granite_8b",
    "phi3-medium-14b": "phi3_medium_14b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch.endswith("-smoke"):
        return reduced_config(get_config(arch[: -len("-smoke")]))
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.make_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
