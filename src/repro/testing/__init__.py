from repro.testing.hypothesis_compat import given, settings, st  # noqa: F401
