"""LeNet (one of the paper's demo models) trained with the manual-backward
NN library on synthetic image classification — conv/pool/dropout layers
flowing as linearized (N, C*H*W) matrices, exactly like SystemML's NN
library.

    PYTHONPATH=src python examples/train_lenet.py [--epochs 2]
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import numpy as np

from repro.configs.lenet import make_spec
from repro.frontend import Keras2Plan


def synthetic_images(n, num_classes=5, size=16, seed=0):
    """Classes are distinguishable blob patterns + noise."""
    rng = np.random.default_rng(seed)
    ys = rng.integers(0, num_classes, n)
    xs = rng.standard_normal((n, 1, size, size)).astype(np.float32) * 0.3
    for i, y in enumerate(ys):
        r, c = divmod(int(y), 3)
        xs[i, 0, 4 * r + 2:4 * r + 6, 4 * c + 2:4 * c + 6] += 2.0
    onehot = np.eye(num_classes, dtype=np.float32)[ys]
    return xs.reshape(n, -1), onehot  # linearized (N, C*H*W)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--n", type=int, default=1024)
    args = ap.parse_args()

    spec, meta = make_spec(input_shape=(1, 16, 16), num_classes=5)
    x, y = synthetic_images(args.n)
    xt, yt = synthetic_images(256, seed=1)

    model = Keras2Plan(spec, meta, optimizer="sgd_momentum", lr=0.01,
                       batch_size=32, epochs=args.epochs)
    model.fit(x, y)
    print(f"loss: {model.history[0]:.3f} -> {model.history[-1]:.3f}")
    acc = model.score(xt, yt)
    print(f"test accuracy: {acc:.3f}")
    assert acc > 0.6
    print("OK")


if __name__ == "__main__":
    main()
