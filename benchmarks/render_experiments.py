"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
records (experiments/dryrun/*.json). Invoked manually after a sweep:

    PYTHONPATH=src python -m benchmarks.render_experiments > experiments/tables.md
"""

from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
GIB = 1024**3


def load(pattern):
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def dryrun_table():
    lines = [
        "| arch | shape | mesh | ok | strategy | plan notes | peak GiB/chip "
        "| args GiB | compile s | collectives (count / GiB per chip-step) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load("*.json"):
        if "_data_parallel" in json.dumps(r.get("plan_notes", "")):
            pass
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | **FAIL** "
                         f"| | {r.get('error', '')[:60]} | | | | |")
            continue
        m, h = r["memory"], r["hlo_cost"]
        colls = ", ".join(f"{k.split('-')[-1]}:{v / GIB:.1f}"
                          for k, v in sorted(h["collectives"].items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | {r['strategy']} "
            f"| {'; '.join(r['plan_notes'])[:70]} "
            f"| {m['peak_estimate_bytes'] / GIB:.1f} "
            f"| {m['argument_bytes'] / GIB:.2f} "
            f"| {r['compile_seconds']:.0f} "
            f"| {h['collective_count']} / {colls} |")
    return "\n".join(lines)


def roofline_table():
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| MODEL_FLOPS/chip | useful FLOPs | one-line lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        "compute": "more chips or lower-precision matmuls; compute-bound is the goal state",
        "memory": "raise arithmetic intensity: larger per-chip batch, fuse elementwise chains, keep bf16 end-to-end",
        "collective": "cut resharding: larger microbatches amortize FSDP gathers; overlap collectives with compute",
    }
    for r in load("*_1pod.json"):
        if not r.get("ok"):
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} "
            f"| {rf['memory_s']:.4f} | {rf['collective_s']:.4f} "
            f"| **{rf['dominant']}** | {rf['model_flops_per_chip']:.2e} "
            f"| {rf['useful_flops_ratio'] * 100:.0f}% "
            f"| {levers[rf['dominant']]} |")
    return "\n".join(lines)


def summary_stats():
    recs = [r for r in load("*.json")]
    ok = [r for r in recs if r.get("ok")]
    by_dom = defaultdict(int)
    fits = 0
    for r in ok:
        if "roofline" in r:
            by_dom[r["roofline"]["dominant"]] += 1
        if r["memory"]["peak_estimate_bytes"] <= r["memory"]["hbm_budget"]:
            fits += 1
    return (f"combos: {len(recs)} total, {len(ok)} compiled OK, "
            f"{fits} within 16GiB HBM (CPU-lowering estimate); "
            f"dominant terms: {dict(by_dom)}")


if __name__ == "__main__":
    print("## Dry-run table\n")
    print(summary_stats() + "\n")
    print(dryrun_table())
    print("\n## Roofline table (single-pod)\n")
    print(roofline_table())
