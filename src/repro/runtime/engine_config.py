"""EngineConfig: the one configuration surface for the serving stack.

The serving entry points had grown three divergent kwarg vocabularies —
``PlanServer(pool_max_bytes=..., page_size=...)``,
``ServingEngine(max_group_batch=..., join_mid_decode=...)``, and the
``launch/serve.py`` argparse flags that re-spelled both — so adding a knob
meant threading it through every layer by hand (and forgetting one, which
is exactly how ``prefill`` ended up defaulting differently per front
door). This module is the SystemML single-API argument applied to
configuration: one frozen :class:`EngineConfig` that every layer builds
from, with the old per-class kwargs kept as deprecated shims for one
release (:func:`fold_legacy_kwargs` overlays them onto a config and warns
once per call site class + kwarg).

The config also owns topology: ``replicas`` / ``placement`` decide whether
:meth:`EngineConfig.build_client` returns a bare
:class:`~repro.runtime.engine.ServingEngine` or a
:class:`~repro.runtime.router.EngineRouter` over N replicas — both satisfy
the :class:`~repro.runtime.engine.EngineClient` protocol, so callers are
written once against the protocol and ``replicas=1`` is the bare engine.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, fields, replace
from typing import Any, Optional

import jax.numpy as jnp

# sentinel for "caller did not pass this legacy kwarg" — None is a real
# value for several of them (eos_id-style), so absence needs its own mark
_UNSET: Any = object()

# (owner, kwarg) pairs already warned about — deprecation noise once per
# process per call-site vocabulary, not once per constructed object
_WARNED: set = set()


def reset_legacy_kwarg_warnings() -> None:
    """Clear the once-per-(owner, kwarg) deprecation registry. The
    registry is process-global on purpose (one warning per call-site
    vocabulary, not per object), which makes warning-behaviour tests
    order-dependent — a fixture calls this so every test starts from the
    never-warned state."""
    _WARNED.clear()


def fold_legacy_kwargs(config: Optional["EngineConfig"], owner: str,
                       **overrides) -> "EngineConfig":
    """Overlay explicitly-passed legacy kwargs onto ``config`` (or a
    default config), warning once per ``(owner, kwarg)``. Legacy kwargs
    win over the config they shadow — existing call sites keep their exact
    behaviour for the deprecation release."""
    changes = {k: v for k, v in overrides.items() if v is not _UNSET}
    for k in changes:
        tag = (owner, k)
        if tag not in _WARNED:
            _WARNED.add(tag)
            warnings.warn(
                f"{owner}({k}=...) is deprecated; pass "
                f"config=EngineConfig({k}=...) instead",
                DeprecationWarning, stacklevel=3)
    cfg = config if config is not None else EngineConfig()
    return replace(cfg, **changes) if changes else cfg


@dataclass(frozen=True)
class EngineConfig:
    """Every serving knob, in one place, grouped by the layer it drives.

    ``PlanServer`` reads the plan-cache + pool fields, ``ServingEngine``
    the batching fields, ``EngineRouter`` the topology fields; the
    ``launch/serve.py`` argparse maps onto the whole thing via
    :meth:`from_args`. Frozen: a config names a scenario — replicas built
    from the same config are interchangeable, which is what makes router
    failover's token-equality guarantee checkable."""

    # -- model / plan-cache (PlanServer) -----------------------------------
    dtype: str = "float32"            # "float32" | "bfloat16"
    enable_cache: bool = True
    cache_capacity: int = 16
    recompile_margin: float = 0.25
    seed: int = 0
    prefill: bool = False             # sequential front door's prompt pass

    # -- KV-cache pool (PlanServer -> KVCachePool) -------------------------
    pool_arenas: int = 4
    pool_max_arenas: int = 0
    pool_max_bytes: float = 0.0
    page_size: int = 64
    # physical decode-attention operator for paged buckets: "auto" lets the
    # plan compiler choose per bucket from the analytic cost terms (the
    # SystemML move); the rest force one operator on every decode plan
    decode_kernel: str = "auto"       # "auto" | "paged" | "gather" | "ref"
    # buffer donation for decode steps: the jitted tick donates the cache
    # pytree to XLA, so KV slot stacks / recurrent state update in place
    # instead of double-buffering (certified by
    # ``repro.analysis.memory_audit``); --no-donate is the A/B escape hatch
    donate: bool = True

    # -- batching / lifecycle (ServingEngine) ------------------------------
    max_group_batch: int = 8
    slo_ms: float = 0.0
    join_mid_decode: bool = True
    # "hol": strict head-of-line bucket pick; "arrival": the pending bucket
    # with the most coalescable rows forms first (bounded deferral keeps
    # the head-of-line bucket starvation-free)
    bucket_select: str = "hol"

    # -- topology (EngineRouter) -------------------------------------------
    replicas: int = 1
    placement: str = "affinity"       # "affinity" | "load"

    # -- diagnostics -------------------------------------------------------
    # per-tick structural assertions over pool/engine/router state
    # (repro.analysis.sanitize); pure host-side walks, no device sync
    sanitize: bool = False

    def __post_init__(self):
        if self.dtype not in ("float32", "bfloat16"):
            raise ValueError(f"dtype must be float32|bfloat16, "
                             f"got {self.dtype!r}")
        if self.bucket_select not in ("hol", "arrival"):
            raise ValueError(f"bucket_select must be hol|arrival, "
                             f"got {self.bucket_select!r}")
        if self.placement not in ("affinity", "load"):
            raise ValueError(f"placement must be affinity|load, "
                             f"got {self.placement!r}")
        if self.replicas < 1:
            raise ValueError("replicas must be >= 1")
        if self.cache_capacity < 1:
            raise ValueError("cache_capacity must be >= 1")
        if self.recompile_margin < 0:
            raise ValueError("recompile_margin must be >= 0")
        if self.page_size < 0:
            raise ValueError("page_size must be >= 0 (0 = row-granular)")
        if self.decode_kernel not in ("auto", "paged", "gather", "ref"):
            raise ValueError(f"decode_kernel must be auto|paged|gather|ref, "
                             f"got {self.decode_kernel!r}")
        if self.pool_arenas < 1:
            raise ValueError("pool_arenas must be >= 1")
        if self.pool_max_arenas < 0 or self.pool_max_bytes < 0:
            raise ValueError("pool caps must be >= 0 (0 = unbounded)")
        if self.max_group_batch < 1:
            raise ValueError("max_group_batch must be >= 1")
        if self.slo_ms < 0:
            raise ValueError("slo_ms must be >= 0")

    # ------------------------------------------------------------------
    def jnp_dtype(self):
        return jnp.float32 if self.dtype == "float32" else jnp.bfloat16

    @classmethod
    def from_args(cls, args) -> "EngineConfig":
        """Build from an argparse namespace (``launch/serve.py`` flag
        names). Missing attributes keep their config defaults, so partial
        namespaces (tests, embedding drivers) work too."""
        pick = {}
        for f in fields(cls):
            if hasattr(args, f.name):
                pick[f.name] = getattr(args, f.name)
        # flags whose argparse spelling differs from the field name
        if hasattr(args, "no_cache"):
            pick["enable_cache"] = not args.no_cache
        if hasattr(args, "no_donate"):
            pick["donate"] = not args.no_donate
        return cls(**{k: v for k, v in pick.items()})

    # -- builders (function-local imports break the layering cycle:
    # serve_loop/engine/router all import *this* module) -------------------
    def build_server(self, model_cfg, mesh_cfg=None, **kw):
        from repro.runtime.serve_loop import PlanServer  # lint: allow-local-import
        return PlanServer(model_cfg, mesh_cfg, config=self, **kw)

    def build_engine(self, server, *, clock=None, **kw):
        from repro.runtime.engine import ServingEngine  # lint: allow-local-import
        return ServingEngine(server, config=self, clock=clock, **kw)

    def build_client(self, model_cfg, mesh_cfg=None, *, servers=None):
        """The topology decision: one engine for ``replicas == 1``, an
        :class:`EngineRouter` above that — same ``EngineClient`` surface
        either way. ``servers``: pre-built (warm) PlanServers to wrap
        instead of constructing fresh ones (must match ``replicas``)."""
        if servers is None:
            servers = [self.build_server(model_cfg, mesh_cfg)
                       for _ in range(max(1, self.replicas))]
        if self.replicas <= 1:
            return self.build_engine(servers[0])
        from repro.runtime.router import EngineRouter  # lint: allow-local-import
        return EngineRouter(servers, config=self)
