"""Continuous-batching request scheduler on top of :class:`PlanServer`.

The plan cache (PR 1) made steady-state serving cheap *per request*; this
module makes it cheap *per token* by filling each shape bucket's batch
dimension with real requests instead of padding every request up to its
bucket alone. The scheduler is the serving-side analogue of SystemML's
parfor batching argument (and BigDL/MMLSpark's coarse-grained batched
scoring): one compiled plan, many concurrent requests.

Mechanics:

- :class:`RequestQueue` admits :class:`ServeRequest`\\ s asynchronously
  (each stamped with an arrival time) and coalesces compatible pending
  requests — same power-of-two context bucket — into a shared *group*
  whose batch rows are the concatenation of the member requests.
- :class:`ContinuousBatchingScheduler` interleaves prefill and decode:
  each scheduler tick admits due arrivals, prefills at most one newly
  coalesced group (drawing the prefill plan from the same
  :class:`~repro.core.plan_cache.PlanCache` as decode, via
  ``PlanServer.prefill_entry``), then advances every active group by one
  decode step. New arrivals therefore start prefilling between the decode
  steps of in-flight groups rather than behind them.
- Per-request queueing vs. execution latency and SLO attainment are
  tracked in :class:`~repro.runtime.metrics.SchedulerMetrics`.

Arrivals are simulated against a virtual clock that never runs slower
than the real one: execution timing is measured, idle gaps between
arrivals are skipped instead of slept through.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import InputShape
from repro.core.plan_cache import BucketPolicy, CacheEntry, bucket_pow2
from repro.core.strategies import RuntimeStats
from repro.runtime.metrics import SchedulerMetrics
from repro.runtime.serve_loop import PlanServer, ServeRequest


@dataclass
class QueuedRequest:
    """One admitted request plus its lifecycle timestamps (virtual clock)."""

    rid: int
    req: ServeRequest
    arrival_s: float
    start_s: float = -1.0        # group formed: prefill began
    finish_s: float = -1.0       # last requested token decoded
    rows: Tuple[int, int] = (0, 0)  # this request's rows in its group batch

    @property
    def queue_s(self) -> float:
        return max(0.0, self.start_s - self.arrival_s)

    @property
    def exec_s(self) -> float:
        return max(0.0, self.finish_s - self.start_s)

    @property
    def total_s(self) -> float:
        return max(0.0, self.finish_s - self.arrival_s)


class RequestQueue:
    """FIFO admission with bucket-aware coalescing.

    ``next_group`` is deliberately head-of-line fair: the *oldest* pending
    request picks the context bucket, and only same-bucket requests may
    join its group (in arrival order, until the group's batch capacity is
    full). A popular bucket can therefore never starve an unpopular one —
    it just rides along whenever its own head reaches the front.
    """

    def __init__(self, policy: BucketPolicy = BucketPolicy(),
                 max_group_batch: int = 8):
        if max_group_batch < 1:
            raise ValueError("max_group_batch must be >= 1")
        self.policy = policy
        self.max_group_batch = max_group_batch
        self._pending: List[QueuedRequest] = []
        self._next_rid = 0

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def pending(self) -> Tuple[QueuedRequest, ...]:
        return tuple(self._pending)

    def seq_bucket(self, req: ServeRequest) -> int:
        return bucket_pow2(req.context, self.policy.min_seq)

    def admit(self, req: ServeRequest, arrival_s: float = 0.0) -> QueuedRequest:
        qr = QueuedRequest(rid=self._next_rid, req=req, arrival_s=arrival_s)
        self._next_rid += 1
        self._pending.append(qr)
        return qr

    def next_group(self) -> List[QueuedRequest]:
        """Pop the next coalesced group (empty list if nothing pending).

        The head-of-line request always joins (even if its batch alone
        exceeds ``max_group_batch`` — it must be served eventually); later
        same-bucket requests fill the remaining batch slots in FIFO order,
        skipping any too big for the space left.
        """
        if not self._pending:
            return []
        head = self._pending[0]
        sb = self.seq_bucket(head.req)
        group: List[QueuedRequest] = [head]
        used = head.req.batch
        for qr in self._pending[1:]:
            if self.seq_bucket(qr.req) != sb:
                continue
            if used + qr.req.batch > self.max_group_batch:
                continue
            group.append(qr)
            used += qr.req.batch
        for qr in group:
            self._pending.remove(qr)
        return group


class _Clock:
    """Virtual clock: real elapsed time plus skipped idle gaps."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self._skew = 0.0

    def now(self) -> float:
        return time.perf_counter() - self._t0 + self._skew

    def advance_to(self, t: float) -> None:
        self._skew += max(0.0, t - self.now())


@dataclass
class _Group:
    """One coalesced batch in flight: shared KV cache + decode plan."""

    members: List[QueuedRequest]
    entry: CacheEntry                 # decode plan for the group's bucket
    context: int                      # max member context (same bucket)
    kv: Any = None
    toks: Any = None
    pos: int = 0
    steps_done: int = 0
    max_steps: int = 0
    decoded: List[Any] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.steps_done >= self.max_steps

    @property
    def total_batch(self) -> int:
        return sum(m.req.batch for m in self.members)


class ContinuousBatchingScheduler:
    """Drives a :class:`PlanServer` with coalesced groups instead of
    one-request-at-a-time ``handle`` calls.

    Both plan families come from the server's single :class:`PlanCache`:
    ``kind="prefill"`` entries for the batched prompt pass, ``kind="decode"``
    entries for the shared-cache generation steps.
    """

    def __init__(
        self,
        server: PlanServer,
        *,
        max_group_batch: int = 8,
        slo_ms: float = 0.0,
        queue: Optional[RequestQueue] = None,
    ):
        self.server = server
        self.queue = queue or RequestQueue(server.policy, max_group_batch)
        self.metrics = SchedulerMetrics(slo_s=slo_ms / 1e3)
        self.active: List[_Group] = []
        self.results: List[Dict[str, Any]] = []

    # -- group lifecycle ---------------------------------------------------
    def _start_group(self, members: List[QueuedRequest], now: float) -> _Group:
        srv = self.server
        total_batch = sum(m.req.batch for m in members)
        context = max(m.req.context for m in members)
        row = 0
        for m in members:
            m.start_s = now
            m.rows = (row, row + m.req.batch)
            row += m.req.batch

        # prefill: batched prompt pass at the group's bucket, plan cached
        first = srv.prefill_first_token(total_batch, context)

        # decode: shared KV cache at the same bucket family
        entry = srv.decode_entry(total_batch, context)
        b, s = entry.key.batch_bucket, entry.key.seq_bucket
        group = _Group(
            members=members,
            entry=entry,
            context=context,
            kv=srv.model.init_cache(b, s),
            # prefill and decode share the bucket policy, so the prefill
            # logits already carry one first token per bucket row
            toks=first,
            max_steps=max(m.req.new_tokens for m in members),
        )
        self.metrics.observe_group([m.req.batch for m in members], b)
        return group

    def _decode_tick(self, group: _Group, clock: _Clock) -> None:
        srv = self.server
        logits, group.kv = group.entry.step_fn(
            srv.params, group.kv, group.toks, jnp.int32(group.pos))
        group.toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        jax.block_until_ready(group.toks)
        group.decoded.append(group.toks)
        group.pos += 1
        group.steps_done += 1
        now = clock.now()
        for m in group.members:
            if m.finish_s < 0 and group.steps_done >= m.req.new_tokens:
                m.finish_s = now
                self._complete(m, group)

    def _complete(self, m: QueuedRequest, group: _Group) -> None:
        self.metrics.observe_request(m.queue_s, m.exec_s)
        lo, hi = m.rows
        toks = jnp.concatenate(group.decoded[: m.req.new_tokens], axis=1)
        self.results.append({
            "rid": m.rid,
            "batch": m.req.batch,
            "context": m.req.context,
            "bucket": (group.entry.key.batch_bucket,
                       group.entry.key.seq_bucket),
            "group_size": len(group.members),
            "tokens": toks[lo:hi],
            "queue_s": m.queue_s,
            "exec_s": m.exec_s,
            "total_s": m.total_s,
        })

    def _retire_group(self, group: _Group) -> None:
        """Observed runtime statistics feed dynamic recompilation exactly
        as in the sequential path."""
        srv = self.server
        shape = InputShape(
            f"group_{group.total_batch}x{group.context}",
            group.context, group.total_batch, "decode")
        watermark = srv.observed_watermark(group.entry, group.kv, group.toks)
        srv.observe(group.entry.key,
                    RuntimeStats(shape=shape, watermark_bytes=watermark))

    # -- main loop ---------------------------------------------------------
    def run(self, arrivals: Iterable[Tuple[float, ServeRequest]]
            ) -> List[Dict[str, Any]]:
        """Serve a stream of ``(arrival_s, request)`` pairs to completion.

        Returns one record per request (completion order). Tick structure:
        admit due arrivals → coalesce + prefill at most one new group →
        one decode step for every active group. Prefill work for new
        arrivals therefore interleaves with decode of in-flight groups.
        """
        todo = sorted(arrivals, key=lambda a: a[0])
        clock = _Clock()
        idx = 0
        while idx < len(todo) or len(self.queue) or self.active:
            now = clock.now()
            while idx < len(todo) and todo[idx][0] <= now:
                self.queue.admit(todo[idx][1], todo[idx][0])
                self.metrics.admitted += 1
                idx += 1
            if not self.active and not len(self.queue):
                # idle: skip ahead to the next arrival instead of sleeping
                clock.advance_to(todo[idx][0])
                continue
            if len(self.queue):
                members = self.queue.next_group()
                if members:
                    self.active.append(self._start_group(members, clock.now()))
            for group in list(self.active):
                self._decode_tick(group, clock)
                if group.done:
                    self._retire_group(group)
                    self.active.remove(group)
        return self.results

    def summary(self) -> str:
        from repro.runtime.metrics import scheduler_summary
        # the scheduler's own total latency, not server.latency — handle()
        # is never called on this path, so the server accumulator is empty
        return scheduler_summary(self.metrics, self.server.metrics,
                                 self.metrics.total_latency)


def simulate_arrivals(
    requests: Sequence[ServeRequest],
    rate_per_s: float = 0.0,
    seed: int = 0,
) -> List[Tuple[float, ServeRequest]]:
    """Stamp requests with Poisson-process arrival times at ``rate_per_s``
    (exponential inter-arrival gaps, seeded). ``rate_per_s <= 0`` means a
    closed burst: everything arrives at t=0 (maximal coalescing pressure).
    """
    import random

    if rate_per_s <= 0:
        return [(0.0, r) for r in requests]
    rng = random.Random(seed)
    t = 0.0
    out = []
    for r in requests:
        t += rng.expovariate(rate_per_s)
        out.append((t, r))
    return out
