"""Plan auditor: static jaxpr analysis of every compiled serving step.

SystemML catches plan-level hazards by propagating statistics over the
program *before* execution; this pass does the same for the serving
stack's compiled steps. For every (arch, dtype, kind, bucket) cell in the
audit matrix it traces the exact step the scheduler would jit
(:func:`make_decode_step` / :func:`make_prefill` over the
:class:`PlanCompiler` plan for that cell) to a closed jaxpr — abstract
tracing only, no XLA compile, no device arrays — and walks it for:

- **dtype-promotion leaks** (``dtype-leak``): in a reduced-precision plan,
  (a) float32/float64 *array constants* baked into the step (a clean step
  closes over nothing — every real array is an input), (b) lax-level
  promotion edges (an eqn producing f32 from a bf16 input without an
  explicit ``convert_element_type`` — jnp-level code can't produce these,
  raw-lax/kernel code can), and (c) f32 leaking into the step's *outputs*:
  logits off the compute dtype or a cache leaf coming back wider than it
  went in. Deliberate upcasts (softmax/state accumulation behind
  ``.astype`` fences) pass all three; this is the exact class behind the
  historical fp32 corrective recompiles. A scalar f32 literal that is
  astype'd back before any output is the one shape none of the three can
  see — jax lowers implicit promotion to the same ``convert_element_type``
  as a deliberate fence.
- **host sync / retrace hazards** (``host-sync``, ``dynamic-shape``):
  callback/infeed/outfeed primitives inside the jitted tick, and any
  abstract value with a non-static dimension.
- **memory-statistics validation** (``memory-under-estimate``,
  ``memory-uncovered``): a liveness scan over the jaxpr yields a
  *floor* (inputs + outputs that must coexist — no allocator can do
  better) and a *ceiling* (no-reuse peak, plus the rest of the provisioned
  pool the step serves next to, plus the same workspace fraction
  ``estimate_memory`` budgets). The plan's compile-time estimate must sit
  inside ``[floor, ceiling]``: below the floor it provably under-estimates
  (a future corrective recompile at serve time), above the ceiling the
  statistic exceeds even the reuse-free worst case (plans would refuse
  capacity they have).
- **decode-kernel selection** (``kernel-choice``): every decode cell is
  audited under both forced physical operators (``paged`` and ``gather``)
  and the record carries the kernel the plan actually selected, so the
  matrix asserts the choice per cell; a forced compiler whose plan records
  a different operator is flagged, and — the silent perf cliff — a
  long-context paged decode plan (seq beyond ``LONG_CONTEXT_THRESHOLD``)
  that is *not* running the fused paged kernel pays the gather
  materialization's ``(2 + 2 q_per_kv)x`` cache traffic on every step
  without any numerical signal, so :func:`check_kernel_choice` flags it
  statically (no tracing needed).

Run ``python -m repro.analysis.plan_audit --smoke``: audits the smoke
matrix, runs the injected-violation self-test (a planted fp32 constant
and a planted host callback must be flagged), writes
``ANALYSIS_report.json``, and exits non-zero on any clean-tree finding or
self-test miss.
"""

from __future__ import annotations

import argparse
import sys
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.core import Literal

from repro.analysis import Finding
from repro.analysis.matrix import (PAGE_SIZE, POOL_ARENAS,  # noqa: F401
                                   REPORT_PATH, SMOKE_ARCHS, SMOKE_BUCKETS,
                                   SMOKE_DTYPES, matrix_meta, merge_report,
                                   smoke_cells)
from repro.config import InputShape, MeshConfig
from repro.configs import get_config
from repro.core.planner import LONG_CONTEXT_THRESHOLD, PlanCompiler
from repro.models.model import build_model
from repro.runtime.serve_loop import make_decode_step, make_prefill

# The smoke-matrix constants live in repro.analysis.matrix (shared by all
# three statistics passes) and are re-exported here for compatibility.
WORKSPACE_FRACTION = 0.08  # mirrors core/memory.py's workspace class

LOW_PRECISION = (jnp.bfloat16, jnp.float16)
WIDE = (np.dtype("float32"), np.dtype("float64"))
HOST_SYNC_MARKERS = ("callback", "infeed", "outfeed", "host_")


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def sub_jaxprs(eqn) -> List[Any]:
    """Child jaxprs of a call-like eqn (scan/while/cond/pjit/custom_*)."""
    subs = []
    for v in eqn.params.values():
        if getattr(v, "jaxpr", None) is not None:
            subs.append(v.jaxpr)
        elif isinstance(v, (list, tuple)):
            subs.extend(w.jaxpr for w in v
                        if getattr(w, "jaxpr", None) is not None)
    return subs


def iter_eqns(jaxpr) -> Iterable[Any]:
    """Every eqn in ``jaxpr`` and, recursively, in nested bodies."""
    jx = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jx.eqns:
        yield eqn
        for sub in sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def aval_bytes(av) -> int:
    dt = getattr(av, "dtype", None)
    if dt is None:        # tokens and friends: abstract non-array values
        return 0
    n = 1
    for d in av.shape:
        n *= int(d)
    return n * np.dtype(dt).itemsize


# ---------------------------------------------------------------------------
# pass 1: dtype-promotion leaks
# ---------------------------------------------------------------------------


def audit_dtype(closed, out_tree, in_cache, compute_dtype,
                where: str) -> List[Finding]:
    """Flag fp32 reachable in a reduced-precision plan (see module doc
    for the three detectors and the one shape they cannot see)."""
    if np.dtype(compute_dtype) not in (np.dtype(d) for d in LOW_PRECISION):
        return []
    out: List[Finding] = []
    for c in closed.consts:
        dt = np.dtype(getattr(c, "dtype", np.float64))
        if dt in WIDE:
            shape = getattr(c, "shape", ())
            out.append(Finding(
                rule="dtype-leak", where=where,
                detail=f"{dt.name}{list(shape)} constant baked into a "
                       f"{np.dtype(compute_dtype).name} step"))
    for eqn in iter_eqns(closed.jaxpr):
        if eqn.primitive.name == "convert_element_type" or sub_jaxprs(eqn):
            continue
        outs_wide = any(
            np.dtype(getattr(v.aval, "dtype", np.int32)) in WIDE
            for v in eqn.outvars)
        ins_low = any(
            getattr(v.aval, "dtype", None) == np.dtype(compute_dtype)
            for v in eqn.invars if hasattr(v, "aval"))
        if outs_wide and ins_low:
            out.append(Finding(
                rule="dtype-leak", where=where,
                detail=f"primitive {eqn.primitive.name} promotes "
                       f"{np.dtype(compute_dtype).name} to f32 without an "
                       f"explicit convert fence"))
    logits, cache_out = out_tree
    if np.dtype(logits.dtype) != np.dtype(compute_dtype):
        out.append(Finding(
            rule="dtype-leak", where=where,
            detail=f"logits come out {np.dtype(logits.dtype).name} in a "
                   f"{np.dtype(compute_dtype).name} plan"))
    if cache_out is not None:
        for k, sds in in_cache.items():
            got = np.dtype(cache_out[k].dtype)
            want = np.dtype(sds.dtype)
            if got != want:
                out.append(Finding(
                    rule="dtype-leak", where=where,
                    detail=f"cache leaf {k!r} widens {want.name} -> "
                           f"{got.name} across the step"))
    return out


# ---------------------------------------------------------------------------
# pass 2: host sync + retrace hazards
# ---------------------------------------------------------------------------


def audit_host_sync(closed, where: str) -> List[Finding]:
    out: List[Finding] = []
    for eqn in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        if any(m in name for m in HOST_SYNC_MARKERS):
            out.append(Finding(
                rule="host-sync", where=where,
                detail=f"primitive {name} synchronizes with the host "
                       f"inside the jitted tick"))
    return out


def audit_static_shapes(closed, where: str) -> List[Finding]:
    out: List[Finding] = []
    for eqn in iter_eqns(closed.jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            av = getattr(v, "aval", None)
            if av is None or not hasattr(av, "shape"):
                continue
            if any(not isinstance(d, (int, np.integer)) for d in av.shape):
                out.append(Finding(
                    rule="dynamic-shape", where=where,
                    detail=f"non-static dimension in {av} at "
                           f"{eqn.primitive.name} (retrace hazard)"))
    return out


# ---------------------------------------------------------------------------
# pass 3: memory-statistics validation
# ---------------------------------------------------------------------------


def jaxpr_peak_bytes(jaxpr) -> int:
    """No-reuse peak for one jaxpr body: invars + consts resident
    throughout, plus a liveness scan over the intermediates (a value is
    held from its producing eqn to its last use). Call-like eqns
    contribute their body's own recursive peak while they run."""
    jx = getattr(jaxpr, "jaxpr", jaxpr)
    resident = sum(aval_bytes(v.aval) for v in jx.invars)
    resident += sum(aval_bytes(v.aval) for v in jx.constvars)
    last_use: Dict[Any, int] = {}
    for i, e in enumerate(jx.eqns):
        for v in e.invars:
            if not isinstance(v, Literal):
                last_use[v] = i
    for v in jx.outvars:
        if not isinstance(v, Literal):
            last_use[v] = len(jx.eqns)
    live: Dict[Any, int] = {}
    peak = 0
    for i, e in enumerate(jx.eqns):
        body = max((jaxpr_peak_bytes(s) for s in sub_jaxprs(e)), default=0)
        out_b = sum(aval_bytes(v.aval) for v in e.outvars)
        peak = max(peak, sum(live.values()) + out_b + body)
        for v in e.outvars:
            if last_use.get(v, i) > i:
                live[v] = aval_bytes(v.aval)
        live = {v: b for v, b in live.items() if last_use.get(v, -1) > i}
    return resident + peak


def resident_floor_bytes(closed, donated_bytes: int = 0) -> int:
    """Certified lower bound on the step's peak: its inputs and outputs
    must coexist, whatever XLA does in between — minus ``donated_bytes``,
    the input bytes the plan donates (a donated input aliases its output
    buffer, so the pair occupies one allocation, not two). The flag comes
    from the plan, not an assumption: ``repro.analysis.memory_audit``
    certifies that recorded donation turns into real aliasing in the
    lowered executable."""
    jx = closed.jaxpr
    total = sum(aval_bytes(v.aval) for v in jx.invars)
    total += sum(aval_bytes(v.aval) for v in jx.outvars
                 if not isinstance(v, Literal))
    return max(0, total - int(donated_bytes))


def audit_memory(closed, estimate_total: float, pool_slack_bytes: float,
                 where: str, donated_bytes: int = 0
                 ) -> Tuple[Dict[str, Any], List[Finding]]:
    """Sandwich the plan's compile-time estimate between the certified
    floor and the reuse-free ceiling (plus pool slack + workspace). Both
    bounds condition on the plan's donation flags (``donated_bytes`` > 0
    for a ``donate_cache`` decode plan): the jaxpr liveness scan counts
    the cache's output copy as a fresh allocation, so for a donating step
    the double-buffer term is subtracted from the ceiling — a donated
    estimate must fit under the *tighter* bound, and an estimate that
    still carries the double-buffer term gets flagged instead of
    silently absorbed."""
    floor = resident_floor_bytes(closed, donated_bytes)
    ceiling = (jaxpr_peak_bytes(closed.jaxpr) - int(donated_bytes)
               + pool_slack_bytes)
    ceiling = int(ceiling * (1.0 + WORKSPACE_FRACTION))
    record = {
        "floor_bytes": int(floor),
        "estimate_bytes": float(estimate_total),
        "ceiling_bytes": int(ceiling),
        "donated_bytes": int(donated_bytes),
        "covered": bool(ceiling >= estimate_total),
    }
    findings: List[Finding] = []
    if estimate_total < floor:
        findings.append(Finding(
            rule="memory-under-estimate", where=where,
            detail=f"estimate {estimate_total:.0f}B below the certified "
                   f"floor {floor}B — the plan will breach its watermark "
                   f"and burn a corrective recompile at serve time",
            data=record))
    elif not record["covered"]:
        findings.append(Finding(
            rule="memory-uncovered", where=where,
            detail=f"estimate {estimate_total:.0f}B exceeds the reuse-free "
                   f"ceiling {ceiling}B — the statistic over-provisions "
                   f"beyond any possible execution",
            data=record))
    return record, findings


# ---------------------------------------------------------------------------
# pass 4: decode-kernel selection
# ---------------------------------------------------------------------------


def check_kernel_choice(model, config, shape, page: int,
                        where: str, forced: str = "auto") -> List[Finding]:
    """Static checks over the plan's recorded decode kernel — pure plan
    metadata, no tracing. ``model`` is the :class:`ModelConfig`, ``config``
    the chosen :class:`PlanConfig`, ``forced`` the compiler's kernel knob.

    Two rules: a forced compiler must record what it was forced to (except
    attention-free families, where ``none`` is the only honest answer);
    and a long-context paged decode plan must be running the fused paged
    kernel — at those buckets the gather path materializes the committed
    cache plus its ``q_per_kv``-repeated expansion every step, the exact
    traffic cliff the operator-selection tentpole exists to avoid."""
    out: List[Finding] = []
    if shape.kind != "decode":
        return out
    attention_free = model.layer_pattern().count("a") == 0
    if attention_free:
        if config.decode_kernel != "none":
            out.append(Finding(
                rule="kernel-choice", where=where,
                detail=f"attention-free family records decode kernel "
                       f"{config.decode_kernel!r} (expected 'none')"))
        return out
    if forced != "auto" and config.decode_kernel != forced:
        out.append(Finding(
            rule="kernel-choice", where=where,
            detail=f"compiler forced decode kernel {forced!r} but the "
                   f"plan records {config.decode_kernel!r}"))
    if (page > 0 and shape.seq_len > LONG_CONTEXT_THRESHOLD
            and config.decode_kernel != "paged"):
        out.append(Finding(
            rule="kernel-choice", where=where,
            detail=f"long-context decode plan (seq {shape.seq_len}) runs "
                   f"{config.decode_kernel!r}, not the fused paged kernel "
                   f"— every step pays the gather materialization's "
                   f"{2 + 2 * model.q_per_kv}x cache traffic"))
    return out


# ---------------------------------------------------------------------------
# cell tracing
# ---------------------------------------------------------------------------


def trace_cell(model, plan, mesh_cfg, kind: str, batch: int, seq: int,
               page: int = PAGE_SIZE, wrap=None):
    """Closed jaxpr + abstract output tree + cache specs for one cell —
    ShapeDtypeStruct tracing end to end (no params materialized).
    ``wrap(step)`` lets the self-test plant violations in the step."""
    params = model.param_specs()
    if kind == "decode":
        ent, n_pages, sc = model.paged_cache_entries(batch, seq, page)
        cache = {k: jax.ShapeDtypeStruct(s, d) for k, (s, a, d) in ent.items()}
        step = make_decode_step(model, plan.config, mesh_cfg, page=page,
                                seq_len=seq)
        if wrap is not None:
            step = wrap(step)
        args = [params, cache,
                jax.ShapeDtypeStruct((batch, 1), jnp.int32),
                jax.ShapeDtypeStruct((batch,), jnp.int32)]
        if n_pages:
            args.append(jax.ShapeDtypeStruct((batch, -(-sc // page)),
                                             jnp.int32))
        closed = jax.make_jaxpr(step)(*args)
        out_tree = jax.eval_shape(step, *args)
        return closed, out_tree, cache
    step = make_prefill(model, plan.config, mesh_cfg)
    if wrap is not None:
        step = wrap(step)
    batch_in = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32)}
    closed = jax.make_jaxpr(step)(params, batch_in)
    out_tree = jax.eval_shape(step, params, batch_in)
    return closed, out_tree, None


def audit_cell(arch: str, dtype: str, kind: str, batch: int, seq: int, *,
               page: int = PAGE_SIZE, pool_arenas: int = POOL_ARENAS,
               decode_kernel: str = "auto",
               wrap=None) -> Tuple[Dict[str, Any], List[Finding]]:
    """Compile the plan and audit the traced step for one matrix cell.
    ``decode_kernel`` is the compiler knob: the matrix runs decode cells
    under both forced operators so each physical read path is traced."""
    where = f"{arch}/{dtype}/{kind}/b{batch}s{seq}"
    if kind == "decode" and decode_kernel != "auto":
        where += f"/{decode_kernel}"
    cfg = get_config(arch)
    mesh_cfg = MeshConfig(shape=(1,), axis_names=("data",))
    model = build_model(cfg, dtype=dtype)
    compiler = PlanCompiler(cache_page_size=page,
                            cache_pool_arenas=pool_arenas,
                            decode_kernel=decode_kernel)
    shape = InputShape(f"req_{batch}x{seq}", seq, batch, kind)
    plan = compiler.compile(cfg, shape, mesh_cfg, dtype=dtype)
    closed, out_tree, cache = trace_cell(model, plan, mesh_cfg, kind,
                                         batch, seq, page=page, wrap=wrap)
    findings: List[Finding] = []
    if kind == "decode":
        findings += audit_dtype(closed, out_tree, cache, model.dtype, where)
    findings += audit_host_sync(closed, where)
    findings += audit_static_shapes(closed, where)
    findings += check_kernel_choice(cfg, plan.config, shape, page, where,
                                    forced=decode_kernel)
    # the step serves next to the rest of the provisioned pool: slack is
    # (pool_arenas - 1) decode arenas of this bucket
    ent = model.cache_entries(batch, seq)
    arena_bytes = sum(int(np.prod(s)) * np.dtype(d).itemsize
                      for s, a, d in ent.values())
    # a donate_cache plan aliases the cache input onto its output: the
    # sandwich bounds drop that double-buffer term for exactly the bytes
    # the plan records as donated
    donated_bytes = 0
    if kind == "decode" and plan.config.donate_cache and cache is not None:
        donated_bytes = sum(int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
                            for s in cache.values())
    mem, mem_findings = audit_memory(
        closed, plan.memory.total if plan.memory else 0.0,
        (pool_arenas - 1) * arena_bytes, where, donated_bytes=donated_bytes)
    findings += mem_findings
    record = {
        "arch": arch, "dtype": dtype, "kind": kind,
        "batch": batch, "seq": seq,
        "donate_cache": bool(plan.config.donate_cache
                             if kind == "decode" else False),
        # what the plan actually chose (vs the compiler knob): the matrix
        # asserts the selected physical operator per cell
        "decode_kernel": plan.config.decode_kernel,
        "forced_kernel": decode_kernel,
        "eqns": sum(1 for _ in iter_eqns(closed.jaxpr)),
        "memory": mem,
        "findings": len(findings),
    }
    return record, findings


def run_audit(archs: Sequence[str] = SMOKE_ARCHS,
              dtypes: Sequence[str] = SMOKE_DTYPES,
              buckets: Sequence[Tuple[int, int]] = SMOKE_BUCKETS,
              kinds: Sequence[str] = ("decode", "prefill"),
              page: int = PAGE_SIZE,
              pool_arenas: int = POOL_ARENAS,
              log=None) -> Tuple[List[Dict[str, Any]], List[Finding]]:
    cells: List[Dict[str, Any]] = []
    findings: List[Finding] = []
    for cell in smoke_cells(archs=archs, dtypes=dtypes, buckets=buckets,
                            kinds=kinds):
        rec, found = audit_cell(cell.arch, cell.dtype, cell.kind, cell.batch,
                                cell.seq, page=page,
                                pool_arenas=pool_arenas,
                                decode_kernel=cell.forced_kernel)
        cells.append(rec)
        findings.extend(found)
        if log:
            log(f"  {cell.where}: {rec['eqns']} eqns, kernel="
                f"{rec['decode_kernel']}, "
                f"{rec['findings']} finding(s)")
    return cells, findings


# ---------------------------------------------------------------------------
# self-test: planted violations the auditor must flag
# ---------------------------------------------------------------------------


def _wrap_fp32_const(step):
    """Plant the historical bug: an fp32 array constant baked into a bf16
    decode step (converted back afterwards, so only the constant and the
    transient promotion betray it)."""
    bias = np.linspace(0.0, 0.1, 8, dtype=np.float32)

    def wrapped(params, cache, tokens, pos, tables=None):
        args = (params, cache, tokens, pos) + (
            (tables,) if tables is not None else ())
        logits, cache_out = step(*args)
        leaked = logits + jnp.asarray(bias).sum()
        return leaked.astype(logits.dtype), cache_out

    return wrapped


def _wrap_host_callback(step):
    """Plant a host callback inside the jitted tick."""

    def wrapped(params, cache, tokens, pos, tables=None):
        args = (params, cache, tokens, pos) + (
            (tables,) if tables is not None else ())
        logits, cache_out = step(*args)
        jax.debug.callback(lambda x: None, logits)
        return logits, cache_out

    return wrapped


def selftest(arch: str = "yi-6b-smoke") -> Dict[str, Any]:
    """Verify the detectors on planted violations (and a clean control)
    in a bf16 decode step. Returns per-probe pass/fail."""
    _, clean = audit_cell(arch, "bfloat16", "decode", 2, 64)
    _, fp32 = audit_cell(arch, "bfloat16", "decode", 2, 64,
                         wrap=_wrap_fp32_const)
    _, cb = audit_cell(arch, "bfloat16", "decode", 2, 64,
                       wrap=_wrap_host_callback)

    # planted kernel-choice violation: a long-context plan whose paged
    # kernel was silently dropped must flag (and the honest plan must not)
    cfg = get_config("yi-6b")
    mesh_cfg = MeshConfig(shape=(1,), axis_names=("data",))
    shape = InputShape("probe", LONG_CONTEXT_THRESHOLD + 1, 8, "decode")
    plan = PlanCompiler(cache_page_size=PAGE_SIZE,
                        cache_pool_arenas=POOL_ARENAS).compile(
        cfg, shape, mesh_cfg, dtype="bfloat16")
    doctored = plan.config.replace(decode_kernel="gather")
    flagged = check_kernel_choice(cfg, doctored, shape, PAGE_SIZE,
                                  "selftest/long-context")
    honest = check_kernel_choice(cfg, plan.config, shape, PAGE_SIZE,
                                 "selftest/long-context")

    # planted sandwich violation: an estimate that still carries the
    # double-buffer term must overflow the donated (tighter) ceiling and
    # get flagged, while the same figure fits the un-donated ceiling —
    # that asymmetry is what "the bounds condition on donation" means
    mesh_cfg2 = MeshConfig(shape=(1,), axis_names=("data",))
    model = build_model(get_config(arch), dtype="bfloat16")
    probe_plan = PlanCompiler(cache_page_size=PAGE_SIZE,
                              cache_pool_arenas=POOL_ARENAS,
                              decode_kernel="paged").compile(
        get_config(arch), InputShape("probe", 64, 2, "decode"),
        mesh_cfg2, dtype="bfloat16")
    closed, _, cache = trace_cell(model, probe_plan, mesh_cfg2,
                                  "decode", 2, 64)
    donated = sum(int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
                  for s in cache.values())
    stale_estimate = (jaxpr_peak_bytes(closed.jaxpr)
                      * (1.0 + WORKSPACE_FRACTION)) - donated // 2
    _, over = audit_memory(closed, stale_estimate, 0.0,
                           "selftest/donated-ceiling",
                           donated_bytes=donated)
    _, under = audit_memory(closed, stale_estimate, 0.0,
                            "selftest/donated-ceiling")
    return {
        "clean_control": not clean,
        "fp32_const_flagged": any(f.rule == "dtype-leak" for f in fp32),
        "host_callback_flagged": any(f.rule == "host-sync" for f in cb),
        "paged_kernel_absent_flagged": (
            any(f.rule == "kernel-choice" for f in flagged) and not honest),
        "donated_ceiling_enforced": (
            any(f.rule == "memory-uncovered" for f in over)
            and not any(f.rule == "memory-uncovered" for f in under)),
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="static jaxpr audit of every compiled serving step")
    ap.add_argument("--smoke", action="store_true",
                    help="audit the CI smoke matrix (archs x dtypes x "
                         "buckets) plus the injected-violation self-test")
    ap.add_argument("--archs", nargs="*", default=None,
                    help="override the arch list")
    ap.add_argument("--report", default=REPORT_PATH,
                    help=f"JSON report path (default {REPORT_PATH})")
    ap.add_argument("--no-selftest", action="store_true",
                    help="skip the planted-violation self-test")
    args = ap.parse_args(argv)

    archs = tuple(args.archs) if args.archs else SMOKE_ARCHS
    print(f"plan_audit: {len(archs)} arch(s) x {len(SMOKE_DTYPES)} dtypes "
          f"x {len(SMOKE_BUCKETS)} buckets")
    cells, findings = run_audit(archs=archs, log=print)

    st: Dict[str, Any] = {}
    if not args.no_selftest:
        st = selftest()
        for probe, ok in st.items():
            print(f"  selftest {probe}: {'ok' if ok else 'MISSED'}")

    # the report file is shared with the memory and cost auditors (their
    # sections live under "memory" / "cost"): update ours in place
    merge_report(args.report, {
        "matrix": matrix_meta(archs=archs),
        "cells": cells,
        "findings": [{"rule": f.rule, "where": f.where, "detail": f.detail}
                     for f in findings],
        "selftest": st,
    })

    for f in findings:
        print(f)
    missed = [k for k, ok in st.items() if not ok]
    print(f"plan_audit: {len(cells)} cells, {len(findings)} finding(s), "
          f"report -> {args.report}")
    if missed:
        print(f"plan_audit: self-test MISSED: {', '.join(missed)}")
    return 1 if findings or missed else 0


if __name__ == "__main__":
    sys.exit(main())
