"""Continuous-batching benchmark: coalesced scheduler throughput vs.
sequential per-request ``PlanServer.handle`` on the same mixed-shape stream.

Sequential serving pads every request up to its own power-of-two bucket and
decodes it alone; the scheduler fills a bucket's batch dimension with
compatible pending requests, so the same number of decode-step launches
serves several requests at once. Acceptance target: >= 2x request
throughput for the coalesced path, and — with dtype-aware memory estimates —
an fp32 stream must complete with **zero** recompiles (the first estimate
for every bucket is already fp32-sized).

    PYTHONPATH=src python benchmarks/bench_scheduler.py [--smoke]

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and exits
non-zero below the throughput gate or on any spurious recompile.
"""

from __future__ import annotations

import argparse
import sys
import time

TARGET_SPEEDUP = 2.0


def _stream(smoke: bool):
    """Default mixed-shape stream: single-sequence requests (one user query
    each) over two context buckets. Sequential serving decodes each at a
    batch-1 bucket; the scheduler coalesces 8 of them into one group."""
    mix = [(1, 40), (1, 90), (1, 60), (1, 100), (1, 50), (1, 120),
           (1, 40), (1, 100), (1, 60), (1, 90), (1, 50), (1, 100),
           (1, 40), (1, 120), (1, 60), (1, 90)]
    if smoke:
        return mix, 8, 4
    return mix * 2, 8, 6


def _measure(smoke: bool, arch: str):
    """Returns (rows, speedup, recompiles): CSV rows plus the numeric gates
    so CI doesn't re-parse its own formatting. Both paths serve full
    prefill+decode requests from warm plan caches; each is timed over
    several trials and the best trial is compared (noise floor, not luck)."""
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.runtime.scheduler import (ContinuousBatchingScheduler,
                                         simulate_arrivals)
    from repro.runtime.serve_loop import PlanServer, ServeRequest

    cfg = get_config(arch)
    shapes, new_tokens, trials = _stream(smoke)
    reqs = [ServeRequest(b, c, new_tokens) for b, c in shapes]

    # warm both paths: compile + trace every bucket outside measurement
    srv_seq = PlanServer(cfg, dtype=jnp.float32, capacity=16, prefill=True)
    for b, c in sorted(set(shapes)):
        srv_seq.handle(ServeRequest(b, c, new_tokens))
    srv = PlanServer(cfg, dtype=jnp.float32, capacity=16)
    ContinuousBatchingScheduler(srv, max_group_batch=8).run(
        simulate_arrivals(reqs))

    # interleave trials so transient box load penalizes both paths alike;
    # compare best-of-trials (the noise floor, not the luck of one run)
    seq_s, coal_s, sched = None, None, None
    for _ in range(trials):
        dt = _time_trial(lambda: [srv_seq.handle(r) for r in reqs])
        if seq_s is None or dt < seq_s:
            seq_s = dt
        trial = ContinuousBatchingScheduler(srv, max_group_batch=8)
        dt = _time_trial(lambda: trial.run(simulate_arrivals(reqs)))
        if coal_s is None or dt < coal_s:
            coal_s, sched = dt, trial
    seq_rps = len(reqs) / seq_s
    coal_rps = len(reqs) / coal_s

    speedup = coal_rps / seq_rps if seq_rps else 0.0
    recompiles = srv.metrics.recompiles + srv_seq.metrics.recompiles
    m = sched.metrics
    rows = [
        f"scheduler_sequential,{seq_s / len(reqs) * 1e6:.0f},"
        f"rps={seq_rps:.2f};recompiles={srv_seq.metrics.recompiles}",
        f"scheduler_coalesced,{coal_s / len(reqs) * 1e6:.0f},"
        f"rps={coal_rps:.2f};groups={m.groups};"
        f"bucket_fill={m.bucket_fill:.2f};recompiles={srv.metrics.recompiles}",
        f"scheduler_speedup,{coal_s / len(reqs) * 1e6:.0f},"
        f"x={speedup:.1f};target={TARGET_SPEEDUP}",
    ]
    return rows, speedup, recompiles


def _time_trial(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(smoke: bool = False, arch: str = "yi-6b-smoke"):
    """Harness entry point (benchmarks/run.py contract): CSV rows only."""
    return _measure(smoke, arch)[0]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream for CI (seconds, not minutes)")
    ap.add_argument("--arch", default="yi-6b-smoke")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    rows, speedup, recompiles = _measure(args.smoke, args.arch)
    for row in rows:
        print(row, flush=True)
    ok = True
    if speedup < TARGET_SPEEDUP:
        print(f"FAIL: coalesced speedup {speedup:.1f}x < "
              f"{TARGET_SPEEDUP}x target", file=sys.stderr)
        ok = False
    if recompiles:
        print(f"FAIL: fp32 stream burned {recompiles} recompiles "
              f"(dtype-aware estimates should need zero)", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
