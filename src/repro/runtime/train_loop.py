"""Training runtime: plan-driven train-step construction.

``make_train_step`` turns (model, ExecutionPlan, TrainConfig) into a jit-able
step function whose gradient accumulation, optimizer-state dtype and
sharding constraints all come from the *plan* — the model code never sees
the mesh. This is the runtime half of the paper's compiler: SystemML's
generated execution plan, here realized as a jitted SPMD program.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import MeshConfig, TrainConfig
from repro.core.sharding import spec_for, tree_specs
from repro.core.strategies import PlanConfig
from repro.models.common import ShardCtx
from repro.nn.optim import OPTIMIZER_SLOTS, clip_by_global_norm, get_optimizer


# ---------------------------------------------------------------------------
# optimizer state plumbing (pytree-of-dict params)
# ---------------------------------------------------------------------------


def opt_state_dtype(plan: PlanConfig):
    return jnp.float32 if plan.opt_state_dtype == "float32" else jnp.bfloat16


def init_opt_state(optimizer: str, params: Dict, plan: PlanConfig) -> Dict:
    opt = get_optimizer(optimizer)
    dt = opt_state_dtype(plan)
    return {k: opt.init(v, dtype=dt) for k, v in params.items()}


def opt_state_specs(optimizer: str, param_specs: Dict, plan: PlanConfig) -> Dict:
    slots = OPTIMIZER_SLOTS[optimizer]
    dt = opt_state_dtype(plan)
    return {
        k: tuple(jax.ShapeDtypeStruct(s.shape, dt) for _ in range(slots))
        for k, s in param_specs.items()
    }


def opt_state_axes(optimizer: str, param_axes: Dict) -> Dict:
    slots = OPTIMIZER_SLOTS[optimizer]
    return {k: tuple(ax for _ in range(slots)) for k, ax in param_axes.items()}


# ---------------------------------------------------------------------------
# batch sharding specs
# ---------------------------------------------------------------------------

BATCH_AXES_BY_RANK = {
    2: ("batch", "seq"),
    3: ("batch", "seq", None),
}


def batch_specs(batch_like: Dict, plan: PlanConfig, mesh_cfg: MeshConfig) -> Dict:
    out = {}
    for k, v in batch_like.items():
        axes = BATCH_AXES_BY_RANK.get(len(v.shape), ("batch",) + (None,) * (len(v.shape) - 1))
        if k in ("frames", "patch_embeds"):
            axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = spec_for(tuple(v.shape), axes, plan, mesh_cfg, "act")
    return out


# ---------------------------------------------------------------------------
# the train step
# ---------------------------------------------------------------------------


def make_train_step(model, plan: PlanConfig, mesh_cfg: MeshConfig,
                    train: TrainConfig):
    ctx = ShardCtx(plan, mesh_cfg)
    opt_name = train.optimizer

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, ctx)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if plan.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        m = plan.microbatches

        def split(x):
            b = x.shape[0]
            return x.reshape(m, b // m, *x.shape[1:])

        micro = {k: split(v) for k, v in batch.items()}
        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            acc, loss_sum = carry
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / m,
                               acc, grads)
            return (acc, loss_sum + loss / m), None

        (grads, loss), _ = jax.lax.scan(body, (acc0, jnp.float32(0.0)), micro)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        return loss, {"xent": loss, "aux": jnp.float32(0.0)}, grads

    def train_step(params, opt_state, batch, step):
        loss, metrics, grads = compute_grads(params, batch)
        if train.grad_clip:
            grads, gnorm = clip_by_global_norm(grads, train.grad_clip)
        else:
            gnorm = jnp.float32(0.0)
        opt = get_optimizer(opt_name)
        new_params, new_state = {}, {}
        for k, p in params.items():
            np_, ns = opt.update(p, grads[k], opt_state[k],
                                 lr=train.learning_rate, t=step + 1)
            new_params[k] = np_.astype(p.dtype)
            new_state[k] = ns
        out_metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_params, new_state, out_metrics

    return train_step


def train_shardings(model, plan: PlanConfig, mesh_cfg: MeshConfig,
                    train: TrainConfig, mesh):
    """(param_specs/shardings, opt_specs/shardings) for jit in_shardings."""
    pspecs = model.param_specs()
    paxes = model.param_axes()
    p_part = tree_specs(pspecs, paxes, plan, mesh_cfg, "param")
    o_specs = opt_state_specs(train.optimizer, pspecs, plan)
    o_axes = opt_state_axes(train.optimizer, paxes)
    o_part = {
        k: tuple(spec_for(tuple(s.shape), a, plan, mesh_cfg, "opt")
                 for s, a in zip(o_specs[k], o_axes[k]))
        for k in o_specs
    }
    def as_shard(tree):
        return jax.tree.map(lambda sp: NamedSharding(mesh, sp), tree,
                            is_leaf=lambda x: isinstance(x, P))

    return (pspecs, p_part, as_shard(p_part)), (o_specs, o_part, as_shard(o_part))
