"""Static analysis for the serving stack (SystemML-style plan validation).

Three passes, all CI-gated:

- :mod:`repro.analysis.plan_audit` — walk the closed jaxprs of every
  compiled decode/prefill step across the arch x dtype x bucket matrix and
  flag dtype-promotion leaks, host-sync/callback primitives, non-static
  shapes, and compile-time memory statistics that provably under-estimate
  the step's resident requirement (a future corrective recompile).
- :mod:`repro.analysis.lint` — AST rules for the project invariants the
  runtime enforces by convention (blessed cache/admission helpers, rid
  minting, import hygiene, tracer host-sync, plan-cache encapsulation).
- :mod:`repro.analysis.sanitize` — per-tick structural assertions over the
  live KV pool, engine, and router (``EngineConfig(sanitize=True)``).

This ``__init__`` stays import-light on purpose: ``runtime.engine`` pulls
in :mod:`repro.analysis.sanitize`, while :mod:`repro.analysis.plan_audit`
imports the runtime — eager submodule imports here would close that loop.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict


@dataclass(frozen=True)
class Finding:
    """One analysis finding, shared by all passes.

    ``rule`` is the stable identifier (what waivers and tests key on),
    ``where`` locates it (``path:line`` for lint, a matrix-cell label for
    the plan auditor, an object path for the sanitizer), and ``detail`` is
    the human-readable explanation."""

    rule: str
    where: str
    detail: str
    data: Dict = field(default_factory=dict, compare=False)

    def __str__(self) -> str:
        return f"[{self.rule}] {self.where}: {self.detail}"
