"""Serving launcher: batched greedy decoding with a planner-chosen cache
layout.

Single-shot mode (the original path):

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b-smoke \
        --batch 4 --context 128 --tokens 32

Mixed-shape request-stream mode — exercises the plan cache + dynamic
recompilation end-to-end (``repro.core.plan_cache``): requests of varying
(batch, context) round up to power-of-two buckets, steady-state requests
hit cached compiled plans, and estimate breaches trigger recompilation:

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b-smoke \
        --stream --requests 24 --tokens 4
    # explicit shape mix, cache disabled for A/B:
    PYTHONPATH=src python -m repro.launch.serve --stream \
        --shapes 2x100,1x40,4x60 --no-cache
"""

from __future__ import annotations

import argparse
import random
import time

import jax
import jax.numpy as jnp

from repro.config import InputShape, MeshConfig
from repro.configs import ARCH_IDS, get_config
from repro.core.planner import compile_plan
from repro.models.model import build_model
from repro.runtime.serve_loop import (PlanServer, ServeRequest, greedy_decode,
                                      make_decode_step)

DEFAULT_SHAPE_MIX = ((1, 40), (2, 100), (4, 60), (1, 200), (2, 250))


def _parse_shapes(spec: str):
    """``"2x100,1x40"`` -> ((2, 100), (1, 40))."""
    out = []
    for part in spec.split(","):
        try:
            b, c = part.lower().split("x")
            out.append((int(b), int(c)))
        except ValueError:
            raise SystemExit(
                f"--shapes: bad entry {part!r} (expected BATCHxCONTEXT, "
                f'e.g. "2x100,1x40")')
    return tuple(out)


def serve_stream(args) -> None:
    cfg = get_config(args.arch)
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16
    srv = PlanServer(cfg, dtype=dtype, enable_cache=not args.no_cache,
                     capacity=args.cache_capacity)
    mix = _parse_shapes(args.shapes) if args.shapes else DEFAULT_SHAPE_MIX
    rng = random.Random(args.seed)
    print(f"# stream: {args.requests} requests over shape mix {mix} "
          f"cache={'off' if args.no_cache else 'on'}")
    for i in range(args.requests):
        b, c = mix[rng.randrange(len(mix))]
        out = srv.handle(ServeRequest(b, c, args.tokens))
        flag = " RECOMPILED" if out["recompiled"] else ""
        print(f"req[{i:03d}] batch={b} ctx={c} -> bucket={out['bucket']} "
              f"{out['latency_s'] * 1e3:8.1f}ms{flag}")
        for r in out["recompile_reasons"]:
            print(f"         reason: {r}")
    print(srv.summary())


def serve_once(args) -> None:
    cfg = get_config(args.arch)
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16
    model = build_model(cfg, dtype=dtype)

    n_dev = len(jax.devices())
    mesh_cfg = MeshConfig(shape=(n_dev,), axis_names=("data",))
    shape = InputShape("cli", args.context, args.batch, "decode")
    plan = compile_plan(cfg, shape, mesh_cfg)
    print(plan.explain())

    params = model.init_params(jax.random.PRNGKey(0))
    cache = model.init_cache(args.batch, args.context)
    step = jax.jit(make_decode_step(model, plan.config, mesh_cfg))

    first = jnp.ones((args.batch, 1), jnp.int32)
    # warmup
    _ = step(params, cache, first, jnp.int32(0))
    t0 = time.perf_counter()
    toks, cache = greedy_decode(model, params, cache, first, 0, args.tokens,
                                decode_step=step)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x {args.batch} seqs "
          f"in {dt:.2f}s = {args.tokens * args.batch / dt:.1f} tok/s")
    print("sample:", toks[0, :16].tolist())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--dtype", default="float32")
    # mixed-shape request-stream mode (plan cache + dynamic recompilation)
    ap.add_argument("--stream", action="store_true",
                    help="serve a mixed-shape request stream via PlanServer")
    ap.add_argument("--requests", type=int, default=16,
                    help="stream mode: number of requests")
    ap.add_argument("--shapes", default="",
                    help='stream mode: request mix as "BxC,BxC,..." '
                         "(default: built-in 5-shape mix)")
    ap.add_argument("--no-cache", action="store_true",
                    help="stream mode: disable the plan cache (A/B baseline)")
    ap.add_argument("--cache-capacity", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.stream:
        serve_stream(args)
    else:
        serve_once(args)


if __name__ == "__main__":
    main()
