"""End-to-end behaviour tests for the paper's system.

The paper's §2 workflow, reproduced: declare a model (Keras2DML analogue),
let the cost-based compiler pick an execution plan, train with one of the
six optimizers, score with the parfor allreduce plan — and the serving
path: plan -> sharded decode loop.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (INPUT_SHAPES, SINGLE_DEVICE_MESH, SINGLE_POD_MESH,
                          InputShape, TrainConfig)
from repro.configs import get_config
from repro.configs.softmax_classifier import make_spec as softmax_spec
from repro.core.planner import compile_plan
from repro.core.strategies import Strategy
from repro.data import SyntheticClassification, make_batch
from repro.frontend import Keras2Plan
from repro.models.model import build_model
from repro.runtime.serve_loop import greedy_decode
from repro.runtime.train_loop import init_opt_state, make_train_step

KEY = jax.random.PRNGKey(0)


def test_paper_workflow_end_to_end():
    """Section 2's example: softmax classifier, minibatch SGD, scoring."""
    spec, meta = softmax_spec(num_features=30, num_classes=5)
    data = SyntheticClassification(30, 5)
    x, y = data.batch(1024)
    est = Keras2Plan(spec, meta, optimizer="sgd", lr=0.5, batch_size=32,
                     epochs=2, train_algo="minibatch", test_algo="allreduce")
    est.fit(x, y)
    assert est.history[-1] < est.history[0] * 0.6
    xt, yt = data.batch(256, step=1)
    assert est.score(xt, yt) > 0.7
    assert "affine::forward" in est.dml_script


def test_big_model_train_loop_loss_decreases():
    """Reduced-config model, a few dozen steps on CPU: the full runtime
    path (planner plan -> train step -> optimizer)."""
    cfg = get_config("granite-8b-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    shape = InputShape("t", 32, 8, "train")
    plan = compile_plan(cfg, shape, SINGLE_DEVICE_MESH)
    assert plan.config.strategy == Strategy.LOCAL
    train = TrainConfig(optimizer="adam", learning_rate=1e-2)
    step = jax.jit(make_train_step(model, plan.config, SINGLE_DEVICE_MESH, train))
    params = model.init_params(KEY)
    opt = init_opt_state("adam", params, plan.config)
    losses = []
    for i in range(50):
        batch = make_batch(cfg, shape, step=i, dtype=jnp.float32)
        params, opt, m = step(params, opt, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.9, losses


def test_serve_path_greedy_decode():
    cfg = get_config("mamba2-1.3b-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(KEY)
    cache = model.init_cache(2, 32)
    first = jnp.ones((2, 1), jnp.int32)
    toks, cache = greedy_decode(model, params, cache, first, 0, 8)
    assert toks.shape == (2, 8)
    assert int(toks.max()) < cfg.vocab_size


def test_plan_explain_is_informative():
    cfg = get_config("llama3-405b")
    plan = compile_plan(cfg, INPUT_SHAPES["train_4k"], SINGLE_POD_MESH)
    text = plan.explain()
    for needle in ("EXECUTION PLAN", "strategy", "memory/chip", "cost/chip"):
        assert needle in text


def test_microbatched_step_matches_unmicrobatched():
    """Gradient accumulation is semantics-preserving (same loss surface)."""
    cfg = get_config("yi-6b-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    shape = InputShape("t", 16, 8, "train")
    train = TrainConfig(optimizer="sgd", learning_rate=1e-2, grad_clip=0.0)
    p1 = compile_plan(cfg, shape, SINGLE_DEVICE_MESH).config.replace(microbatches=1)
    p4 = p1.replace(microbatches=4)
    params = model.init_params(KEY)
    batch = make_batch(cfg, shape, dtype=jnp.float32)
    s1 = make_train_step(model, p1, SINGLE_DEVICE_MESH, train)
    s4 = make_train_step(model, p4, SINGLE_DEVICE_MESH, train)
    out1, _, m1 = s1(params, init_opt_state("sgd", params, p1), batch, jnp.int32(0))
    out4, _, m4 = s4(params, init_opt_state("sgd", params, p4), batch, jnp.int32(0))
    for k in out1:
        np.testing.assert_allclose(np.asarray(out1[k]), np.asarray(out4[k]),
                                   rtol=2e-3, atol=2e-5)
