"""Operator dispatch layer (paper §3, "GPU Backend" / "Native BLAS").

SystemML "compile[s] a GPU low-level operator if the input data, intermediate
data and output data for a given operation fits in the GPU device memory",
falling back to generic operators otherwise. The TPU analogue, one level
down the hierarchy: dispatch to the Pallas kernel when the *per-block
working set fits VMEM*, else fall back to plain XLA (jnp) ops.

On this CPU container the Pallas path runs in ``interpret=True`` mode (used
by tests/benchmarks); on a real TPU ``interpret=False`` compiles to Mosaic.
Set ``ops.BACKEND`` to force a path.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import TPU_V5E
from repro.kernels import ref
from repro.kernels.conv2d_im2col import conv2d_im2col
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul as matmul_kernel
from repro.kernels.paged_attention import paged_attention_xla, paged_decode_attention
from repro.kernels.ssd_scan import ssd_scan

# "auto": pallas iff running on TPU; "pallas": force (interpret on CPU);
# "xla": force jnp fallback.
BACKEND = "auto"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas() -> bool:
    if BACKEND == "pallas":
        return True
    if BACKEND == "xla":
        return False
    return _on_tpu()


def _interpret() -> bool:
    return not _on_tpu()


def _fits_vmem(*block_bytes: float) -> bool:
    """SystemML's device-memory-fit test, applied to VMEM per-block sets."""
    return sum(block_bytes) <= TPU_V5E.vmem_bytes * 0.8


# ---------------------------------------------------------------------------


def matmul(a: jnp.ndarray, b: jnp.ndarray, bm: int = 128, bn: int = 128,
           bk: int = 128) -> jnp.ndarray:
    dt = a.dtype.itemsize
    if _use_pallas() and _fits_vmem(bm * bk * dt, bk * bn * dt, bm * bn * 4):
        return matmul_kernel(a, b, bm=bm, bn=bn, bk=bk, interpret=_interpret())
    return ref.matmul_ref(a, b)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, pad: int = 0) -> jnp.ndarray:
    n, c, h, wd = x.shape
    f, _, k, _ = w.shape
    dt = x.dtype.itemsize
    hp, wp = h + 2 * pad, wd + 2 * pad
    ho, wo = (hp - k) // stride + 1, (wp - k) // stride + 1
    blk = c * hp * wp * dt + ho * wo * c * k * k * 4 + c * k * k * 128 * dt
    if _use_pallas() and _fits_vmem(blk):
        return conv2d_im2col(x, w, stride=stride, pad=pad, interpret=_interpret())
    return ref.conv2d_ref(x, w, stride=stride, pad=pad)


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              q_offset: Optional[int] = None, bq: int = 128, bk: int = 128):
    d = q.shape[-1]
    dt = q.dtype.itemsize
    if _use_pallas() and _fits_vmem(bq * d * dt, 2 * bk * d * dt, bq * bk * 4,
                                    bq * d * 4):
        return flash_attention(
            q, k, v, causal=causal, window=window,
            q_offset=-1 if q_offset is None else q_offset,
            bq=bq, bk=bk, interpret=_interpret(),
        )
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)


def paged_attention(q, k_cache, v_cache, tables, pos, *, page: int, sc: int):
    """Fused paged-decode attention; page tables resolved inside the op.

    Pallas path per-block working set: one K and one V physical page, the
    row's (g, D) query group, and the f32 accumulator scratch.
    """
    d = q.shape[-1]
    g = q.shape[2] // k_cache.shape[1]
    dt = q.dtype.itemsize
    if _use_pallas() and _fits_vmem(2 * page * d * dt, g * d * dt,
                                    g * (d + 2) * 4):
        return paged_decode_attention(q, k_cache, v_cache, tables, pos,
                                      page=page, sc=sc, interpret=_interpret())
    return paged_attention_xla(q, k_cache, v_cache, tables, pos,
                               page=page, sc=sc)


def ssd(x, dt, a, b_mat, c_mat, d, *, chunk: int = 64):
    P = x.shape[-1]
    N = b_mat.shape[-1]
    dtb = x.dtype.itemsize
    blk = chunk * (P + 2 * N + 1) * dtb + chunk * chunk * 4 + P * N * 4
    if _use_pallas() and _fits_vmem(blk):
        return ssd_scan(x, dt, a, b_mat, c_mat, d, chunk=chunk,
                        interpret=_interpret())
    y, _ = ref.ssd_chunked_ref(x, dt, a, b_mat, c_mat, d,
                               chunk=min(chunk, x.shape[1]))
    return y
