"""Benchmark harness — one module per paper claim (deliverable d).

Prints ``name,us_per_call,derived`` CSV per the harness contract:
  * operator selection crossover  (paper §3 Sparse Operations)
  * plan selection per arch/shape (paper §1/§3 compiler claim)
  * parfor scaling, collective-free (paper §3 Distributed Operations)
  * kernel micro-benchmarks       (paper §3 BLAS/GPU backend)
  * roofline terms from the dry-run artifacts (deliverable g)
"""

import traceback

from benchmarks import (bench_engine, bench_kernels,
                        bench_operator_selection, bench_parfor,
                        bench_plan_cache, bench_plan_selection,
                        bench_roofline, bench_router)


def main() -> None:
    print("name,us_per_call,derived")
    for mod in (bench_operator_selection, bench_plan_selection,
                bench_plan_cache, bench_engine, bench_router, bench_parfor,
                bench_kernels, bench_roofline):
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{mod.__name__},0,ERROR={type(e).__name__}:{e}")
            traceback.print_exc()


if __name__ == '__main__':
    main()
