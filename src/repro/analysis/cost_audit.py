"""Compute-cost certifier: jaxpr-derived FLOP/traffic bounds that audit
the planner's cost model and its kernel-selection decisions.

SystemML's compiler picks physical operators from size/cost statistics,
so the statistics must be *right*: a drifted constant in
``core/cost.py`` silently flips the paged/gather crossover and nothing
numerical ever notices. The memory statistics got their validator in
PR 7 (the ``plan_audit`` floor/ceiling sandwich) and their lifetime
certificate in PR 9 (``memory_audit``); this pass is the third leg, for
the *compute* statistics (``model_flops_per_step``,
``decode_attention_traffic``, ``decode_kernel_seconds``) and for the
decisions made from them.

**Per-cell cost sandwich.** Every smoke-matrix decode/prefill cell's
closed jaxpr is walked by a per-equation cost interpreter:

- ``dot_general`` / ``conv_general_dilated`` equations yield a certified
  MAC-FLOP count (2 x output elements x contraction size); reductions
  and element-wise primitives count one FLOP per element; data-movement
  primitives (gather/scatter/reshape/transpose/slice/...) count zero.
  ``scan`` bodies multiply by trip count; ``cond`` takes min over
  branches on the floor side and max on the ceiling side; ``while``
  contributes nothing to the floor and one iteration to the ceiling;
  a ``pallas_call`` body is scaled by its grid size on the ceiling side
  only (grid multiplicity is heuristic, so fused-kernel MACs never
  inflate the certified floor).
- operand/result bytes give two traffic bounds: a **floor** (step inputs
  + outputs minus the provably-reused buffers — the donated cache output
  that aliases its input is written only at the new token's slice, never
  re-materialized) and a reuse-free **ceiling** (every equation's
  operands and results spilled, no fusion).

The analytic model is then sandwiched per cell, exactly like the memory
sandwich: certified FLOP floor <= ``cost.flops`` <= ceiling, and traffic
floor <= ``cost.physical_hbm_bytes()`` <= ceiling. The analytic FLOPs
may sit above raw traced MACs (it prices the embedding lookup at matmul
convention, 2 x vocab x d_model per token) and slightly below them for
grouped-conv/SSM families whose 2ND convention undercounts — both
conventions are explicit constants here, not silent slack.

**Decision audits.** On top of the certified per-cell costs, the pass
audits the *selections* through :meth:`PlanCompiler.selection_trace`:

- **crossover monotonicity**: sweeping context length (and separately the
  observed committed-page fraction) must flip the paged/gather choice at
  most once — the analytic delta is linear in the swept statistic, so a
  second flip (an inversion) means the cost terms lost their structure.
  The committed-frac sweep is also directional: raising the fraction
  only ever raises the paged cost, so the flip must be paged -> gather.
- **forced-kernel consistency**: a compiler forced to an operator must
  record that operator on every decode plan (attention-free families
  record ``none``).
- **donation-independence**: the donate knob changes the traffic
  statistic by the same write-back term for every operator, so it must
  never change the kernel choice.
- **explain completeness**: every plan axis in
  :data:`repro.core.strategies.PLAN_AXES` must be recorded by
  ``ExecutionPlan.explain_axes()`` — a plan decision EXPLAIN cannot
  surface is un-debuggable.
- **trace-closure certificate**: the pow2 bucket ladder reachable from an
  :class:`~repro.runtime.engine_config.EngineConfig` is finite and closed
  under re-bucketing (``bucket_pow2`` is idempotent), so the set of jit
  signatures the engine can ever request is a finite product — no
  unbounded-retrace path exists.

Run ``python -m repro.analysis.cost_audit --smoke``: audits the matrix,
runs the planted-violation self-test (an inflated FLOP constant, a
crossover inversion, and a plan axis missing from ``explain()`` must all
be flagged), merges the ``cost`` section into ``ANALYSIS_report.json``,
and exits non-zero on any clean-tree finding or self-test miss.
"""

from __future__ import annotations

import argparse
import math
import sys
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis import Finding
from repro.analysis.matrix import (PAGE_SIZE, POOL_ARENAS, REPORT_PATH,
                                   SMOKE_ARCHS, SMOKE_BUCKETS, SMOKE_DTYPES,
                                   matrix_meta, merge_report, smoke_cells)
from repro.analysis.plan_audit import (aval_bytes, resident_floor_bytes,
                                       sub_jaxprs, trace_cell)
from repro.config import InputShape, MeshConfig
from repro.configs import get_config
from repro.core.plan_cache import BucketPolicy, bucket_pow2
from repro.core.planner import PlanCompiler
from repro.core.strategies import PLAN_AXES
from repro.models.model import build_model
from repro.runtime.engine_config import EngineConfig

# Sandwich conventions (documented, not silent slack):
# - the analytic model counts the embedding lookup at matmul convention
#   (2 x vocab x d_model FLOPs per token) where the trace does a gather;
#   the ceiling gets that allowance explicitly (see _lookup_allowance).
# - FLOP_FLOOR_SLACK absorbs counting-convention skew on grouped convs /
#   SSM scans, where the analytic 2ND undercounts traced MACs by a few
#   percent (mamba2 smoke: 2.5%). The floor is still a real bound: an
#   analytic figure 5% under the traced must-do arithmetic is drift.
# - FLOP_CEIL_SLACK covers transcendental weighting (exp/rsqrt count one
#   FLOP here, several on hardware) and window-convention skew on the
#   analytic attention term.
FLOP_FLOOR_SLACK = 0.95
FLOP_CEIL_SLACK = 1.25

# data movement: zero FLOPs (the traffic bounds price these)
_MOVEMENT = frozenset((
    "gather", "scatter", "dynamic_slice", "dynamic_update_slice",
    "broadcast_in_dim", "reshape", "transpose", "concatenate", "slice",
    "iota", "convert_element_type", "select_n", "squeeze", "rev", "pad",
    "copy", "stop_gradient", "split",
))
_REDUCE_PREFIXES = ("reduce_", "cum", "arg")


@dataclass
class CostBounds:
    """Accumulated per-equation costs for one jaxpr body."""

    macs_lo: float = 0.0      # certified MAC FLOPs (floor side)
    flops_hi: float = 0.0     # MACs + element-wise + reduces (ceiling side)
    eqn_bytes: float = 0.0    # reuse-free traffic: per-eqn operand+result

    def add(self, other: "CostBounds", scale_lo: float = 1.0,
            scale_hi: float = 1.0) -> None:
        self.macs_lo += other.macs_lo * scale_lo
        self.flops_hi += other.flops_hi * scale_hi
        self.eqn_bytes += other.eqn_bytes * scale_hi


def _shape_elems(av) -> int:
    n = 1
    for d in getattr(av, "shape", ()):
        n *= int(d)
    return n


def _dot_flops(eqn) -> float:
    """2 x output elements x contraction length for one dot_general."""
    (lhs_contract, _), _ = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    k = 1
    for d in lhs_contract:
        k *= int(lhs.shape[d])
    return 2.0 * _shape_elems(eqn.outvars[0].aval) * k


def _conv_flops(eqn) -> float:
    """2 x output elements x (kernel spatial x in_channels / groups)."""
    rhs = eqn.invars[1].aval
    dn = eqn.params["dimension_numbers"]
    out_ch = int(rhs.shape[dn.rhs_spec[0]])
    k = _shape_elems(rhs) // max(1, out_ch)
    return 2.0 * _shape_elems(eqn.outvars[0].aval) * k


def _grid_steps(eqn) -> int:
    """Total grid steps of a pallas_call (1 if unreadable)."""
    gm = eqn.params.get("grid_mapping")
    grid = getattr(gm, "grid", None) or eqn.params.get("grid") or ()
    steps = 1
    for g in grid:
        try:
            steps *= int(g)
        except (TypeError, ValueError):
            return 1
    return max(1, steps)


def jaxpr_cost(jaxpr) -> CostBounds:
    """The per-equation cost interpreter (see module doc for the
    conventions on scan/while/cond/pallas_call)."""
    jx = getattr(jaxpr, "jaxpr", jaxpr)
    out = CostBounds()
    for eqn in jx.eqns:
        name = eqn.primitive.name
        if name == "scan":
            length = int(eqn.params.get("length", 1))
            out.add(jaxpr_cost(eqn.params["jaxpr"]), scale_lo=length,
                    scale_hi=length)
            continue
        if name == "while":
            # trip count is not static: nothing certified for the floor,
            # one iteration for the ceiling (serving steps are while-free;
            # the convention is recorded, not load-bearing)
            for key in ("cond_jaxpr", "body_jaxpr"):
                if key in eqn.params:
                    out.add(jaxpr_cost(eqn.params[key]), scale_lo=0.0)
            continue
        if name == "cond":
            branches = [jaxpr_cost(b) for b in eqn.params.get("branches", ())]
            if branches:
                out.macs_lo += min(b.macs_lo for b in branches)
                out.flops_hi += max(b.flops_hi for b in branches)
                out.eqn_bytes += max(b.eqn_bytes for b in branches)
            continue
        if name == "pallas_call":
            body = eqn.params.get("jaxpr")
            if body is not None:
                # ceiling side only: grid multiplicity is heuristic, so
                # fused-kernel MACs never inflate the certified floor
                out.add(jaxpr_cost(body), scale_lo=0.0,
                        scale_hi=_grid_steps(eqn))
            out.eqn_bytes += sum(
                aval_bytes(v.aval) for v in list(eqn.invars)
                + list(eqn.outvars) if hasattr(v, "aval"))
            continue
        subs = sub_jaxprs(eqn)
        if subs:          # pjit / custom_* / checkpoint: run-once bodies
            for s in subs:
                out.add(jaxpr_cost(s))
            continue
        out.eqn_bytes += sum(
            aval_bytes(v.aval) for v in list(eqn.invars) + list(eqn.outvars)
            if hasattr(v, "aval"))
        if name == "dot_general":
            f = _dot_flops(eqn)
            out.macs_lo += f
            out.flops_hi += f
        elif name == "conv_general_dilated":
            f = _conv_flops(eqn)
            out.macs_lo += f
            out.flops_hi += f
        elif name in _MOVEMENT:
            pass
        elif name.startswith(_REDUCE_PREFIXES):
            out.flops_hi += sum(_shape_elems(v.aval) for v in eqn.invars
                                if hasattr(v, "aval"))
        else:             # element-wise / transcendental: 1 FLOP / element
            out.flops_hi += sum(_shape_elems(v.aval) for v in eqn.outvars)
    return out


def _lookup_allowance(cfg, kind: str, batch: int, seq: int) -> float:
    """FLOPs the analytic model charges for the embedding lookup (matmul
    convention) that the trace performs as a zero-FLOP gather."""
    tokens = batch * (seq if kind != "decode" else 1)
    return 2.0 * cfg.vocab_size * cfg.d_model * tokens


# ---------------------------------------------------------------------------
# per-cell sandwich
# ---------------------------------------------------------------------------


def audit_cell(arch: str, dtype: str, kind: str, batch: int, seq: int, *,
               page: int = PAGE_SIZE, pool_arenas: int = POOL_ARENAS,
               decode_kernel: str = "auto", flop_scale: float = 1.0,
               traffic_scale: float = 1.0
               ) -> Tuple[Dict[str, Any], List[Finding]]:
    """Sandwich one cell's analytic FLOP and traffic statistics between
    the jaxpr-derived bounds. ``flop_scale`` / ``traffic_scale`` are the
    self-test hooks: they inflate the analytic figure as a drifted
    constant in ``core/cost.py`` would."""
    where = f"{arch}/{dtype}/{kind}/b{batch}s{seq}"
    if kind == "decode" and decode_kernel != "auto":
        where += f"/{decode_kernel}"
    cfg = get_config(arch)
    mesh_cfg = MeshConfig(shape=(1,), axis_names=("data",))
    model = build_model(cfg, dtype=dtype)
    compiler = PlanCompiler(cache_page_size=page,
                            cache_pool_arenas=pool_arenas,
                            decode_kernel=decode_kernel)
    shape = InputShape(f"req_{batch}x{seq}", seq, batch, kind)
    plan = compiler.compile(cfg, shape, mesh_cfg, dtype=dtype)
    closed, _out_tree, cache = trace_cell(model, plan, mesh_cfg, kind,
                                          batch, seq, page=page)
    bounds = jaxpr_cost(closed.jaxpr)

    flop_floor = FLOP_FLOOR_SLACK * bounds.macs_lo
    flop_ceiling = FLOP_CEIL_SLACK * (
        bounds.flops_hi + _lookup_allowance(cfg, kind, batch, seq))
    analytic_flops = plan.cost.flops * flop_scale

    # provably-reused buffers: a donated cache output aliases its input —
    # only the new token's slice is written, never a full re-materialized
    # copy, so those output bytes leave the traffic floor
    reused = 0
    if kind == "decode" and plan.config.donate_cache and cache is not None:
        reused = sum(int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
                     for s in cache.values())
    traffic_floor = resident_floor_bytes(closed, reused)
    traffic_ceiling = bounds.eqn_bytes
    analytic_traffic = plan.cost.physical_hbm_bytes() * traffic_scale

    findings: List[Finding] = []
    if analytic_flops < flop_floor:
        findings.append(Finding(
            rule="flop-under-estimate", where=where,
            detail=f"analytic {analytic_flops:.3g} FLOPs below the "
                   f"certified floor {flop_floor:.3g} (traced MACs "
                   f"{bounds.macs_lo:.3g}) — the roofline compute term "
                   f"under-prices the step"))
    elif analytic_flops > flop_ceiling:
        findings.append(Finding(
            rule="flop-over-estimate", where=where,
            detail=f"analytic {analytic_flops:.3g} FLOPs above the "
                   f"derived ceiling {flop_ceiling:.3g} — a cost-model "
                   f"constant has drifted (inflated FLOP term)"))
    if analytic_traffic < traffic_floor:
        findings.append(Finding(
            rule="traffic-under-estimate", where=where,
            detail=f"analytic {analytic_traffic:.3g}B physical traffic "
                   f"below the floor {traffic_floor:.3g}B (inputs + "
                   f"non-reused outputs must cross HBM) — the memory "
                   f"roofline term under-prices the step"))
    elif analytic_traffic > traffic_ceiling:
        findings.append(Finding(
            rule="traffic-over-estimate", where=where,
            detail=f"analytic {analytic_traffic:.3g}B physical traffic "
                   f"above the reuse-free ceiling {traffic_ceiling:.3g}B "
                   f"— the statistic exceeds even a fusion-free "
                   f"execution"))
    record = {
        "arch": arch, "dtype": dtype, "kind": kind,
        "batch": batch, "seq": seq,
        "decode_kernel": plan.config.decode_kernel,
        "forced_kernel": decode_kernel,
        "flops": {
            "floor": float(flop_floor),
            "analytic": float(analytic_flops),
            "ceiling": float(flop_ceiling),
            "traced_macs": float(bounds.macs_lo),
        },
        "traffic": {
            "floor_bytes": float(traffic_floor),
            "analytic_bytes": float(analytic_traffic),
            "ceiling_bytes": float(traffic_ceiling),
            "reused_bytes": float(reused),
        },
        "findings": len(findings),
    }
    return record, findings


# ---------------------------------------------------------------------------
# decision audits (pure checkers + the sweeps that feed them)
# ---------------------------------------------------------------------------


def check_selection_monotonic(picks: Sequence[Tuple[Any, str]], where: str,
                              axis: str = "seq") -> List[Finding]:
    """No crossover inversions along one swept statistic.

    ``picks`` is the ordered [(coordinate, kernel), ...] a sweep
    produced. The analytic paged-vs-gather delta is linear in the swept
    statistic (cache bytes and grid steps both scale with it), so a valid
    selection sequence flips at most once; a second flip means the cost
    terms lost the structure selection relies on. The committed-frac
    sweep is additionally directional: raising the fraction only raises
    the paged cost, so the single admissible flip is paged -> gather."""
    out: List[Finding] = []
    kernels = [k for _, k in picks]
    flips = [(picks[i - 1], picks[i]) for i in range(1, len(kernels))
             if kernels[i] != kernels[i - 1]]
    if len(flips) > 1:
        pts = ", ".join(f"{a[1]}@{a[0]}->{b[1]}@{b[0]}" for a, b in flips)
        out.append(Finding(
            rule="crossover-inversion", where=where,
            detail=f"kernel choice flips {len(flips)} times along the "
                   f"{axis} sweep ({pts}); the analytic delta is linear "
                   f"in {axis}, so at most one crossover is possible"))
    elif flips and axis == "committed_frac":
        (_, k_lo), (_, k_hi) = flips[0]
        if (k_lo, k_hi) != ("paged", "gather"):
            out.append(Finding(
                rule="crossover-inversion", where=where,
                detail=f"committed-frac sweep flips {k_lo} -> {k_hi}; "
                       f"raising the fraction only raises the paged "
                       f"cost, so only paged -> gather is admissible"))
    return out


def check_explain_axes(axes: Dict[str, str], where: str) -> List[Finding]:
    """Every plan axis must be recorded by ``explain_axes()``."""
    missing = [a for a in PLAN_AXES if a not in axes]
    return [Finding(
        rule="explain-axis-missing", where=where,
        detail=f"plan axis {a!r} is not recorded by "
               f"ExecutionPlan.explain(): the decision cannot be "
               f"debugged from EXPLAIN output") for a in missing]


def _sweep_seqs(max_seq: int = 8192) -> List[int]:
    s, out = 16, []
    while s <= max_seq:
        out.append(s)
        s *= 2
    return out


def audit_decisions(archs: Sequence[str] = SMOKE_ARCHS,
                    dtypes: Sequence[str] = SMOKE_DTYPES,
                    page: int = PAGE_SIZE,
                    pool_arenas: int = POOL_ARENAS,
                    log=None) -> Tuple[Dict[str, Any], List[Finding]]:
    """The full plan-axis cross product of selection checks: crossover
    monotonicity (context-length and committed-frac sweeps),
    forced-kernel consistency, donation-independence, and explain
    completeness, per (arch x dtype x bucket)."""
    findings: List[Finding] = []
    sweeps: List[Dict[str, Any]] = []
    mesh_cfg = MeshConfig(shape=(1,), axis_names=("data",))
    for arch in archs:
        cfg = get_config(arch)
        for dtype in dtypes:
            where = f"{arch}/{dtype}"
            compiler = PlanCompiler(cache_page_size=page,
                                    cache_pool_arenas=pool_arenas)
            # crossover monotonicity in context length
            picks = [(s, compiler.selection_trace(
                cfg, InputShape("sweep", s, 4, "decode"))["kernel"])
                for s in _sweep_seqs()]
            findings += check_selection_monotonic(
                picks, f"{where}/seq-sweep", axis="seq")
            # crossover monotonicity in committed pages
            shape = InputShape("sweep", 128, 4, "decode")
            fracs = [i / 20.0 for i in range(1, 21)]
            frac_picks = [(f, compiler.selection_trace(
                cfg, shape, committed_frac=f)["kernel"]) for f in fracs]
            findings += check_selection_monotonic(
                frac_picks, f"{where}/frac-sweep", axis="committed_frac")
            sweeps.append({"arch": arch, "dtype": dtype,
                           "seq_picks": [[s, k] for s, k in picks],
                           "frac_picks": [[f, k] for f, k in frac_picks]})
            for batch, seq in SMOKE_BUCKETS:
                shape = InputShape(f"b{batch}s{seq}", seq, batch, "decode")
                cell = f"{where}/b{batch}s{seq}"
                # forced-kernel consistency across the forced axis
                for forced in ("paged", "gather", "ref"):
                    fc = PlanCompiler(cache_page_size=page,
                                      cache_pool_arenas=pool_arenas,
                                      decode_kernel=forced)
                    got = fc.compile(cfg, shape, mesh_cfg,
                                     dtype=dtype).config.decode_kernel
                    want = ("none" if cfg.layer_pattern().count("a") == 0
                            else forced)
                    if got != want:
                        findings.append(Finding(
                            rule="forced-kernel-mismatch", where=cell,
                            detail=f"compiler forced {forced!r} but the "
                                   f"plan records {got!r} "
                                   f"(expected {want!r})"))
                # donation-independence of the kernel choice
                kernels = set()
                for donate in (True, False):
                    dc = PlanCompiler(cache_page_size=page,
                                      cache_pool_arenas=pool_arenas,
                                      donate_cache=donate)
                    kernels.add(dc.compile(cfg, shape, mesh_cfg,
                                           dtype=dtype).config.decode_kernel)
                if len(kernels) > 1:
                    findings.append(Finding(
                        rule="donation-dependent-kernel", where=cell,
                        detail=f"kernel choice depends on the donate "
                               f"knob ({sorted(kernels)}); the write-back "
                               f"term is operator-independent, so it "
                               f"must never move the crossover"))
                # explain completeness over every plan axis
                plan = PlanCompiler(
                    cache_page_size=page,
                    cache_pool_arenas=pool_arenas).compile(
                        cfg, shape, mesh_cfg, dtype=dtype)
                findings += check_explain_axes(plan.explain_axes(), cell)
            if log:
                log(f"  {where}: seq sweep "
                    f"{'/'.join(k for _, k in picks)}")
    return {"sweeps": sweeps}, findings


# ---------------------------------------------------------------------------
# trace-closure certificate
# ---------------------------------------------------------------------------


def _bucket_ladder(max_value: int, minimum: int) -> List[int]:
    """All buckets reachable from requests bounded by ``max_value``."""
    out, b = [], bucket_pow2(1, minimum)
    top = bucket_pow2(max_value, minimum)
    while b <= top:
        out.append(b)
        b *= 2
    return out


def trace_closure_certificate(
        engine: Optional[EngineConfig] = None,
        policy: Optional[BucketPolicy] = None,
        max_seq: int = 65_536) -> Tuple[Dict[str, Any], List[Finding]]:
    """Certify that the jit-signature set reachable from an EngineConfig
    is finite. Signatures are keyed by (kind, batch bucket, seq bucket,
    decode kernel, donate); the bucket ladders are finite pow2 sets, the
    kernel axis is bounded by the operator vocabulary (dynamic
    recompilation can flip paged <-> gather per bucket), and donate is
    pinned by the config — so the product is finite *provided* bucketing
    is closed (idempotent: re-bucketing a bucketed shape is a fixed
    point, so re-entrant recompiles mint no new signatures). Idempotence
    and coverage are checked bucket by bucket, not assumed."""
    engine = engine or EngineConfig()
    policy = policy or BucketPolicy()
    findings: List[Finding] = []
    where = "trace-closure"
    batches = _bucket_ladder(engine.max_group_batch, policy.min_batch)
    seqs = _bucket_ladder(max_seq, policy.min_seq)
    # closure: every ladder entry is a fixed point of its own bucketing
    for b in batches:
        if bucket_pow2(b, policy.min_batch) != b:
            findings.append(Finding(
                rule="trace-closure", where=where,
                detail=f"batch bucket {b} is not a bucketing fixed point "
                       f"— re-entrant recompiles mint new signatures"))
    for s in seqs:
        if bucket_pow2(s, policy.min_seq) != s:
            findings.append(Finding(
                rule="trace-closure", where=where,
                detail=f"seq bucket {s} is not a bucketing fixed point"))
    # coverage: boundary request sizes land inside the ladder
    probes = [1, 2, 3, max_seq // 2 + 1, max_seq]
    for n in probes:
        if 1 <= n <= max_seq and bucket_pow2(n, policy.min_seq) not in seqs:
            findings.append(Finding(
                rule="trace-closure", where=where,
                detail=f"request seq {n} buckets outside the ladder"))
    kinds = ("decode", "prefill") if engine.prefill else ("decode",)
    kernels = (1 if engine.decode_kernel != "auto"
               else 2)   # auto: recompile can flip paged <-> gather
    signatures = len(batches) * len(seqs) * len(kinds) * kernels
    bound = ((math.floor(math.log2(max(batches) // min(batches))) + 1)
             * (math.floor(math.log2(max(seqs) // min(seqs))) + 1)
             * len(kinds) * kernels)
    if signatures > bound:
        findings.append(Finding(
            rule="trace-closure", where=where,
            detail=f"{signatures} reachable signatures exceed the "
                   f"log-product bound {bound}"))
    record = {
        "batch_buckets": batches,
        "seq_buckets": seqs,
        "kinds": list(kinds),
        "kernel_axis": kernels,
        "signatures": signatures,
        "bound": bound,
        "finite": not findings,
    }
    return record, findings


# ---------------------------------------------------------------------------
# smoke driver
# ---------------------------------------------------------------------------


def run_audit(archs: Sequence[str] = SMOKE_ARCHS,
              dtypes: Sequence[str] = SMOKE_DTYPES,
              buckets: Sequence[Tuple[int, int]] = SMOKE_BUCKETS,
              kinds: Sequence[str] = ("decode", "prefill"),
              page: int = PAGE_SIZE,
              pool_arenas: int = POOL_ARENAS,
              log=None) -> Tuple[List[Dict[str, Any]], List[Finding]]:
    cells: List[Dict[str, Any]] = []
    findings: List[Finding] = []
    for cell in smoke_cells(archs=archs, dtypes=dtypes, buckets=buckets,
                            kinds=kinds):
        rec, found = audit_cell(cell.arch, cell.dtype, cell.kind,
                                cell.batch, cell.seq, page=page,
                                pool_arenas=pool_arenas,
                                decode_kernel=cell.forced_kernel)
        cells.append(rec)
        findings.extend(found)
        if log:
            fl, tr = rec["flops"], rec["traffic"]
            log(f"  {cell.where}: flops "
                f"{fl['floor']:.3g} <= {fl['analytic']:.3g} <= "
                f"{fl['ceiling']:.3g}; traffic "
                f"{tr['floor_bytes']:.3g} <= {tr['analytic_bytes']:.3g} "
                f"<= {tr['ceiling_bytes']:.3g}; "
                f"{rec['findings']} finding(s)")
    return cells, findings


# ---------------------------------------------------------------------------
# self-test: planted violations the auditor must flag
# ---------------------------------------------------------------------------


def selftest(arch: str = "yi-6b-smoke") -> Dict[str, Any]:
    """Three planted violations (an inflated FLOP constant, a crossover
    inversion, a plan axis missing from explain) plus a clean control."""
    _, clean = audit_cell(arch, "bfloat16", "decode", 2, 64,
                          decode_kernel="gather")
    # a 64x-inflated FLOP constant must overflow the derived ceiling
    _, inflated = audit_cell(arch, "bfloat16", "decode", 2, 64,
                             decode_kernel="gather", flop_scale=64.0)
    # and a 64x-deflated one must fall through the certified floor
    _, deflated = audit_cell(arch, "bfloat16", "decode", 2, 64,
                             decode_kernel="gather", flop_scale=1 / 64.0)

    # a doctored selection sweep with a second flip (the inversion) must
    # flag; the real compiler sweep must not
    doctored = [(64, "gather"), (128, "paged"), (256, "gather"),
                (512, "paged")]
    inversion = check_selection_monotonic(doctored, "selftest/doctored")
    compiler = PlanCompiler(cache_page_size=PAGE_SIZE,
                            cache_pool_arenas=POOL_ARENAS)
    cfg = get_config(arch)
    honest = check_selection_monotonic(
        [(s, compiler.selection_trace(
            cfg, InputShape("sweep", s, 4, "decode"))["kernel"])
         for s in _sweep_seqs()], "selftest/honest")

    # a plan axis dropped from the explain record must flag; the full
    # record must not
    mesh_cfg = MeshConfig(shape=(1,), axis_names=("data",))
    plan = compiler.compile(cfg, InputShape("probe", 64, 2, "decode"),
                            mesh_cfg, dtype="bfloat16")
    axes = dict(plan.explain_axes())
    axes.pop("decode_kernel")
    missing = check_explain_axes(axes, "selftest/dropped-axis")
    complete = check_explain_axes(plan.explain_axes(), "selftest/full")
    return {
        "clean_control": not clean,
        "inflated_flops_flagged": any(f.rule == "flop-over-estimate"
                                      for f in inflated),
        "deflated_flops_flagged": any(f.rule == "flop-under-estimate"
                                      for f in deflated),
        "crossover_inversion_flagged": (
            any(f.rule == "crossover-inversion" for f in inversion)
            and not honest),
        "missing_explain_axis_flagged": (
            any(f.rule == "explain-axis-missing" for f in missing)
            and not complete),
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="jaxpr-derived FLOP/traffic bounds auditing the "
                    "planner's cost model and its selection decisions")
    ap.add_argument("--smoke", action="store_true",
                    help="audit the CI smoke matrix (cost sandwich + "
                         "selection invariants + trace closure) plus the "
                         "planted-violation self-test")
    ap.add_argument("--archs", nargs="*", default=None,
                    help="override the arch list")
    ap.add_argument("--report", default=REPORT_PATH,
                    help=f"JSON report path (default {REPORT_PATH})")
    ap.add_argument("--no-selftest", action="store_true",
                    help="skip the planted-violation self-test")
    args = ap.parse_args(argv)

    archs = tuple(args.archs) if args.archs else SMOKE_ARCHS
    print(f"cost_audit: {len(archs)} arch(s) x {len(SMOKE_DTYPES)} dtypes "
          f"x {len(SMOKE_BUCKETS)} buckets")
    cells, findings = run_audit(archs=archs, log=print)
    decisions, dec_findings = audit_decisions(archs=archs, log=print)
    findings += dec_findings
    closure, cls_findings = trace_closure_certificate()
    findings += cls_findings
    print(f"  trace closure: {closure['signatures']} reachable jit "
          f"signatures (bound {closure['bound']}), "
          f"finite={closure['finite']}")

    st: Dict[str, Any] = {}
    if not args.no_selftest:
        st = selftest()
        for probe, ok in st.items():
            print(f"  selftest {probe}: {'ok' if ok else 'MISSED'}")

    merge_report(args.report, {"cost": {
        "matrix": matrix_meta(archs=archs),
        "cells": cells,
        "decisions": decisions,
        "trace_closure": closure,
        "findings": [{"rule": f.rule, "where": f.where, "detail": f.detail}
                     for f in findings],
        "selftest": st,
    }})

    for f in findings:
        print(f)
    missed = [k for k, ok in st.items() if not ok]
    print(f"cost_audit: {len(cells)} cells, {len(findings)} finding(s), "
          f"report -> {args.report} [cost]")
    if missed:
        print(f"cost_audit: self-test MISSED: {', '.join(missed)}")
    return 1 if findings or missed else 0


if __name__ == "__main__":
    sys.exit(main())
