"""internvl2-2b [vlm] — 24L, d_model=2048, 16H (GQA kv=8), d_ff=8192,
vocab=92553. InternViT vision encoder + projector is a STUB: ``input_specs``
supplies precomputed patch embeddings (256 prefix tokens). [arXiv:2404.16821]
"""

from repro.config import ModelConfig


def make_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92553,
        frontend="vision",
        num_frontend_tokens=256,
        rope_theta=1_000_000.0,
        citation="arXiv:2404.16821",
    )
