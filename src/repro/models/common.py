"""Shared model substrate: param-spec helpers, RMSNorm, RoPE, sharding ctx.

Parameters travel as nested dicts of arrays; every param dict has a
*parallel axes dict* whose leaves are tuples of logical axis names consumed
by ``repro.core.sharding`` — the planner owns physical layout, the model
owns logical structure (the SystemML separation of script from plan).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.config import MeshConfig
from repro.core.sharding import spec_for
from repro.core.strategies import PlanConfig


# ---------------------------------------------------------------------------
# ShardCtx: plan-driven sharding hints inside model code
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardCtx:
    plan: Optional[PlanConfig] = None
    mesh_cfg: Optional[MeshConfig] = None

    def constrain(self, x: jnp.ndarray, axes: Tuple[Optional[str], ...],
                  kind: str = "act") -> jnp.ndarray:
        if self.plan is None or self.mesh_cfg is None:
            return x
        if self.mesh_cfg.num_devices == 1:
            return x  # LOCAL plan: nothing to constrain (no mesh in context)
        spec = spec_for(tuple(x.shape), axes, self.plan, self.mesh_cfg, kind)
        return lax.with_sharding_constraint(x, spec)

    def ckpt_constrain(self, x: jnp.ndarray) -> jnp.ndarray:
        """Residual-checkpoint constraint: seq over 'model' when the plan
        chose sequence-parallel remat checkpoints (Megatron SP). GSPMD
        lowers the transition out of a TP region into a reduce-scatter."""
        if self.plan is None or not self.plan.seq_shard_checkpoints:
            return x
        batch = self.plan.batch_axes or None
        return lax.with_sharding_constraint(x, P(batch, "model", None))

    def constrain_seq_model(self, x: jnp.ndarray) -> jnp.ndarray:
        """Pin dim-1 (seq) to the model axis, rest replicated-by-batch —
        the SP-attention layout for archs whose heads don't divide the
        model axis."""
        if self.plan is None or self.mesh_cfg is None or self.mesh_cfg.num_devices == 1:
            return x
        batch = self.plan.batch_axes or None
        return lax.with_sharding_constraint(
            x, P(*([batch, "model"] + [None] * (x.ndim - 2))))

    def seq_gather(self, x: jnp.ndarray) -> jnp.ndarray:
        """Megatron-SP region boundary: all-gather the seq dim at layer
        entry so the TP dims (heads/ffn) are free to use the model axis —
        without this, GSPMD resolves the axis conflict by gathering the
        *weights* every layer (catastrophically worse)."""
        if self.plan is None or not self.plan.seq_shard_checkpoints:
            return x
        batch = self.plan.batch_axes or None
        return lax.with_sharding_constraint(
            x, P(*([batch] + [None] * (x.ndim - 1))))


NULL_CTX = ShardCtx()


# ---------------------------------------------------------------------------
# param spec plumbing
# ---------------------------------------------------------------------------


class SpecBuilder:
    """Collects (shape, axes, init) triples; materializes either
    ShapeDtypeStructs (dry-run) or real initialized arrays (smoke/train)."""

    def __init__(self, dtype=jnp.bfloat16):
        self.dtype = dtype
        self.entries: Dict[str, Any] = {}

    def add(self, name: str, shape: Tuple[int, ...],
            axes: Tuple[Optional[str], ...], init: str = "normal",
            scale: Optional[float] = None, dtype=None):
        assert len(shape) == len(axes), (name, shape, axes)
        self.entries[name] = (tuple(shape), tuple(axes), init, scale,
                              dtype or self.dtype)
        return self

    def specs(self):
        return {
            k: jax.ShapeDtypeStruct(sh, dt)
            for k, (sh, ax, ini, sc, dt) in self.entries.items()
        }

    def axes(self):
        return {k: ax for k, (sh, ax, ini, sc, dt) in self.entries.items()}

    def init(self, key):
        out = {}
        for k, (sh, ax, ini, sc, dt) in self.entries.items():
            key, sub = jax.random.split(key)
            if ini == "zeros":
                out[k] = jnp.zeros(sh, dt)
            elif ini == "ones":
                out[k] = jnp.ones(sh, dt)
            elif ini == "ssm_a":
                # A_log init: log of uniform [1, 16] (mamba2 convention)
                out[k] = jnp.log(
                    jax.random.uniform(sub, sh, jnp.float32, 1.0, 16.0)
                ).astype(dt)
            else:
                fan_in = sh[-2] if len(sh) >= 2 else sh[-1]
                s = sc if sc is not None else 1.0 / math.sqrt(max(1, fan_in))
                out[k] = (jax.random.normal(sub, sh, jnp.float32) * s).astype(dt)
        return out


def merge_trees(**subtrees):
    return dict(subtrees)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(ms + eps)).astype(x.dtype) * gamma


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def softmax_xent_logits(logits: jnp.ndarray, targets: jnp.ndarray,
                        mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """logits (..., V) bf16 -> fp32 mean xent over unmasked positions."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: (B, S, C); w: (W, C).
    With ``state`` (B, W-1, C): single-step decode (S==1) path returning
    (y, new_state)."""
    wd = w.shape[0]
    if state is not None:
        full = jnp.concatenate([state, x], axis=1)       # (B, W, C)
        y = jnp.einsum("bwc,wc->bc", full[:, -wd:], w)[:, None, :]
        return y, full[:, 1:]
    pad = jnp.zeros(x.shape[:1] + (wd - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    # stack of shifted views -> einsum (BLAS-3 form, no explicit loop conv)
    views = jnp.stack([xp[:, i : i + x.shape[1]] for i in range(wd)], axis=0)
    return jnp.einsum("wbsc,wc->bsc", views, w)
