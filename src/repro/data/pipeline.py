"""Data pipeline.

SystemML consumes data "generated as part of the big data pipeline" —
NumPy arrays / Spark DataFrames flow into Keras2DML's ``fit(X, Y)``. Here:
deterministic synthetic corpora (token streams, classification matrices,
modality embeddings) + host-side batching with per-shard slicing, so each
data-parallel host only materializes its slice (the RDD-partition analogue).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

import jax.numpy as jnp

from repro.config import InputShape, ModelConfig


@dataclass
class TokenDatasetSpec:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLM:
    """Deterministic synthetic language-modelling stream: a noisy order-2
    Markov chain over the vocab, so models can actually reduce loss on it
    (pure-uniform tokens would pin xent at log V)."""

    def __init__(self, spec: TokenDatasetSpec):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        v = spec.vocab_size
        self._shift = int(rng.integers(1, max(2, min(v, 97))))
        self._noise = 0.15

    def batch(self, step: int, batch_size: Optional[int] = None) -> Dict[str, np.ndarray]:
        s = self.spec
        b = batch_size or s.global_batch
        rng = np.random.default_rng((s.seed, step))
        first = rng.integers(0, s.vocab_size, (b, 1))
        toks = [first]
        for t in range(s.seq_len):
            prev = toks[-1]
            nxt = (prev * 31 + self._shift) % s.vocab_size
            noise = rng.random((b, 1)) < self._noise
            rand = rng.integers(0, s.vocab_size, (b, 1))
            toks.append(np.where(noise, rand, nxt))
        seq = np.concatenate(toks, axis=1).astype(np.int32)  # (b, S+1)
        return {"tokens": seq[:, :-1], "targets": seq[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class SyntheticClassification:
    """(X, Y) design-matrix data for the paper's own demos (softmax
    classifier / LeNet): a random linear teacher, optionally sparsified —
    SystemML's sparse-input regime."""

    def __init__(self, num_features: int, num_classes: int, seed: int = 0,
                 density: float = 1.0):
        self.d, self.k, self.seed, self.density = num_features, num_classes, seed, density
        rng = np.random.default_rng(seed)
        self.teacher = rng.standard_normal((num_features, num_classes))

    def batch(self, n: int, step: int = 0):
        rng = np.random.default_rng((self.seed, step, 1))
        x = rng.standard_normal((n, self.d))
        if self.density < 1.0:
            mask = rng.random((n, self.d)) < self.density
            x = x * mask
        y = np.argmax(x @ self.teacher + 0.1 * rng.standard_normal((n, self.k)), axis=1)
        onehot = np.eye(self.k, dtype=np.float32)[y]
        return x.astype(np.float32), onehot


def make_batch(model: ModelConfig, shape: InputShape, step: int = 0,
               batch_override: Optional[int] = None,
               seq_override: Optional[int] = None,
               dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Materialize one host-side batch (numpy->jnp) for any arch/shape."""
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    # fixed dataset seed: the Markov rule is a property of the corpus, the
    # step only selects the batch window
    lm = SyntheticLM(TokenDatasetSpec(model.vocab_size, s, b, seed=0))
    batch = {k: jnp.asarray(v) for k, v in lm.batch(step, b).items()}
    rng = np.random.default_rng((7, step))
    if model.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, model.num_frontend_tokens, model.d_model)),
            dtype=dtype)
    if model.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, model.encoder_seq, model.d_model)),
            dtype=dtype)
    return batch
