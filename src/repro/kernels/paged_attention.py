"""Paged-attention decode Pallas kernel (page tables resolved in-kernel).

Decode attention against a *paged* KV cache: K/V live in a flat slot stack
``(n_slots, Hkv, D)`` shared by all rows of an arena, and each request row
owns a ``(max_pages,)`` int32 page table mapping its logical pages onto
physical ones. The serving hot path previously resolved that indirection
with jnp gathers *around* the flash kernel, materializing a gathered
``(B, Sc, Hkv, D)`` K/V copy plus a GQA-expanded ``(B, Sc, Hq, D)`` copy
before attending. This kernel fuses the indirection into the attention
itself:

- grid ``(B, Hkv, n_pages)`` — one block row per (request, kv head), the
  page axis minor (sequential) so online-softmax state lives in VMEM;
- the page table and per-row ``pos`` ride in as *scalar prefetch* operands
  (``PrefetchScalarGridSpec``), so the K/V BlockSpec index_maps read the
  table entry and DMA the physical page directly — no gathered copy exists;
- accumulation covers *committed pages only*: page ``j`` of a row is
  skipped (``pl.when``) unless ``j * page < min(pos + 1, Sc)``.

Mask equivalence (why one kernel serves both cache layouts): the decode
validity rule in ``models/attention.py::decode_attention`` is

    non-rotating:  valid(i) = i <= pos
    rotating:      valid(i) = 0 <= pos - mod(pos - i, Sc) <= pos

For a single query at position ``pos`` both reduce to the same set
``i < min(pos + 1, Sc)``: a rotating cache at depth ``pos >= Sc`` has every
slot live, and below that depth slots ``i <= pos`` are exactly the written
ones. The rotation only changes *which absolute position* a slot holds
(i.e. the cache contents), never the valid set, so the kernel needs ``pos``
and ``Sc`` but not the window.

``paged_attention_xla`` is the fallback form for non-TPU backends: same
committed-slot masking, grouped GQA einsums straight off the flat slot
stack (no ``jnp.repeat`` expansion), one gather instead of three
materialized intermediates. Dispatch between them lives in ``ops.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import NEG_INF, phys_slots


def _paged_decode_kernel(
    tables_ref,  # (B, n_pages) int32, scalar prefetch
    pos_ref,     # (B,) int32, scalar prefetch
    q_ref,       # (1, 1, g, D)
    k_ref,       # (page, 1, D) — the physical page picked by the index_map
    v_ref,       # (page, 1, D)
    o_ref,       # (1, 1, g, D)
    m_ref,       # (g, 1) f32 scratch
    l_ref,       # (g, 1) f32 scratch
    acc_ref,     # (g, D) f32 scratch
    *, page: int, n_pages: int, sc: int, g: int, scale: float,
):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n_valid = jnp.minimum(pos_ref[b] + 1, sc)  # committed slots in this row

    @pl.when(j * page < n_valid)
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (g, d)
        k = k_ref[:, 0, :].astype(jnp.float32)                 # (page, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, page)
        islot = j * page + jax.lax.broadcasted_iota(jnp.int32, (g, page), 1)
        mask = islot < n_valid
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                    # (g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(
            p, v_ref[:, 0, :].astype(jnp.float32)
        )
        m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _done():
        lsum = l_ref[...]
        safe = jnp.where(lsum == 0.0, 1.0, lsum)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("page", "sc", "interpret"))
def paged_decode_attention(
    q: jnp.ndarray,        # (B, 1, Hq, D) — one new token per row
    k_cache: jnp.ndarray,  # (n_slots, Hkv, D) flat slot stack
    v_cache: jnp.ndarray,  # (n_slots, Hkv, D)
    tables: jnp.ndarray,   # (B, n_pages) int32; unallocated entries >= n_phys
    pos: jnp.ndarray,      # (B,) int32 absolute position of the new token
    *,
    page: int,
    sc: int,               # logical cache length per row (bucket Sc)
    interpret: bool = False,
) -> jnp.ndarray:
    bsz, _, hq, d = q.shape
    hkv = k_cache.shape[1]
    g = hq // hkv
    n_phys = k_cache.shape[0] // page
    n_pages = tables.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (bsz,))

    # Sentinel / out-of-range table entries are clamped to a real page at
    # DMA time; the committed-slot mask keeps their scores out of the sum.
    def kv_map(b, h, j, tables_ref, pos_ref):
        del pos_ref
        return (jnp.minimum(tables_ref[b, j], n_phys - 1), h, 0)

    grid = (bsz, hkv, n_pages)
    kernel = functools.partial(
        _paged_decode_kernel, page=page, n_pages=n_pages, sc=sc, g=g,
        scale=1.0 / (d ** 0.5),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d), lambda b, h, j, *_: (b, h, 0, 0)),
                pl.BlockSpec((page, 1, d), kv_map),
                pl.BlockSpec((page, 1, d), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d), lambda b, h, j, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype),
        interpret=interpret,
    )(tables, pos, q.reshape(bsz, hkv, g, d), k_cache, v_cache)
    return out.reshape(bsz, hq, d)[:, None]


def paged_attention_xla(
    q: jnp.ndarray,        # (B, 1, Hq, D)
    k_cache: jnp.ndarray,  # (n_slots, Hkv, D)
    v_cache: jnp.ndarray,  # (n_slots, Hkv, D)
    tables: jnp.ndarray,   # (B, n_pages) int32
    pos: jnp.ndarray,      # (B,) int32
    *,
    page: int,
    sc: int,
) -> jnp.ndarray:
    """XLA form of the fused operator (the non-TPU dispatch target).

    Algorithmically matches the kernel: committed-slot mask, scores taken
    in grouped (kv-head) form so the GQA expansion is never materialized,
    and uncommitted slots pinned to slot 0 so the single gather is the only
    cache-sized intermediate.
    """
    bsz, _, hq, d = q.shape
    hkv = k_cache.shape[1]
    g = hq // hkv
    n_slots = k_cache.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (bsz,))

    n_valid = jnp.minimum(pos + 1, sc)[:, None]               # (B, 1)
    valid = jnp.arange(sc, dtype=jnp.int32)[None, :] < n_valid  # (B, Sc)
    phys = phys_slots(tables, sc, page)
    phys = jnp.where(valid, jnp.minimum(phys, n_slots - 1), 0)

    ke = k_cache[phys]                                        # (B, Sc, Hkv, D)
    ve = v_cache[phys]
    qf = q.astype(jnp.float32)[:, 0].reshape(bsz, hkv, g, d) * (d ** -0.5)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, ke.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, ve.astype(jnp.float32))
    return o.reshape(bsz, hq, d)[:, None].astype(q.dtype)
