"""EngineRouter example: a replica fleet behind the one EngineClient API.

Shows the three things the router adds on top of `serve_engine.py`:

1. **placement** — each submitted request is routed to one of N engine
   replicas by bucket affinity (join an in-flight same-bucket group,
   else an idle replica, else the replica whose plan cache already holds
   the bucket); every decision is recorded with its reason;
2. **one client surface** — the same `submit` / `stream` / `drain`
   consumption code runs unchanged against a bare engine
   (`EngineConfig(replicas=1).build_client(...)`) or a fleet;
3. **drain / failover** — a replica leaves mid-decode and its in-flight
   requests finish on the survivors, token streams intact.

    PYTHONPATH=src python examples/serve_router.py --arch yi-6b-smoke
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.configs import get_config
from repro.runtime.engine_config import EngineConfig
from repro.runtime.serve_loop import ServeRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b-smoke")
    ap.add_argument("--replicas", type=int, default=2)
    args = ap.parse_args()

    cfg = EngineConfig(replicas=args.replicas)
    router = cfg.build_client(get_config(args.arch))

    # --- 1. placement: a burst of mixed-shape requests spreads across the
    # fleet; same-bucket requests land where they can coalesce
    handles = [router.submit(ServeRequest(1, 40 + 8 * (i % 3), 8))
               for i in range(6)]
    for d in router.decisions:
        print(f"rid={d.rid} -> replica[{d.replica}] ({d.reason})")

    # --- 2. the EngineClient surface: stream a few of one request's
    # tokens while the rest of the fleet keeps decoding underneath
    # (the consumption code is identical against a bare engine)
    print("rid", handles[0].rid, "streams:", end=" ")
    for ev in handles[0].stream():
        if ev.token is not None:
            print(int(ev.token[0, 0]), end=" ", flush=True)
            if ev.index >= 3:
                print("...")
                break

    # --- 3. drain / failover: take replica 1 out while it still holds
    # live mid-decode work — everything finishes on the survivors
    live_on_1 = [h.rid for h in router.handles.values()
                 if h.replica is not None and h.replica.idx == 1
                 and not h.done]
    moved = router.drain_replica(1)
    print(f"drained replica 1 (live: {live_on_1}); "
          f"resubmitted {[h.rid for h in moved]} to survivors")
    router.drain()
    done = sorted(h.rid for h in handles if h.done)
    print(f"all {len(done)} requests completed: {done}")

    print(router.summary())


if __name__ == "__main__":
    main()
