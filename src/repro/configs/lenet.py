"""LeNet — one of the paper's own demo models ("we support a variety of deep
learning models in SystemML such as LeNet, feedforward nets, ...").

Defined as a declarative layer spec consumed by ``repro.frontend.Keras2Plan``
and by the ``repro.nn`` manual-backward library, exactly as the paper's
Keras2DML path generates a DML script using the NN library.
"""


def make_spec(input_shape=(1, 28, 28), num_classes=10):
    """Returns the layer spec list for the frontend (Keras2DML analogue)."""
    c, h, w = input_shape
    return [
        {"kind": "conv2d", "filters": 32, "kernel": 5, "pad": 2, "stride": 1},
        {"kind": "relu"},
        {"kind": "max_pool2d", "pool": 2, "stride": 2},
        {"kind": "conv2d", "filters": 64, "kernel": 5, "pad": 2, "stride": 1},
        {"kind": "relu"},
        {"kind": "max_pool2d", "pool": 2, "stride": 2},
        {"kind": "affine", "units": 512},
        {"kind": "relu"},
        {"kind": "dropout", "p": 0.5},
        {"kind": "affine", "units": num_classes},
        {"kind": "softmax"},
    ], {"input_shape": (c, h, w), "num_classes": num_classes}
