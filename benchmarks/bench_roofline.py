"""Roofline table (deliverable g): read the dry-run records and emit the
three-term roofline per (arch x shape) on the single-pod mesh."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(pattern="*_1pod.json"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run():
    rows = []
    for r in load_records():
        if not r.get("ok"):
            rows.append(f"roofline_{r['arch']}_{r['shape']},0,ERROR")
            continue
        rf = r["roofline"]
        step_us = rf["step_time_lower_bound_s"] * 1e6
        rows.append(
            f"roofline_{r['arch']}_{r['shape']},{step_us:.0f},"
            f"dominant={rf['dominant']};"
            f"compute_ms={rf['compute_s'] * 1e3:.2f};"
            f"memory_ms={rf['memory_s'] * 1e3:.2f};"
            f"collective_ms={rf['collective_s'] * 1e3:.2f};"
            f"useful_flops={rf['useful_flops_ratio']:.2f};"
            f"peak_gib={r['memory']['peak_estimate_bytes'] / 2**30:.1f}"
        )
    return rows


def markdown_table(pattern="*_1pod.json"):
    """Render the §Roofline table for EXPERIMENTS.md."""
    lines = [
        "| arch | shape | strategy | compute s | memory s | collective s |"
        " dominant | useful FLOPs | peak GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records(pattern):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | | |")
            continue
        rf, mem = r["roofline"], r["memory"]
        peak = mem["peak_estimate_bytes"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['strategy']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | {rf['dominant']} "
            f"| {rf['useful_flops_ratio'] * 100:.0f}% "
            f"| {peak / 2**30:.1f} | {'Y' if peak <= mem['hbm_budget'] else 'N'} |"
        )
    return "\n".join(lines)
