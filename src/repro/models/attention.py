"""Attention forward paths for the big models.

Three physical operators for one logical op — the SystemML operator-
selection idea applied to attention:

* ``einsum``  — small sequences (smoke tests; cheapest to trace/compile)
* ``blocked`` — lax.scan over KV chunks with online softmax (flash
  semantics expressed in XLA; keeps peak HBM flat for the 32k dry-runs)
* Pallas flash kernel — on real TPU via ``repro.kernels.ops`` dispatch

plus the decode path (one query against a — possibly rotating — cache).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops as kops

BLOCKED_THRESHOLD = 4096  # beyond this seq, use the blocked operator
KV_CHUNK = 1024


def attention(
    q: jnp.ndarray,     # (B, Sq, H, D)
    k: jnp.ndarray,     # (B, Sk, H, D) — GQA k/v pre-expanded to H (the
    v: jnp.ndarray,     #   repeat is sharded away under tensor parallelism)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,  # absolute position of q[0] relative to k[0]
) -> jnp.ndarray:
    b, sq, hq, d = q.shape
    sk = k.shape[1]
    if jax.default_backend() == "tpu":
        out = kops.attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window,
            q_offset=q_offset,
        )
        return out.transpose(0, 2, 1, 3)
    big = max(sq, sk) >= BLOCKED_THRESHOLD
    # windowed attention beyond its window always prefers the blocked
    # operator: the einsum operator would materialize the full S^2 scores
    if window and max(sq, sk) > window:
        big = True
    if big and sq > 1:
        return _blocked(q, k, v, causal, window, q_offset)
    return _einsum(q, k, v, causal=causal, window=window, q_offset=q_offset)


def _mask(sq, sk, q_offset, causal, window):
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m


def _einsum(q, k, v, *, causal, window, q_offset):
    b, sq, h, d = q.shape
    sk = k.shape[1]
    qf = q.astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, k.astype(jnp.float32))
    m = _mask(sq, sk, q_offset, causal, window)
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _blocked(q, k, v, causal, window, q_offset):
    """Online-softmax over KV chunks with a flash-style custom VJP: the
    backward pass *recomputes* per-chunk scores from (q, k, v, out, lse)
    instead of letting autodiff stack every chunk's probabilities — this is
    what keeps the S^2 term out of HBM for the training shapes."""
    out, _ = _blocked_fwd_impl(q, k, v, causal, window, q_offset)
    return out


def _blocked_fwd(q, k, v, causal, window, q_offset):
    out, lse = _blocked_fwd_impl(q, k, v, causal, window, q_offset)
    return out, (q, k, v, out, lse)


def _blocked_bwd(causal, window, q_offset, res, dout):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    sk = k.shape[1]
    chunk = min(KV_CHUNK, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = d ** -0.5
    qf = q.astype(jnp.float32)
    doutf = dout.astype(jnp.float32)
    outf = out.astype(jnp.float32)
    delta = jnp.sum(doutf * outf, axis=-1)                  # (b, sq, h)
    qpos = q_offset + jnp.arange(sq)
    kc = k.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)

    def step(dq, inp):
        ci, kb, vb = inp
        kbf, vbf = kb.astype(jnp.float32), vb.astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bqhk", qf, kbf) * scale
        kpos = ci * chunk + jnp.arange(chunk)
        msk = kpos[None, :] < sk
        if causal:
            msk = msk & (kpos[None, :] <= qpos[:, None])
        if window:
            msk = msk & (kpos[None, :] > (qpos[:, None] - window))
        p = jnp.exp(s - lse[..., None])
        p = jnp.where(msk[None, :, None, :], p, 0.0)        # (b,sq,h,ck)
        dv = jnp.einsum("bqhk,bqhd->bkhd", p, doutf)
        dp = jnp.einsum("bqhd,bkhd->bqhk", doutf, vbf)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bqhk,bkhd->bqhd", ds, kbf)
        dk = jnp.einsum("bqhk,bqhd->bkhd", ds, qf)
        return dq, (dk, dv)

    dq0 = jnp.zeros((b, sq, h, d), jnp.float32)
    dq, (dks, dvs) = lax.scan(step, dq0, (jnp.arange(n_chunks), kc, vc))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, h, d)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, h, d)
    if pad:
        dk, dv = dk[:, :sk], dv[:, :sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_blocked.defvjp(_blocked_fwd, _blocked_bwd)


def _blocked_fwd_impl(q, k, v, causal, window, q_offset):
    """Online-softmax over KV chunks: flash semantics in pure XLA.
    Returns (out, lse)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    chunk = min(KV_CHUNK, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qf = q.astype(jnp.float32) * (d ** -0.5)
    qpos = q_offset + jnp.arange(sq)

    kc = k.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        ci, kb, vb = inp
        s = jnp.einsum("bqhd,bkhd->bqhk", qf, kb.astype(jnp.float32))
        kpos = ci * chunk + jnp.arange(chunk)
        msk = jnp.ones((sq, chunk), bool)
        msk &= kpos[None, :] < sk  # padding
        if causal:
            msk &= kpos[None, :] <= qpos[:, None]
        if window:
            msk &= kpos[None, :] > (qpos[:, None] - window)
        s = jnp.where(msk[None, :, None, :], s, -1e30)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(msk[None, :, None, :], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = alpha[..., None] * acc + jnp.einsum(
            "bqhk,bkhd->bqhd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, sq, h), -1e30, jnp.float32)
    l0 = jnp.zeros((b, sq, h), jnp.float32)
    a0 = jnp.zeros((b, sq, h, d), jnp.float32)
    (m_f, l_f, acc), _ = lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    l_safe = jnp.where(l_f == 0, 1.0, l_f)
    out = (acc / l_safe[..., None]).astype(q.dtype)
    # log-sum-exp of the *scaled* scores, for the recompute-backward
    lse = jnp.where(l_f == 0, -1e30, m_f + jnp.log(l_safe))
    return out, lse


# ---------------------------------------------------------------------------
# decode: one query against a (possibly rotating) cache
# ---------------------------------------------------------------------------


def decode_attention(
    q: jnp.ndarray,        # (B, 1, Hq, D)
    k_cache: jnp.ndarray,  # (B, Sc, Hkv, D)
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,      # int32: absolute position of the new token —
    *,                     #   scalar (whole batch) or (B,) per-row vector
    window: int = 0,       # rotating cache iff window > 0 (Sc == window)
) -> jnp.ndarray:
    b, _, h, d = q.shape
    sc = k_cache.shape[1]
    qf = (q.astype(jnp.float32) * (d ** -0.5))[:, 0]
    s = jnp.einsum("bhd,bkhd->bhk", qf, k_cache.astype(jnp.float32))
    slots = jnp.arange(sc)[None, :]          # (1, Sc)
    pb = jnp.reshape(pos, (-1, 1))           # (B, 1) or (1, 1) — broadcasts
    if window:
        # rotating cache: slot i holds absolute position
        # p_i = pos - ((pos - i) mod Sc); valid iff 0 <= p_i <= pos
        p_i = pb - jnp.mod(pb - slots, sc)
        valid = (p_i >= 0) & (p_i <= pb)
    else:
        valid = slots <= pb
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhk,bkhd->bhd", p, v_cache.astype(jnp.float32))
    return o[:, None].astype(q.dtype)


def paged_slots(tables: jnp.ndarray, lslots: jnp.ndarray,
                page: int) -> jnp.ndarray:
    """Physical slot per logical slot through a page table:
    ``table[lslot // page] * page + lslot % page``.

    ``tables``: (B, max_pages) int32; unallocated entries hold the sentinel
    ``n_pages``, mapping to out-of-range physical slots (gathers through
    them are masked by the position validity mask, scatters drop).
    ``lslots``: (B,) or (B, S) logical slots. Returns same-shape physical
    slot indices into the arena's flat ``n_pages * page`` slot stack."""
    lp = jnp.clip(lslots // page, 0, tables.shape[1] - 1)
    entry = jnp.take_along_axis(
        tables, lp if lp.ndim > 1 else lp[:, None], axis=1)
    if lp.ndim == 1:
        entry = entry[:, 0]
    return entry * page + jnp.mod(lslots, page)


def paged_gather_kv(
    k_cache: jnp.ndarray,  # (n_slots, Hkv, D) — flat per-arena slot stack
    v_cache: jnp.ndarray,
    tables: jnp.ndarray,   # (B, max_pages) int32 page table per row
    page: int,
    sc: int,               # logical cache slots per row
    pos: Optional[jnp.ndarray] = None,  # per-row decode position
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gather each row's logical cache view ``(B, sc, Hkv, D)`` out of the
    shared slot stack.

    With ``pos``, slots beyond each row's committed extent
    (``min(pos + 1, sc)`` — identical for dense and rotating rows, see
    kernels/paged_attention.py) are masked: their gather index is pinned to
    slot 0 and the gathered values zeroed, so uncommitted bucket slots are
    neither wandered through (sentinel table entries point at clamped
    arbitrary arena slots) nor carried as garbage into the attention op.
    The decode validity mask downstream already hides their scores; the
    masking here makes the memory access pattern and the gathered values
    deterministic. Without ``pos`` (legacy callers) slots on unallocated
    pages read clamped garbage, still hidden by the validity mask."""
    b = tables.shape[0]
    i = jnp.arange(sc, dtype=jnp.int32)
    phys = paged_slots(tables, jnp.broadcast_to(i, (b, sc)), page)
    phys = jnp.minimum(phys, k_cache.shape[0] - 1)
    if pos is not None:
        posb = jnp.broadcast_to(
            jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)), (b,))
        committed = i[None, :] < jnp.minimum(posb + 1, sc)[:, None]  # (B, sc)
        phys = jnp.where(committed, phys, 0)
        ke, ve = k_cache[phys], v_cache[phys]
        keep = committed[..., None, None]
        return jnp.where(keep, ke, 0), jnp.where(keep, ve, 0)
    return k_cache[phys], v_cache[phys]


def paged_cache_write(
    k_cache: jnp.ndarray, v_cache: jnp.ndarray,  # (n_slots, Hkv, D)
    k_new: jnp.ndarray, v_new: jnp.ndarray,      # (B, 1, Hkv, D)
    pos: jnp.ndarray, tables: jnp.ndarray, page: int, sc: int,
    *, window: int = 0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter each row's new K/V into its page-mapped physical slot.
    Rotating caches (window > 0) wrap within the row's own pages
    (``pos mod sc``); non-rotating writes beyond capacity — and writes from
    rows whose page table is unallocated (free rows) — are dropped."""
    b = k_new.shape[0]
    n_slots = k_cache.shape[0]
    posb = jnp.broadcast_to(
        jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)), (b,))
    lslot = jnp.mod(posb, sc) if window else posb
    phys = paged_slots(tables, lslot, page)
    if not window:
        phys = jnp.where(posb < sc, phys, n_slots)  # out of capacity: drop
    k_cache = k_cache.at[phys].set(k_new[:, 0].astype(k_cache.dtype),
                                   mode="drop")
    v_cache = v_cache.at[phys].set(v_new[:, 0].astype(v_cache.dtype),
                                   mode="drop")
    return k_cache, v_cache


def cache_write(
    k_cache: jnp.ndarray, v_cache: jnp.ndarray,
    k_new: jnp.ndarray, v_new: jnp.ndarray,  # (B, 1, Hkv, D)
    pos: jnp.ndarray, *, window: int = 0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``pos`` scalar: one shared write slot (dynamic-update-slice). ``pos``
    (B,) vector: rows at different generation depths write their own slots
    (scatter; out-of-capacity rows drop their write — their decode mask
    never exposes those slots either)."""
    sc = k_cache.shape[1]
    if pos.ndim:
        slot = jnp.mod(pos, sc) if window else pos
        rows = jnp.arange(k_cache.shape[0])
        k_cache = k_cache.at[rows, slot].set(
            k_new[:, 0].astype(k_cache.dtype), mode="drop")
        v_cache = v_cache.at[rows, slot].set(
            v_new[:, 0].astype(v_cache.dtype), mode="drop")
        return k_cache, v_cache
    slot = jnp.mod(pos, sc) if window else pos
    k_cache = lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    return k_cache, v_cache
