"""Sequential composer over the manual-backward layer library.

This is the runtime for the scripts that ``repro.frontend.Keras2Plan``
generates — the structural analogue of the DML training script in the
paper's §2 (forward chain, backward chain in reverse, optimizer update),
with zero reliance on jax autodiff.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.linearize import conv2d_out_hw
from repro.nn import layers as L
from repro.nn import loss as LOSS
from repro.nn.optim import get_optimizer


@dataclass
class LayerInstance:
    kind: str
    attrs: Dict[str, Any] = field(default_factory=dict)
    n_params: int = 0


class Sequential:
    """Build from a spec list (see repro/configs/lenet.py)."""

    def __init__(self, spec: List[dict], meta: Dict[str, Any]):
        self.spec = spec
        self.meta = meta
        self.layers: List[LayerInstance] = []
        self._infer_shapes()

    # -- shape inference over the linearized pipeline ----------------------
    def _infer_shapes(self):
        shape = self.meta["input_shape"]  # (C,H,W) or (D,)
        for s in self.spec:
            kind = s["kind"]
            li = LayerInstance(kind, dict(s))
            if kind == "conv2d":
                c, h, w = shape
                k, st, pd = s["kernel"], s.get("stride", 1), s.get("pad", 0)
                ho, wo = conv2d_out_hw(h, w, k, st, pd)
                li.attrs.update(c=c, h=h, w=w)
                li.n_params = 2
                shape = (s["filters"], ho, wo)
            elif kind in ("max_pool2d", "avg_pool2d"):
                c, h, w = shape
                p = s["pool"]
                li.attrs.update(c=c, h=h, w=w)
                shape = (c, h // p, w // p)
            elif kind == "affine":
                d = int(math.prod(shape))
                li.attrs.update(d=d)
                li.n_params = 2
                shape = (s["units"],)
            elif kind in ("batch_norm1d",):
                li.attrs.update(d=int(math.prod(shape)))
                li.n_params = 2  # gamma, beta (+non-trainable running stats)
            elif kind == "batch_norm2d":
                c, h, w = shape
                li.attrs.update(c=c, h=h, w=w)
                li.n_params = 2
            elif kind in ("relu", "leaky_relu", "elu", "sigmoid", "tanh",
                          "gelu", "softmax", "log_softmax", "dropout"):
                pass
            else:
                raise ValueError(f"unsupported layer kind {kind!r}")
            li.attrs["out_shape"] = shape
            self.layers.append(li)
        self.out_shape = shape

    # -- init ---------------------------------------------------------------
    def init(self, key) -> List[Tuple]:
        params: List[Tuple] = []
        extras: List[Tuple] = []  # running stats etc.
        for li in self.layers:
            key, sub = jax.random.split(key)
            if li.kind == "conv2d":
                w, b = L.conv2d.init(li.attrs["c"], li.attrs["filters"],
                                     li.attrs["kernel"], sub)
                params.append((w, b))
                extras.append(())
            elif li.kind == "affine":
                w, b = L.affine.init(li.attrs["d"], li.attrs["units"], sub)
                params.append((w, b))
                extras.append(())
            elif li.kind == "batch_norm1d":
                g, bt, rm, rv = L.batch_norm1d.init(li.attrs["d"])
                params.append((g, bt))
                extras.append((rm, rv))
            elif li.kind == "batch_norm2d":
                g, bt, rm, rv = L.batch_norm2d.init(li.attrs["c"])
                params.append((g, bt))
                extras.append((rm, rv))
            else:
                params.append(())
                extras.append(())
        self.extras = extras
        return params

    # -- forward (returns caches for manual backward) -----------------------
    def forward(self, params, x, *, mode: str = "train", key=None):
        caches = []
        for li, p in zip(self.layers, params):
            a = li.attrs
            if li.kind == "conv2d":
                out, cols = L.conv2d.forward(x, p[0], p[1], a["c"], a["h"], a["w"],
                                             a["kernel"], a.get("stride", 1), a.get("pad", 0))
                caches.append(("conv2d", x, cols))
                x = out
            elif li.kind == "affine":
                out = L.affine.forward(x, p[0], p[1])
                caches.append(("affine", x))
                x = out
            elif li.kind == "max_pool2d":
                out, _ = L.max_pool2d.forward(x, a["c"], a["h"], a["w"], a["pool"])
                caches.append(("max_pool2d", x))
                x = out
            elif li.kind == "avg_pool2d":
                out, _ = L.avg_pool2d.forward(x, a["c"], a["h"], a["w"], a["pool"])
                caches.append(("avg_pool2d", x))
                x = out
            elif li.kind == "dropout":
                if mode == "train":
                    key, sub = jax.random.split(key)
                    out, mask = L.dropout.forward(x, a["p"], sub)
                else:
                    out, mask = x, jnp.ones_like(x)
                caches.append(("dropout", mask))
                x = out
            elif li.kind in ("relu", "leaky_relu", "elu", "sigmoid", "tanh",
                             "gelu", "softmax", "log_softmax"):
                cls = getattr(L, li.kind)
                out = cls.forward(x)
                caches.append((li.kind, x))
                x = out
            elif li.kind == "batch_norm1d":
                out, cache, _, _ = L.batch_norm1d.forward(
                    x, p[0], p[1], mode, *self.extras[len(caches)])
                caches.append(("batch_norm1d", x, cache))
                x = out
            elif li.kind == "batch_norm2d":
                out, cache, _, _ = L.batch_norm2d.forward(
                    x, p[0], p[1], a["c"], a["h"], a["w"], mode,
                    *self.extras[len(caches)])
                caches.append(("batch_norm2d", x, cache))
                x = out
        return x, caches

    # -- backward (reverse chain, hand-written grads) ------------------------
    def backward(self, params, caches, dout):
        grads: List[Tuple] = [None] * len(self.layers)
        for i in reversed(range(len(self.layers))):
            li, p, cache = self.layers[i], params[i], caches[i]
            a = li.attrs
            if li.kind == "conv2d":
                _, x, cols = cache
                dout, dw, db = L.conv2d.backward(dout, cols, x, p[0], a["c"], a["h"],
                                                 a["w"], a["kernel"],
                                                 a.get("stride", 1), a.get("pad", 0))
                grads[i] = (dw, db)
            elif li.kind == "affine":
                _, x = cache
                dout, dw, db = L.affine.backward(dout, x, p[0], p[1])
                grads[i] = (dw, db)
            elif li.kind == "max_pool2d":
                _, x = cache
                dout = L.max_pool2d.backward(dout, None, x, a["c"], a["h"], a["w"], a["pool"])
                grads[i] = ()
            elif li.kind == "avg_pool2d":
                _, x = cache
                dout = L.avg_pool2d.backward(dout, None, x, a["c"], a["h"], a["w"], a["pool"])
                grads[i] = ()
            elif li.kind == "dropout":
                _, mask = cache
                dout = L.dropout.backward(dout, mask)
                grads[i] = ()
            elif li.kind in ("relu", "leaky_relu", "elu", "sigmoid", "tanh",
                             "gelu", "softmax", "log_softmax"):
                _, x = cache
                dout = getattr(L, li.kind).backward(dout, x)
                grads[i] = ()
            elif li.kind == "batch_norm1d":
                _, x, c = cache
                dout, dg, db = L.batch_norm1d.backward(dout, c, x, p[0])
                grads[i] = (dg, db)
            elif li.kind == "batch_norm2d":
                _, x, c = cache
                dout, dg, db = L.batch_norm2d.backward(dout, c, x, p[0], a["c"], a["h"], a["w"])
                grads[i] = (dg, db)
        return dout, grads

    # -- the paper's §2 training loop -----------------------------------------
    def make_train_step(self, optimizer: str = "sgd", lr: float = 0.01,
                        loss: str = "cross_entropy"):
        opt = get_optimizer(optimizer)

        def train_step(params, opt_state, x, y, key, t=1):
            probs, caches = self.forward(params, x, mode="train", key=key)
            if loss == "cross_entropy":
                loss_val = LOSS.cross_entropy_loss.forward(probs, y)
                dprobs = LOSS.cross_entropy_loss.backward(probs, y)
            elif loss == "l2":
                loss_val = LOSS.l2_loss.forward(probs, y)
                dprobs = LOSS.l2_loss.backward(probs, y)
            else:
                raise ValueError(loss)
            _, grads = self.backward(params, caches, dprobs)
            new_params, new_state = [], []
            for p, g, s in zip(params, grads, opt_state):
                if not p:
                    new_params.append(p)
                    new_state.append(s)
                    continue
                ps, ss = [], []
                for pj, gj, sj in zip(p, g, s):
                    pn, sn = opt.update(pj, gj, sj, lr=lr, t=t)
                    ps.append(pn)
                    ss.append(sn)
                new_params.append(tuple(ps))
                new_state.append(tuple(ss))
            return new_params, new_state, loss_val

        return train_step

    def init_opt_state(self, optimizer: str, params):
        opt = get_optimizer(optimizer)
        return [tuple(opt.init(pj) for pj in p) if p else () for p in params]

    def predict(self, params, x):
        out, _ = self.forward(params, x, mode="test")
        return out
