"""Step metrics / throughput accounting + serving-path counters.

The plan-cache counters (:class:`PlanCacheMetrics`) live next to the cache
in ``repro.core.plan_cache``; they are re-exported here so the runtime layer
has one metrics surface, and :func:`serve_summary` renders them together
with per-request latency."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.config import HardwareSpec, InputShape, MeshConfig, ModelConfig, TPU_V5E
from repro.core.cost import model_flops_per_step
from repro.core.plan_cache import PlanCacheMetrics  # noqa: F401  (re-export)


@dataclass
class StepTimer:
    model: Optional[ModelConfig] = None
    shape: Optional[InputShape] = None
    mesh: Optional[MeshConfig] = None
    hw: HardwareSpec = TPU_V5E
    history: List[Dict] = field(default_factory=list)
    _t0: float = 0.0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int, metrics: Dict) -> Dict:
        dt = time.perf_counter() - self._t0
        rec = {"step": step, "seconds": dt}
        rec.update({k: float(v) for k, v in metrics.items()})
        if self.model is not None and self.shape is not None:
            flops = model_flops_per_step(self.model, self.shape)
            rec["tokens_per_s"] = self.shape.global_batch * self.shape.seq_len / dt
            if self.mesh is not None:
                rec["mfu"] = flops / dt / (self.mesh.num_devices * self.hw.peak_flops)
        self.history.append(rec)
        return rec

    def summary(self) -> Dict:
        if not self.history:
            return {}
        n = len(self.history)
        keys = self.history[-1].keys()
        return {k: sum(h.get(k, 0.0) for h in self.history) / n
                for k in keys if k != "step"}


@dataclass
class LatencyStats:
    """Per-request latency accumulator for the serving stream."""

    samples: List[float] = field(default_factory=list)

    def record(self, seconds: float) -> None:
        self.samples.append(float(seconds))

    @property
    def count(self) -> int:
        return len(self.samples)

    def mean(self) -> float:
        return sum(self.samples) / len(self.samples) if self.samples else 0.0

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        idx = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
        return xs[idx]

    def summary(self) -> str:
        ms = 1e3
        return (f"requests={self.count} mean={self.mean() * ms:.2f}ms "
                f"p50={self.percentile(50) * ms:.2f}ms "
                f"p95={self.percentile(95) * ms:.2f}ms")


def serve_summary(cache: PlanCacheMetrics, latency: LatencyStats) -> str:
    """One-line serving report: plan-cache counters + request latency."""
    return (f"plan_cache: hits={cache.hits} misses={cache.misses} "
            f"evictions={cache.evictions} compiles={cache.compiles} "
            f"recompiles={cache.recompiles} hit_rate={cache.hit_rate:.2f} "
            f"compile_s={cache.compile_seconds:.2f}  |  {latency.summary()}")


def format_metrics(rec: Dict) -> str:
    parts = []
    for k, v in rec.items():
        if isinstance(v, float):
            parts.append(f"{k}={v:.4g}")
        else:
            parts.append(f"{k}={v}")
    return "  ".join(parts)
