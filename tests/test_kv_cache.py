"""Row-addressable KV-cache pool (PR 3): arena/row lifecycle, the
prefill→decode handoff (prompt-conditioning equivalence per family),
span-covering request buckets at power-of-two context boundaries,
mid-decode group joins, and the pool-breach recompilation predicate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import InputShape
from repro.configs import get_config
from repro.core.plan_cache import BucketPolicy, recompile_reasons
from repro.core.strategies import RuntimeStats
from repro.models.model import build_model
from repro.runtime.kv_cache import KVCachePool
from repro.runtime.scheduler import (ContinuousBatchingScheduler,
                                     RequestQueue, simulate_arrivals)
from repro.runtime.serve_loop import PlanServer, ServeRequest

KEY = jax.random.PRNGKey(0)
CFG = get_config("yi-6b-smoke")


# ---------------------------------------------------------------------------
# pool: arena + row lifecycle
# ---------------------------------------------------------------------------


def _pool(model=None, **kw):
    model = model or build_model(CFG, dtype=jnp.float32)
    return KVCachePool(model, **kw)


def test_pool_arena_bytes_match_materialized_cache():
    model = build_model(CFG, dtype=jnp.float32)
    pool = _pool(model)
    kv = model.init_cache(4, 128)
    assert pool.arena_bytes(4, 128) == sum(v.nbytes for v in kv.values())


def test_pool_lease_reuse_and_row_accounting():
    pool = _pool()
    a = pool.acquire(4, 64)
    assert (a.batch, a.seq) == (4, 64) and a.rows_free == 4
    rows = pool.alloc_rows(a, 3)
    assert rows == [0, 1, 2] and a.rows_used == 3
    assert pool.occupancy() == pytest.approx(0.75)
    pool.free_rows(a, rows[:1])
    assert a.rows_free == 2
    pool.release(a)
    assert pool.live_bytes() == 0 and pool.total_bytes() > 0
    # same-bucket lease recycles the arena; its rows count as reused
    b = pool.acquire(4, 64)
    assert b is a and pool.metrics.arenas_reused == 1
    pool.alloc_rows(b, 2)
    assert pool.metrics.rows_reused == 2


def test_pool_double_free_rejected():
    pool = _pool()
    a = pool.acquire(2, 64)
    rows = pool.alloc_rows(a, 1)
    pool.free_rows(a, rows)
    with pytest.raises(ValueError):
        pool.free_rows(a, rows)


def test_pool_budget_denies_then_force_overrides():
    pool = _pool(max_arenas=1)
    a = pool.acquire(2, 64)
    assert pool.acquire(2, 128) is None
    assert pool.metrics.arenas_denied == 1
    forced = pool.acquire(2, 128, force=True)
    assert forced is not None
    pool.release(a)
    pool.release(forced)
    # a pooled free arena of the right bucket is always acquirable
    assert pool.can_acquire(2, 64)


def test_pool_free_arenas_lru_evicted():
    """Retired shape buckets cannot pin HBM forever: the free pool is
    LRU-capped, oldest release evicted first."""
    pool = _pool(max_free=2)
    arenas = [pool.acquire(1, s) for s in (16, 32, 64)]
    for a in arenas:
        pool.release(a)
    assert pool.metrics.arenas_evicted == 1
    assert pool.arena_count == 2
    assert not any((a.batch, a.seq) == (1, 16) for a in pool._pooled)


def test_pool_budget_evicts_idle_free_arenas_before_denying():
    """An idle free arena of another bucket never blocks a lease the
    budget could otherwise serve — it is evicted instead."""
    pool = _pool(max_arenas=2)
    a = pool.acquire(1, 16)
    pool.release(a)                  # one idle free arena
    pool.acquire(1, 32)              # leased; arena count at the cap
    c = pool.acquire(1, 64)          # evicts the idle (1,16) to make room
    assert c is not None
    assert pool.metrics.arenas_evicted == 1 and pool.metrics.arenas_denied == 0


def test_pool_zeroing_on_reuse():
    pool = _pool()
    a = pool.acquire(2, 64)
    k = next(iter(a.cache))
    a.cache[k] = a.cache[k] + 1.0
    pool.release(a)
    b = pool.acquire(2, 64, zero=True)
    assert float(jnp.max(jnp.abs(b.cache[k]))) == 0.0


def test_pool_write_rows_scatters_per_row():
    model = build_model(CFG, dtype=jnp.float32)
    pool = _pool(model)
    a = pool.acquire(4, 64)
    src = {k: jnp.full_like(v, 7.0) for k, v in model.init_cache(4, 64).items()}
    pool.write_rows(a, [1, 3], src, src_rows=[0, 1])
    for v in a.cache.values():
        got = np.asarray(jnp.abs(v).max(axis=tuple(
            i for i in range(v.ndim) if i != 1)))
        np.testing.assert_array_equal(got > 0, [False, True, False, True])


# ---------------------------------------------------------------------------
# prefill→decode handoff: prompt-conditioning equivalence per family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-1.3b", "recurrentgemma-2b"])
def test_handoff_decode_matches_full_forward(arch):
    """Decode over a prefill-populated cache — per-row prompt lengths, rows
    at different depths in one batch — must match the full-sequence forward
    at every generated position (attention, SSD, hybrid)."""
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(KEY)
    B, S = 2, 16
    lengths = jnp.array([12, 9], jnp.int32)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits, cache = model.prefill(params, toks, lengths=lengths, cache_len=32)
    # prefill logits == full forward at each row's last prompt position
    seqs = []
    for r in range(B):
        T = int(lengths[r])
        full, _ = model.apply(params, toks[r:r + 1, :T])
        np.testing.assert_allclose(np.asarray(logits[r]),
                                   np.asarray(full[0, T - 1]),
                                   rtol=5e-3, atol=5e-3)
        seqs.append(list(np.asarray(toks[r, :T])))
    # cache pytree is exactly the init_cache layout
    ref = model.init_cache(B, 32)
    assert {k: (v.shape, v.dtype) for k, v in cache.items()} \
        == {k: (v.shape, v.dtype) for k, v in ref.items()}
    # greedy decode from the handoff, per-row positions
    pos = lengths
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    for r in range(B):
        seqs[r].append(int(tok[r, 0]))
    for step in range(3):
        lg, cache = model.decode_step(params, cache, tok, pos)
        for r in range(B):
            full, _ = model.apply(params, jnp.asarray([seqs[r]]))
            np.testing.assert_allclose(
                np.asarray(lg[r, 0]), np.asarray(full[0, -1]),
                rtol=5e-3, atol=5e-3,
                err_msg=f"{arch} row {r} decode step {step}")
        tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
        pos = pos + 1
        for r in range(B):
            seqs[r].append(int(tok[r, 0]))


def test_handoff_rotating_window_prompt_longer_than_window():
    """Hybrid prompts longer than the attention window land in rotated
    cache slots that decode's rotating mask reads back correctly. The
    reduced config's pattern is all-RG-LRU, so force one real windowed
    attention layer into the stack."""
    cfg = get_config("recurrentgemma-2b-smoke").replace(  # window_size=32
        block_pattern="ra")
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(1))
    lengths = jnp.array([45, 38], jnp.int32)
    toks = jax.random.randint(KEY, (2, 48), 0, cfg.vocab_size)
    logits, cache = model.prefill(params, toks, lengths=lengths, cache_len=64)
    lg, _ = model.decode_step(
        params, cache, jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32),
        lengths)
    for r in range(2):
        T = int(lengths[r])
        seq = list(np.asarray(toks[r, :T])) + [int(jnp.argmax(logits[r]))]
        full, _ = model.apply(params, jnp.asarray([seq]))
        np.testing.assert_allclose(np.asarray(lg[r, 0]),
                                   np.asarray(full[0, -1]),
                                   rtol=5e-3, atol=5e-3)


def test_plan_server_handoff_first_token_not_recomputed():
    """Satellite fix: the prefill-produced greedy token opens the output,
    decode consumes it at the prompt's position, and the whole output
    equals the greedy chain of the full-sequence forward — i.e. generated
    text actually conditions on the prompt."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16, prefill=True)
    req = ServeRequest(1, 20, 4)
    out = srv.handle(req)
    # reference greedy chain from full forwards (prompt = the same all-ones
    # bucket tokens the server prefills with)
    seq = [1] * req.context
    expect = []
    for _ in range(req.new_tokens):
        logits, _ = srv.model.apply(srv.params, jnp.asarray([seq]))
        t = int(jnp.argmax(logits[0, -1]))
        expect.append(t)
        seq.append(t)
    assert out["tokens"].shape == (1, req.new_tokens)
    assert out["tokens"][0].tolist() == expect


def test_scheduler_group_tokens_condition_on_prompt():
    """The scheduler path hands prefill rows to decode too: every member's
    tokens equal its own full-forward greedy chain, even when coalesced
    rows sit at different prompt lengths."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    sched = ContinuousBatchingScheduler(srv, max_group_batch=8)
    reqs = [ServeRequest(1, 20, 3), ServeRequest(1, 28, 3)]  # one group
    results = sched.run(simulate_arrivals(reqs))
    assert len(results) == 2
    for rec in results:
        seq = [1] * rec["context"]
        expect = []
        for _ in range(3):
            logits, _ = srv.model.apply(srv.params, jnp.asarray([seq]))
            t = int(jnp.argmax(logits[0, -1]))
            expect.append(t)
            seq.append(t)
        assert rec["tokens"][0].tolist() == expect, rec["rid"]


# ---------------------------------------------------------------------------
# RequestQueue: span buckets at exact power-of-two context boundaries
# ---------------------------------------------------------------------------


def test_queue_buckets_cover_generation_span():
    q = RequestQueue(BucketPolicy(min_batch=1, min_seq=16))
    # context exactly on a power-of-two boundary: the span pushes it up a
    # bucket, so decode rows always have slots for every generated token
    assert q.seq_bucket(ServeRequest(1, 64, 8)) == 128
    assert q.seq_bucket(ServeRequest(1, 128, 1)) == 256
    # spans landing exactly on the boundary stay in it
    assert q.seq_bucket(ServeRequest(1, 56, 8)) == 64
    assert q.seq_bucket(ServeRequest(1, 127, 1)) == 128


def test_boundary_context_request_decodes_full_span():
    """A context sitting exactly on its bucket boundary still gets cache
    rows for every generated token (the old context-only bucketing would
    have overflowed the cache mid-decode)."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16, prefill=True)
    req = ServeRequest(1, 64, 4)
    out = srv.handle(req)
    assert out["bucket"] == (1, 128)
    assert out["tokens"].shape == (1, 4)
    assert not out["recompiled"]


def test_queue_take_joinable_filters_bucket_and_stays_fifo():
    q = RequestQueue(BucketPolicy(min_batch=1, min_seq=16))
    q.admit(ServeRequest(1, 100, 8))    # bucket 128 — fits
    q.admit(ServeRequest(1, 40, 8))     # bucket 64 — other bucket, skipped
    q.admit(ServeRequest(4, 100, 8))    # bucket 128 — too big: scan STOPS
    q.admit(ServeRequest(2, 90, 8))     # bucket 128 — behind the wide one
    taken = q.take_joinable(128, max_rows=3)
    # FIFO within the bucket: nothing behind the unfitting wide request may
    # leapfrog it (no join starvation of wide same-bucket heads)
    assert [t.req.context for t in taken] == [100]
    assert [(t.req.batch, t.req.context) for t in q.pending] \
        == [(1, 40), (4, 100), (2, 90)]


def test_wide_head_not_starved_by_joiners():
    """A wide same-bucket request at the head of the line blocks further
    joins into the arena it is waiting for, so the in-flight group drains
    and the head gets served (regression: join starvation)."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16, pool_max_arenas=1)
    sched = ContinuousBatchingScheduler(srv, max_group_batch=8,
                                        join_mid_decode=True)
    reqs = [ServeRequest(5, 100, 8),    # leases the only arena
            ServeRequest(5, 100, 4),    # wide: can't fit 3 rows
            ServeRequest(1, 90, 2),     # narrow, same bucket
            ServeRequest(1, 92, 2)]
    arrivals = [(0.001 * i, r) for i, r in enumerate(reqs)]
    results = sched.run(arrivals)
    assert len(results) == 4
    # the narrow requests did not leapfrog the wide head mid-decode: no
    # joins happened, and everyone queued behind the head rode the head's
    # own (post-drain) group instead of starting earlier
    assert sched.metrics.joins == 0
    wide = next(r for r in results if r["rid"] == reqs[1].rid)
    narrow = [r for r in results
              if r["rid"] in (reqs[2].rid, reqs[3].rid)]
    assert wide["group_size"] == 3
    assert all(n["joined_at_step"] == 0 for n in narrow)
    assert all(n["bucket"] == wide["bucket"] for n in narrow)


def test_queue_requeue_front_preserves_order():
    q = RequestQueue()
    a = q.admit(ServeRequest(1, 40))
    b = q.admit(ServeRequest(1, 44))
    group = q.next_group()
    assert [m.rid for m in group] == [a.rid, b.rid]
    q.admit(ServeRequest(1, 100))
    q.requeue_front(group)
    assert [m.req.context for m in q.pending] == [40, 44, 100]


# ---------------------------------------------------------------------------
# mid-decode joins
# ---------------------------------------------------------------------------


def test_mid_decode_join_absorbs_into_free_rows():
    """With the pool capped at one arena, requests arriving behind a long
    decode join its free rows mid-flight instead of waiting for the drain
    — and their outputs still condition on their own prompts."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16, pool_max_arenas=1)
    sched = ContinuousBatchingScheduler(srv, max_group_batch=8,
                                        join_mid_decode=True)
    head = ServeRequest(5, 100, 12)
    arrivals = [(0.0, head)] + \
               [(0.001, ServeRequest(1, 90 + 2 * i, 3)) for i in range(3)]
    results = sched.run(arrivals)
    assert len(results) == 4
    assert sched.metrics.joins == 3 and sched.metrics.join_rows == 3
    joined = [r for r in results if r["rid"] != head.rid]
    assert all(r["joined_at_step"] >= 1 for r in joined)
    assert all(r["tokens"].shape == (1, 3) for r in joined)
    # one arena served everything; the head's group never widened past it
    assert srv.pool.metrics.arenas_created == 1
    assert srv.metrics.recompiles == 0
    assert "joins=3" in sched.summary()


def test_admission_only_waits_for_arena():
    """join_mid_decode=False with a full pool: tail requests queue until
    the in-flight group drains (the A/B baseline the benchmark gates)."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16, pool_max_arenas=1)
    sched = ContinuousBatchingScheduler(srv, max_group_batch=8,
                                        join_mid_decode=False)
    head_req, tail_req = ServeRequest(5, 100, 12), ServeRequest(1, 90, 2)
    arrivals = [(0.0, head_req), (0.001, tail_req)]
    results = sched.run(arrivals)
    assert len(results) == 2
    assert sched.metrics.joins == 0
    tail = next(r for r in results if r["rid"] == tail_req.rid)
    head = next(r for r in results if r["rid"] == head_req.rid)
    # the tail could not start before the head finished
    assert tail["queue_s"] >= head["exec_s"] * 0.5
    assert srv.pool.metrics.arenas_denied > 0


# ---------------------------------------------------------------------------
# planner: pool bytes in estimates + pool-breach recompilation
# ---------------------------------------------------------------------------


def test_pool_arenas_scale_compile_time_cache_statistic():
    srv1 = PlanServer(CFG, dtype=jnp.float32, pool_arenas=1)
    srv4 = PlanServer(CFG, dtype=jnp.float32, pool_arenas=4)
    e1 = srv1.decode_entry(2, 128)
    e4 = srv4.decode_entry(2, 128)
    assert e4.plan.memory.per_device["kv_cache"] == pytest.approx(
        4 * e1.plan.memory.per_device["kv_cache"])


def test_pool_breach_triggers_recompile_and_converges():
    """A pool outgrowing the plan's cache statistic recompiles once — the
    corrected statistic covers the observation, so identical occupancy does
    not re-trigger (SystemML's converge-after-one contract)."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    srv.handle(ServeRequest(2, 100, 1))
    key = srv._key_for(2, 101, "decode")
    entry = srv.cache.get(key)
    kv_est = entry.plan.memory.per_device["kv_cache"]
    stats = RuntimeStats(shape=key.bucket_shape(),
                         cache_pool_bytes=3.0 * kv_est)
    reasons = recompile_reasons(entry.plan, stats, margin=0.25)
    assert reasons and "kv-cache pool" in reasons[0]
    refreshed, reasons = srv.observe(key, stats)
    assert reasons and srv.metrics.recompiles == 1
    assert refreshed.plan.memory.per_device["kv_cache"] >= 3.0 * kv_est
    # converged: the same pool occupancy is covered now
    _, again = srv.observe(key, stats)
    assert not again and srv.metrics.recompiles == 1


def test_observed_stats_carry_pool_bytes():
    """Paged pools report *page-exact* live bytes: an idle arena costs
    nothing, an admitted row costs its committed span pages — far below
    the arena's bucket-shaped capacity (the slack that used to over-trigger
    the recompile predicate)."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16)
    entry = srv.decode_entry(2, 200)              # seq bucket 256, 4 pages
    arena = srv.pool.acquire(entry.key.batch_bucket, entry.key.seq_bucket,
                             force=True)
    stats = srv.observed_stats(
        entry, InputShape("t", 200, 2, "decode"), jnp.ones((2, 1), jnp.int32))
    assert stats.cache_pool_bytes == 0.0          # nothing committed yet
    rows = srv.pool.alloc_rows(arena, 2)
    for r in rows:
        srv.pool.admit_row(arena, r, prompt=30, span=40)
    stats = srv.observed_stats(
        entry, InputShape("t", 200, 2, "decode"), jnp.ones((2, 1), jnp.int32))
    expect = 2 * srv.pool.member_bytes(entry.key.seq_bucket, 1, 40)
    assert stats.cache_pool_bytes == pytest.approx(expect)
    assert 0 < stats.cache_pool_bytes < arena.nbytes
    assert stats.watermark_bytes > stats.cache_pool_bytes  # + params
    srv.pool.release(arena)


def test_observed_stats_row_granular_pool_charges_arena():
    """page_size=0 keeps the PR-3 row-granular accounting: a leased arena
    charges its full bucket-shaped capacity."""
    srv = PlanServer(CFG, dtype=jnp.float32, capacity=16, page_size=0)
    entry = srv.decode_entry(2, 64)
    arena = srv.pool.acquire(entry.key.batch_bucket, entry.key.seq_bucket,
                             force=True)
    stats = srv.observed_stats(
        entry, InputShape("t", 64, 2, "decode"), jnp.ones((2, 1), jnp.int32))
    assert stats.cache_pool_bytes == pytest.approx(arena.nbytes)
    srv.pool.release(arena)
