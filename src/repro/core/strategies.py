"""Execution-plan IR.

SystemML's compiler output is a *hybrid runtime execution plan*: a choice of
single-node vs distributed operators per op, driven by memory estimates.
Our plan IR is the TPU analogue: a :class:`PlanConfig` describing how every
tensor class (batch, params, optimizer state, KV cache, experts) is laid out
on the mesh, plus bookkeeping for the chosen operator variants.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from enum import Enum
from typing import Dict, Tuple

from repro.config import InputShape, MeshConfig, ModelConfig


class Strategy(str, Enum):
    """Named points in the plan lattice (DESIGN.md §4), cheapest first.

    DATA_PARALLEL is the paper-faithful distributed plan (SystemML's
    data-parallel RDD plan: weights replicated, rows partitioned).
    Everything below it is the beyond-paper extension of the same
    memory-driven escalation idea.
    """

    LOCAL = "local"
    DATA_PARALLEL = "data_parallel"
    DP_TP = "dp_tensor_parallel"
    FSDP = "fsdp"
    FSDP_TP = "fsdp_tensor_parallel"

    @property
    def order(self) -> int:
        return list(Strategy).index(self)


@dataclass(frozen=True)
class PlanConfig:
    """Concrete layout decisions for one (model x shape x mesh) run."""

    strategy: Strategy
    # -- tensor layouts ----------------------------------------------------
    batch_axes: Tuple[str, ...] = ()          # batch dim sharded over these
    seq_axes: Tuple[str, ...] = ()            # context parallelism (prefill)
    tensor_parallel: bool = False             # heads/ffn/vocab over "model"
    params_over_data: bool = False            # FSDP: params+grads+opt over data
    expert_parallel: bool = False             # MoE expert dim over "model"
    # -- serving cache layout ---------------------------------------------
    cache_batch_axes: Tuple[str, ...] = ()
    cache_heads_over_model: bool = False
    cache_seq_axes: Tuple[str, ...] = ()      # long-context: shard cached seq
    # -- numeric / scheduling knobs (plan-chosen, SystemML-style) ----------
    opt_state_dtype: str = "float32"
    seq_shard_checkpoints: bool = False       # Megatron-style sequence
    # parallelism for remat'd residual checkpoints (over "model")
    remat: bool = True
    microbatches: int = 1                     # gradient-accumulation chunks
    attention_variant: str = "full"           # full | window | none
    # -- operator variants chosen by format dispatch -----------------------
    # Physical decode-attention operator for paged serving buckets, chosen
    # per bucket by the compiler from the analytic cost terms (SystemML's
    # operator selection by data characteristics): "paged" = fused Pallas
    # kernel resolving page tables in-kernel; "gather" = jnp gather +
    # dense decode attention; "ref" = pure-jnp oracle path.
    decode_kernel: str = "gather"             # paged | gather | ref
    # Buffer donation for the decode tick: the jitted step donates its
    # cache argument to XLA (``donate_argnums``), so the KV slot stack /
    # recurrent state update in place instead of double-buffering. The
    # memory statistics condition on this flag (the un-donated step
    # transiently holds a second copy of the group's arena), and
    # ``repro.analysis.memory_audit`` certifies it against the lowered
    # executable's input-output aliasing. Prefill plans keep False: the
    # prompt pass has no cache input to donate.
    donate_cache: bool = False
    notes: Tuple[str, ...] = ()

    def replace(self, **kw) -> "PlanConfig":
        return dataclasses.replace(self, **kw)


# Every plan *axis* — the PlanConfig fields that parameterize an execution
# plan (``notes`` is free-text provenance, not an axis). EXPLAIN output
# must record each one: a plan axis that can change behaviour without
# showing up in ``ExecutionPlan.explain()`` is an un-debuggable decision,
# and both the ``plan-axis-in-explain`` lint rule and the cost auditor's
# explain-completeness check enforce membership against this tuple.
PLAN_AXES: Tuple[str, ...] = tuple(
    f.name for f in dataclasses.fields(PlanConfig) if f.name != "notes")


@dataclass(frozen=True)
class RuntimeStats:
    """Observed data/runtime characteristics of one executed request.

    SystemML distinguishes *compile-time statistics* (worst-case size and
    sparsity assumptions baked into the plan) from *runtime statistics*
    observed while executing it, and re-optimizes when they diverge. This
    is the runtime side: the actual request shape and the measured live-
    bytes watermark, fed back into :meth:`PlanCompiler.recompile`.

    ``cache_pool_bytes`` is the live size of the row-addressable KV-cache
    pool (``repro.runtime.kv_cache``) at observation time; a pool that has
    outgrown the plan's compile-time cache statistics triggers dynamic
    recompilation exactly like an activation-watermark breach. With paged
    arenas the figure is *page-exact* — committed pages plus leased rows'
    recurrent state, not bucket-shaped arena capacity — so bucket slack no
    longer masquerades as memory pressure and over-triggers the predicate.
    """

    shape: InputShape
    watermark_bytes: float = 0.0
    cache_pool_bytes: float = 0.0
    # Observed committed KV pages per request row (0 = not observed).
    # Compile-time kernel selection assumes worst-case commitment (every
    # row at bucket depth); when the observed page counts diverge, dynamic
    # recompilation re-runs decode-kernel selection with this figure and
    # can flip the operator choice.
    committed_pages_per_row: float = 0.0


@dataclass
class ExecutionPlan:
    """Compiler output: layout config + estimates + EXPLAIN text."""

    model: ModelConfig
    shape: InputShape
    mesh: MeshConfig
    config: PlanConfig
    memory: "object" = None     # core.memory.MemoryEstimate
    cost: "object" = None       # core.cost.CostEstimate
    dtype: str = "bfloat16"     # compute dtype the statistics were sized for

    def explain_axes(self) -> Dict[str, str]:
        """Every plan axis (:data:`PLAN_AXES`), rendered. This is the
        authoritative record behind :meth:`explain`: an axis absent here is
        a plan decision EXPLAIN cannot surface, which the
        ``plan-axis-in-explain`` lint rule and the cost auditor's
        explain-completeness check both flag. Add the entry *here* when
        adding a PlanConfig field; ``explain()`` renders from this dict."""
        c = self.config
        return {
            "strategy": c.strategy.value,
            "batch_axes": str(c.batch_axes or "(replicated)"),
            "seq_axes": str(c.seq_axes or "(unsharded)"),
            "tensor_parallel": str(c.tensor_parallel),
            "params_over_data": str(c.params_over_data),
            "expert_parallel": str(c.expert_parallel),
            "cache_batch_axes": str(c.cache_batch_axes or "(replicated)"),
            "cache_heads_over_model": str(c.cache_heads_over_model),
            "cache_seq_axes": str(c.cache_seq_axes or "()"),
            "opt_state_dtype": c.opt_state_dtype,
            "seq_shard_checkpoints": str(c.seq_shard_checkpoints),
            "remat": str(c.remat),
            "microbatches": str(c.microbatches),
            "attention_variant": c.attention_variant,
            "decode_kernel": c.decode_kernel,
            "donate_cache": ("donated (in-place)" if c.donate_cache
                             else "double-buffered"),
        }

    def explain(self) -> str:
        """SystemML-style EXPLAIN output for the generated plan."""
        ax = self.explain_axes()
        lines = [
            f"# EXECUTION PLAN  {self.model.name} x {self.shape.name} "
            f"x mesh{self.mesh.shape} [{self.dtype}]",
            f"strategy:            {ax['strategy']}",
            f"batch sharded over:  {ax['batch_axes']}",
            f"seq sharded over:    {ax['seq_axes']}",
            f"tensor parallel:     {ax['tensor_parallel']}",
            f"params over data:    {ax['params_over_data']} (FSDP/ZeRO)",
            f"expert parallel:     {ax['expert_parallel']}",
            f"opt-state dtype:     {ax['opt_state_dtype']}",
            f"seq-shard ckpts:     {ax['seq_shard_checkpoints']}",
            f"remat:               {ax['remat']}   "
            f"microbatches: {ax['microbatches']}",
            f"attention variant:   {ax['attention_variant']}",
        ]
        if self.shape.is_decode:
            # donation per buffer class: the cache pytree (attention slot
            # stacks + recurrent state) is the only donated step input;
            # params and page tables are read-shared across groups
            lines += [
                f"kv-cache batch axes: {ax['cache_batch_axes']}",
                f"kv-cache heads/model:{ax['cache_heads_over_model']}  "
                f"seq axes:{ax['cache_seq_axes']}",
                f"decode kernel:       {ax['decode_kernel']}",
                f"buffer donation:     kv-cache/recurrent-state "
                f"{ax['donate_cache']}; params, page tables read-only",
            ]
        if self.memory is not None:
            lines.append(self.memory.summary())
        if self.cost is not None:
            lines.append(self.cost.summary())
        for n in self.config.notes:
            lines.append(f"note: {n}")
        return "\n".join(lines)
