"""Light logical operator graph.

SystemML compiles DML into a DAG of high-level operators (HOPs) with
per-operator output-size and memory estimates, then selects physical
operators (LOPs). We keep a miniature version: enough structure for the
memory/cost estimators and the benchmark tables to reason per-operator,
without re-implementing a full HOP/LOP stack (JAX/XLA owns that level).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

from repro.config import InputShape, ModelConfig


@dataclass(frozen=True)
class LogicalOp:
    name: str
    kind: str                  # matmul | attention | scan | norm | router | ...
    flops: float
    bytes_in: float
    bytes_out: float
    count: int = 1             # how many times per step (e.g. per layer)

    @property
    def total_flops(self) -> float:
        return self.flops * self.count

    @property
    def arithmetic_intensity(self) -> float:
        b = self.bytes_in + self.bytes_out
        return self.flops / b if b else float("inf")


@dataclass
class OpGraph:
    ops: List[LogicalOp] = field(default_factory=list)

    def add(self, op: LogicalOp) -> None:
        self.ops.append(op)

    @property
    def total_flops(self) -> float:
        return sum(o.total_flops for o in self.ops)

    @property
    def total_bytes(self) -> float:
        return sum((o.bytes_in + o.bytes_out) * o.count for o in self.ops)

    def dominant(self, n: int = 5) -> List[LogicalOp]:
        return sorted(self.ops, key=lambda o: -o.total_flops)[:n]

    def table(self) -> str:
        rows = ["op,kind,count,gflops,intensity"]
        for o in sorted(self.ops, key=lambda o: -o.total_flops):
            rows.append(
                f"{o.name},{o.kind},{o.count},{o.total_flops / 1e9:.2f},"
                f"{o.arithmetic_intensity:.1f}"
            )
        return "\n".join(rows)


def build_op_graph(model: ModelConfig, shape: InputShape) -> OpGraph:
    """Analytic per-operator graph for one forward pass."""
    g = OpGraph()
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    tok = b * s
    d = model.d_model
    A = 2  # bytes (bf16)

    def mm(name, m, k, n, count=1, kind="matmul"):
        g.add(LogicalOp(name, kind, 2.0 * m * k * n,
                        (m * k + k * n) * A, m * n * A, count))

    pat = model.layer_pattern()
    n_attn = pat.count("a")
    n_ssd = pat.count("s")
    n_lru = pat.count("r")

    if n_attn:
        h, kv, hd, f = model.num_heads, model.num_kv_heads, model.head_dim, model.d_ff
        mm("q_proj", tok, d, h * hd, n_attn)
        mm("kv_proj", tok, d, 2 * kv * hd, n_attn)
        ctx = shape.seq_len if shape.kind == "decode" else s
        if model.window_size:
            ctx = min(ctx, model.window_size)
        g.add(LogicalOp("attention", "attention",
                        4.0 * b * s * ctx * h * hd / (1 if shape.kind == "decode" else 2),
                        tok * (h + 2 * kv) * hd * A, tok * h * hd * A, n_attn))
        mm("o_proj", tok, h * hd, d, n_attn)
        if model.num_experts:
            g.add(LogicalOp("router", "router", 2.0 * tok * d * model.num_experts,
                            tok * d * A, tok * model.num_experts * A, n_attn))
            mm("expert_ffn", tok * model.experts_per_token, d, 3 * f, n_attn, "moe")
        else:
            mm("ffn", tok, d, 3 * f, n_attn)
    if n_ssd:
        di, st, nh = model.d_inner, model.ssm_state, model.ssm_num_heads
        mm("ssd_in_proj", tok, d, 2 * di + 2 * st + nh, n_ssd)
        g.add(LogicalOp("ssd_scan", "scan", 6.0 * tok * di * st,
                        tok * (di + 2 * st) * A, tok * di * A, n_ssd))
        mm("ssd_out_proj", tok, di, d, n_ssd)
    if n_lru:
        w = model.lru_width or d
        mm("lru_proj", tok, d, 2 * w, n_lru)
        g.add(LogicalOp("rg_lru", "scan", 8.0 * tok * w,
                        tok * w * A, tok * w * A, n_lru))
        mm("lru_out", tok, w, d, n_lru)
    g.add(LogicalOp("norms", "norm", 6.0 * tok * d,
                    tok * d * A, tok * d * A, len(pat)))
    mm("lm_head", tok if shape.kind != "decode" else b, d, model.vocab_size)
    return g
