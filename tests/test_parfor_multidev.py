"""parfor task-parallel scoring (paper §3): remote plan == local plan
results, and the remote body contains ZERO collectives (the "avoids
shuffling" claim). Multi-device behaviour runs in a subprocess with 8
placeholder host devices."""

from conftest import run_multidev


def test_parfor_remote_equals_local_and_no_shuffle():
    out = run_multidev("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.parfor import parfor, choose_parfor_plan, count_collectives

mesh = jax.make_mesh((4, 2), ("data", "model"))
w = jax.random.normal(jax.random.PRNGKey(0), (16, 8))

def score(rows):
    return jax.nn.softmax(rows @ w, axis=-1)

x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))

# local (no mesh)
local, plan_l = parfor(score, x)
assert plan_l == "local"

# remote (row-partitioned shard_map)
remote, plan_r = parfor(score, x, mesh=mesh)
assert plan_r == "remote", plan_r
np.testing.assert_allclose(np.asarray(remote), np.asarray(local), rtol=1e-5)

# the "avoids shuffling" property: zero collectives in the lowered plan
import functools
fn = lambda rows: parfor(score, rows, mesh=mesh)[0]
hlo = jax.jit(fn).lower(x).compile().as_text()
n = count_collectives(hlo)
assert n == 0, f"parfor body must be collective-free, found {n}"

# with reduce="mean": exactly the final allreduce appears
fn2 = lambda rows: parfor(lambda r: jnp.sum(r @ w, axis=-1, keepdims=True),
                          rows, mesh=mesh, reduce="mean")[0]
hlo2 = jax.jit(fn2).lower(x).compile().as_text()
assert count_collectives(hlo2) >= 1
print("PARFOR_OK")
""")
    assert "PARFOR_OK" in out


def test_parfor_optimizer_chooses_local_for_small_input():
    out = run_multidev("""
import jax
from repro.core.parfor import choose_parfor_plan
mesh = jax.make_mesh((4, 2), ("data", "model"))
assert choose_parfor_plan(2, mesh) == "local"      # too few rows
assert choose_parfor_plan(3, mesh) == "local"      # indivisible
assert choose_parfor_plan(64, mesh) == "remote"
assert choose_parfor_plan(64, None) == "local"
print("CHOOSE_OK")
""")
    assert "CHOOSE_OK" in out


def test_sharded_train_step_multidev():
    """A reduced arch trains under a real (4 data x 2 model) mesh with the
    planner's shardings — the end-to-end distributed path on 8 devices."""
    out = run_multidev("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.config import MeshConfig, InputShape, TrainConfig
from repro.configs import get_config
from repro.core.planner import compile_plan
from repro.core.sharding import tree_specs
from repro.models.model import build_model
from repro.runtime.train_loop import (make_train_step, init_opt_state,
                                      train_shardings, batch_specs)
from repro.data import make_batch

mesh_cfg = MeshConfig(shape=(4, 2), axis_names=("data", "model"))
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("yi-6b-smoke")
shape = InputShape("tiny", 32, 8, "train")
train = TrainConfig(optimizer="adam", learning_rate=1e-2, force_strategy="fsdp_tensor_parallel")
plan = compile_plan(cfg, shape, mesh_cfg, train)
model = build_model(cfg, dtype=jnp.float32)

with mesh:
    (pspecs, _, pshard), (ospecs, _, oshard) = train_shardings(model, plan.config, mesh_cfg, train, mesh)
    params = model.init_params(jax.random.PRNGKey(0))
    params = jax.device_put(params, pshard)
    opt = init_opt_state(train.optimizer, params, plan.config)
    step_fn = jax.jit(make_train_step(model, plan.config, mesh_cfg, train))
    losses = []
    for i in range(8):
        b = make_batch(cfg, shape, step=i, dtype=jnp.float32)
        params, opt, metrics = step_fn(params, opt, b, jnp.int32(i))
        losses.append(float(metrics["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0], losses
print("TRAIN_MULTIDEV_OK", losses[0], "->", losses[-1])
""", timeout=560)
    assert "TRAIN_MULTIDEV_OK" in out
