"""The audit matrix: one definition of the CI smoke grid.

Three statistics-validation passes (``plan_audit``, ``memory_audit``,
``cost_audit``) walk the same (arch x dtype x kind x bucket x forced
decode kernel) grid; before this module each kept its own copy of the
constants and the enumeration loop, and the copies had already begun to
drift (prefill handoff filtering lived only in one of them). The grid is
now defined once:

- the smoke constants (``SMOKE_ARCHS`` / ``SMOKE_DTYPES`` /
  ``SMOKE_BUCKETS`` / ``PAGE_SIZE`` / ``POOL_ARENAS`` / ``REPORT_PATH``);
- :func:`smoke_cells`, the canonical cell iterator — decode cells under
  both forced physical operators, prefill cells only for handoff-capable
  families, each yielded as a :class:`Cell`;
- :func:`merge_report`, the shared report writer: every pass lands its
  section(s) in ``ANALYSIS_report.json`` *in place*, preserving whatever
  the other passes wrote (and surviving a corrupt or non-dict file on
  disk instead of crashing the gate).

Auditors stay import-light here on purpose: this module pulls in the
model registry (to answer the handoff question) but none of the tracing
machinery, so the lint / sanitize passes can import it too.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, Iterator, Sequence, Tuple

from repro.configs import get_config
from repro.models.model import build_model

# the CI smoke matrix: one arch per serving family (attention / SSD /
# RG-LRU hybrid), both serving dtypes, two buckets spanning the pow2 grid
SMOKE_ARCHS = ("yi-6b-smoke", "mamba2-1.3b-smoke", "recurrentgemma-2b-smoke")
SMOKE_DTYPES = ("bfloat16", "float32")
SMOKE_BUCKETS = ((1, 64), (4, 128))
PAGE_SIZE = 64
POOL_ARENAS = 4            # what PlanServer provisions by default
REPORT_PATH = "ANALYSIS_report.json"

# decode cells are audited under both forced physical operators so every
# read path is traced and asserted; prefill has no decode-attention
# operator to choose
DECODE_KERNELS = ("paged", "gather")


@dataclass(frozen=True)
class Cell:
    """One audit-matrix cell: the coordinates every pass keys records by."""

    arch: str
    dtype: str
    kind: str                  # "decode" | "prefill"
    batch: int
    seq: int
    forced_kernel: str = "auto"

    @property
    def where(self) -> str:
        w = f"{self.arch}/{self.dtype}/{self.kind}/b{self.batch}s{self.seq}"
        if self.kind == "decode" and self.forced_kernel != "auto":
            w += f"/{self.forced_kernel}"
        return w


def supports_prefill(arch: str, dtype: str) -> bool:
    """Whether the family prefills in-band (modality frontends hand off)."""
    return build_model(get_config(arch), dtype=dtype).supports_handoff


def smoke_cells(archs: Sequence[str] = SMOKE_ARCHS,
                dtypes: Sequence[str] = SMOKE_DTYPES,
                buckets: Sequence[Tuple[int, int]] = SMOKE_BUCKETS,
                kinds: Sequence[str] = ("decode", "prefill"),
                kernels: Sequence[str] = DECODE_KERNELS) -> Iterator[Cell]:
    """The canonical enumeration every audit pass walks."""
    for arch in archs:
        for dtype in dtypes:
            for kind in kinds:
                if kind == "prefill" and not supports_prefill(arch, dtype):
                    continue   # modality frontends prefill out of band
                cell_kernels = kernels if kind == "decode" else ("auto",)
                for batch, seq in buckets:
                    for dk in cell_kernels:
                        yield Cell(arch, dtype, kind, batch, seq, dk)


def matrix_meta(archs: Sequence[str] = SMOKE_ARCHS,
                dtypes: Sequence[str] = SMOKE_DTYPES,
                buckets: Sequence[Tuple[int, int]] = SMOKE_BUCKETS,
                **extra: Any) -> Dict[str, Any]:
    """The ``matrix`` header each pass embeds in its report section."""
    meta: Dict[str, Any] = {
        "archs": list(archs),
        "dtypes": list(dtypes),
        "buckets": [list(b) for b in buckets],
    }
    meta.update(extra)
    return meta


def merge_report(path: str, updates: Dict[str, Any]) -> Dict[str, Any]:
    """Merge ``updates`` into the shared analysis report, preserving every
    section some *other* pass wrote. A corrupt, unreadable, or non-dict
    file on disk is replaced rather than crashing the gate — the report
    is evidence, not state the auditors depend on. Returns the merged
    document (what now sits on disk)."""
    p = Path(path)
    report: Dict[str, Any] = {}
    if p.exists():
        try:
            prior = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError):
            prior = None
        if isinstance(prior, dict):
            report = prior
    report.update(updates)
    p.write_text(json.dumps(report, indent=2))
    return report
