"""Scenario metadata for benchmark artifacts.

Every ``BENCH_*.json`` the harness writes is a point on a perf
trajectory; a point is only comparable to its neighbors if it says what
scenario produced it. :func:`scenario_meta` stamps the knobs that change
the numbers — model arch, replica count, arrival rate — plus the code
revision (``git describe``) and interpreter, so two artifacts can be
diffed without guessing which commit or fleet shape they came from.
"""

from __future__ import annotations

import os
import platform
import subprocess
from typing import Any, Dict

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def git_describe() -> str:
    """Current revision (`git describe --always --dirty`), or "unknown"
    outside a git checkout — benches must not fail over provenance."""
    try:
        out = subprocess.run(
            ["git", "describe", "--always", "--dirty", "--tags"],
            capture_output=True, text=True, timeout=10, cwd=_REPO_ROOT)
    except (OSError, subprocess.SubprocessError):
        return "unknown"
    rev = out.stdout.strip()
    return rev if out.returncode == 0 and rev else "unknown"


def scenario_meta(arch: str, *, replicas: int = 1,
                  arrival_rate: float = 0.0, **extra: Any) -> Dict[str, Any]:
    """The dict every bench embeds under ``"meta"`` in its JSON artifact."""
    meta: Dict[str, Any] = {
        "arch": arch,
        "replicas": replicas,
        "arrival_rate_per_s": arrival_rate,
        "git": git_describe(),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }
    meta.update(extra)
    return meta
