"""repro.launch — mesh construction, multi-pod dry-run, train/serve
drivers, HLO cost extraction. NOTE: importing ``repro.launch.dryrun`` sets
XLA_FLAGS for 512 placeholder devices; never import it from tests or
benchmarks."""

from repro.launch.mesh import make_local_mesh, make_production_mesh, mesh_cfg_for

__all__ = ["make_production_mesh", "make_local_mesh", "mesh_cfg_for"]
