"""Sparsity-aware operator selection (paper §3, "Sparse Operations").

SystemML "maintains the number of nonzeros for each intermediate matrix,
decides upon dense or sparse formats, and selects appropriate runtime
operators for combinations of dense and sparse inputs", including four
physical convolution operators (dense/sparse input x dense/sparse filter).

This module reproduces that machinery:

* :class:`MatrixCharacteristics` — dims + nnz metadata propagated through ops
  (SystemML's MatrixCharacteristics).
* :func:`select_format` — the dense/sparse format decision with SystemML's
  classic sparsity threshold (< 0.4).
* CSR-lite sparse ops with *static* shapes (JAX requires static nnz capacity:
  we pad to a capacity and mask, the TPU-native equivalent of SystemML's
  allocated-sparse-row blocks).
* :func:`select_matmul_operator` / :func:`select_conv_operator` — the
  operator-variant dispatch tables.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# SystemML's format decision threshold: matrices with sparsity below this are
# stored sparse (MatrixBlock.SPARSITY_TURN_POINT = 0.4).
SPARSITY_TURN_POINT = 0.4
# Minimum size for the sparse format to pay off (tiny matrices stay dense).
SPARSE_MIN_CELLS = 4096


@dataclass(frozen=True)
class MatrixCharacteristics:
    nrows: int
    ncols: int
    nnz: int = -1  # -1 = unknown -> assume worst-case dense

    @property
    def cells(self) -> int:
        return self.nrows * self.ncols

    @property
    def density(self) -> float:
        if self.nnz < 0:
            return 1.0
        return self.nnz / max(1, self.cells)

    def dense_bytes(self, dtype_bytes: int = 4) -> int:
        return self.cells * dtype_bytes

    def sparse_bytes(self, dtype_bytes: int = 4) -> int:
        """CSR: values + col indices (int32) + row pointers."""
        nnz = self.cells if self.nnz < 0 else self.nnz
        return nnz * (dtype_bytes + 4) + (self.nrows + 1) * 4

    def out_of(self, x: jnp.ndarray) -> "MatrixCharacteristics":
        return MatrixCharacteristics(x.shape[0], x.shape[1], int((x != 0).sum()))


def characteristics(x) -> MatrixCharacteristics:
    x = np.asarray(x)
    return MatrixCharacteristics(x.shape[0], x.shape[1], int((x != 0).sum()))


def select_format(mc: MatrixCharacteristics) -> str:
    """'sparse' iff density < 0.4 and big enough — SystemML's rule."""
    if mc.cells < SPARSE_MIN_CELLS:
        return "dense"
    return "sparse" if mc.density < SPARSITY_TURN_POINT else "dense"


def select_matmul_operator(a: MatrixCharacteristics, b: MatrixCharacteristics) -> str:
    fa, fb = select_format(a), select_format(b)
    return f"matmul_{fa}_{fb}"


def select_conv_operator(x: MatrixCharacteristics, w: MatrixCharacteristics) -> str:
    """The paper's four physical conv operators."""
    fx, fw = select_format(x), select_format(w)
    return f"conv2d_{fx}_{fw}"


# ---------------------------------------------------------------------------
# CSR-lite: static-capacity sparse matrices for JAX
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclass
class CSRMatrix:
    """Padded CSR with static nnz capacity (masked by ``valid``).
    Registered as a pytree (shape is static metadata) so CSR matrices flow
    through jit/grad like any array — SystemML's sparse MatrixBlock role."""

    values: jnp.ndarray    # (capacity,)
    col_idx: jnp.ndarray   # (capacity,) int32
    row_idx: jnp.ndarray   # (capacity,) int32  (row of each stored value)
    valid: jnp.ndarray     # (capacity,) bool
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def nnz_capacity(self) -> int:
        return self.values.shape[0]


def to_csr(x: jnp.ndarray, capacity: int | None = None) -> CSRMatrix:
    xn = np.asarray(x)
    r, c = np.nonzero(xn)
    vals = xn[r, c]
    nnz = vals.shape[0]
    cap = capacity or max(1, nnz)
    if nnz > cap:
        raise ValueError(f"nnz {nnz} exceeds capacity {cap}")
    pad = cap - nnz
    return CSRMatrix(
        values=jnp.asarray(np.pad(vals, (0, pad)).astype(xn.dtype)),
        col_idx=jnp.asarray(np.pad(c, (0, pad)).astype(np.int32)),
        row_idx=jnp.asarray(np.pad(r, (0, pad)).astype(np.int32)),
        valid=jnp.asarray(np.pad(np.ones(nnz, bool), (0, pad))),
        shape=(xn.shape[0], xn.shape[1]),
    )


def csr_to_dense(a: CSRMatrix) -> jnp.ndarray:
    out = jnp.zeros(a.shape, a.values.dtype)
    vals = jnp.where(a.valid, a.values, 0)
    return out.at[a.row_idx, a.col_idx].add(vals)


def spmm(a: CSRMatrix, b: jnp.ndarray) -> jnp.ndarray:
    """Sparse (CSR-lite) x dense matmul: scatter-add of scaled rows of b.

    FLOPs are O(nnz * ncols(b)) — the "reduces the number of floating point
    operations" claim of the paper, validated in benchmarks.
    """
    vals = jnp.where(a.valid, a.values, 0)
    rows_of_b = b[a.col_idx, :] * vals[:, None]          # (cap, n)
    out = jnp.zeros((a.shape[0], b.shape[1]), b.dtype)
    return out.at[a.row_idx, :].add(rows_of_b)


def matmul_auto(a: jnp.ndarray, b: jnp.ndarray) -> Tuple[jnp.ndarray, str]:
    """Format-dispatched matmul: the SystemML operator-selection path."""
    mca, mcb = characteristics(a), characteristics(b)
    op = select_matmul_operator(mca, mcb)
    if op == "matmul_sparse_dense":
        return spmm(to_csr(a), b), op
    if op == "matmul_dense_sparse":
        # A @ B = (B^T @ A^T)^T with B^T sparse
        return spmm(to_csr(b.T), a.T).T, op
    if op == "matmul_sparse_sparse":
        # SystemML executes sparse-sparse via sparse-left iteration; we keep
        # the left operand sparse and densify the right.
        return spmm(to_csr(a), b), op
    return a @ b, op


def sparse_flops_matmul(a: MatrixCharacteristics, b: MatrixCharacteristics) -> int:
    """Worst-case FLOP estimate under the selected operator (sparse-safe)."""
    op = select_matmul_operator(a, b)
    dense = 2 * a.nrows * a.ncols * b.ncols
    if op == "matmul_sparse_dense" or op == "matmul_sparse_sparse":
        nnz = a.cells if a.nnz < 0 else a.nnz
        return 2 * nnz * b.ncols
    if op == "matmul_dense_sparse":
        nnz = b.cells if b.nnz < 0 else b.nnz
        return 2 * nnz * a.nrows
    return dense
