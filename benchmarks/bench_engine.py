"""ServingEngine benchmark: per-token streaming overhead vs batch-mode
completion reading, time-to-first-token / inter-token latency, and
early-termination reclamation, on the same mixed-shape streams.

Streaming is supposed to be *observation, not a different execution path*:
the engine emits a :class:`TokenEvent` per live request per tick either
way, and ``events()`` consumers just drain them. This bench holds that
claim to a number — consuming the full event stream must cost <= 10% wall
time over running the identical workload through the batch adapter
(``ContinuousBatchingScheduler.run``) and reading tokens at the end — and
verifies the streamed tokens are byte-identical to the batch results.

Acceptance targets (CI-enforced):

- streamed wall time <= 1.10x batch wall time on the same request stream;
- streamed tokens byte-identical to batch-mode tokens per request;
- zero recompiles anywhere (dtype-, pool- and page-aware estimates).

Also reported (not gated): time-to-first-token and inter-token latency
percentiles, and the cancel scenario — half the requests cancelled
mid-decode, showing reclaimed pages turning into mid-decode join capacity.

    PYTHONPATH=src python benchmarks/bench_engine.py [--smoke]

Prints ``name,us_per_call,derived`` CSV rows (harness contract), writes the
full result set to ``BENCH_engine.json`` (the perf-trajectory artifact CI
uploads), and exits non-zero below the gate or on a spurious recompile.
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys
import time

import numpy as np

from repro.configs import get_config
from repro.runtime.engine_config import EngineConfig
from repro.runtime.scheduler import (ContinuousBatchingScheduler,
                                     simulate_arrivals)
from repro.runtime.serve_loop import ServeRequest

try:
    from benchmarks.bench_meta import artifact_revision_status, scenario_meta
except ImportError:  # run as a script from the benchmarks/ directory
    from bench_meta import artifact_revision_status, scenario_meta


TARGET_OVERHEAD = 1.10
# the un-donated tick holds input + output copies of the group's arena, so
# its observed live-bytes watermark on the long-context cell must sit at
# least this factor above the donating (in-place) run's
DONATION_TARGET = 1.3
RESULTS_JSON = "BENCH_engine.json"


def _stream(smoke: bool):
    """Single-sequence requests over two context buckets (the
    bench_scheduler mix): enough ticks that per-token event overhead would
    show, small enough for CI smoke."""
    mix = [(1, 40), (1, 90), (1, 60), (1, 100), (1, 50), (1, 120),
           (1, 40), (1, 100)]
    if smoke:
        return mix, 8, 4
    return mix * 2, 8, 6


def _time_trial(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _measure(smoke: bool, arch: str):
    """Returns (rows, overhead, equal, recompiles, detail)."""
    cfg = get_config(arch)
    ecfg = EngineConfig(cache_capacity=16)
    shapes, new_tokens, trials = _stream(smoke)
    reqs = [ServeRequest(b, c, new_tokens) for b, c in shapes]

    # one server for everything: identical params, warm plan cache
    srv = ecfg.build_server(cfg)
    ContinuousBatchingScheduler(srv, config=ecfg).run(
        simulate_arrivals(reqs))

    def run_batch():
        sched = ContinuousBatchingScheduler(srv, config=ecfg)
        return sched.run(simulate_arrivals(reqs))

    def run_streamed():
        eng = ecfg.build_engine(srv)
        handles = [eng.submit(r) for r in reqs]
        toks = {h.rid: [] for h in handles}
        for ev in eng.events():
            if ev.token is not None:
                toks[ev.rid].append(ev.token)
        return eng, handles, toks

    # interleave trials so transient box load penalizes both paths alike,
    # and gate on the *median per-pair ratio*: each back-to-back pair runs
    # identical jitted work, so the pair ratio isolates the streaming
    # overhead from absolute box speed; the median drops spike-contaminated
    # pairs on either side (a min would let one slow batch half mask a
    # real streaming regression, a ratio of independent minima would let
    # one unlucky streamed floor fail the gate)
    batch_s = streamed_s = None
    batch_results = streamed_out = None
    ratios = []
    for _ in range(trials):
        res = {}
        b_dt = _time_trial(lambda: res.setdefault("r", run_batch()))
        if batch_s is None or b_dt < batch_s:
            batch_s, batch_results = b_dt, res["r"]
        res = {}
        s_dt = _time_trial(lambda: res.setdefault("r", run_streamed()))
        if streamed_s is None or s_dt < streamed_s:
            streamed_s, streamed_out = s_dt, res["r"]
        if b_dt:
            ratios.append(s_dt / b_dt)
    overhead = statistics.median(ratios) if ratios else 0.0

    # streamed tokens must be byte-identical to the batch-mode results
    eng, handles, toks = streamed_out
    batch_by_rid = {r["rid"]: np.asarray(r["tokens"]) for r in batch_results}
    equal = True
    for orig, h in zip(reqs, handles):
        got = np.concatenate([np.asarray(t) for t in toks[h.rid]], axis=1)
        if not np.array_equal(got, batch_by_rid[orig.rid]):
            equal = False
    m = eng.metrics
    ttft50 = m.ttft_latency.percentile(50)
    ttft95 = m.ttft_latency.percentile(95)
    itl50 = m.itl_latency.percentile(50)
    itl95 = m.itl_latency.percentile(95)

    # cancel scenario (informational): half the requests hang up after 2
    # tokens; their rows/pages return the same tick and join-admit the rest
    srv_c = ecfg.build_server(cfg)
    n_c = 6 if smoke else 10
    cancel_reqs = [ServeRequest(1, 60, 24) for _ in range(n_c)]
    eng_c = ecfg.build_engine(srv_c)
    ch = {h.rid: h for h in (eng_c.submit(r) for r in cancel_reqs)}
    victims = {r.rid for r in cancel_reqs[::2]}
    for ev in eng_c.events():
        if ev.token is not None and ev.rid in victims and ev.index + 1 >= 2:
            eng_c.cancel(ch[ev.rid])
    reclaimed = srv_c.pool.metrics.pages_reclaimed

    recompiles = srv.metrics.recompiles + srv_c.metrics.recompiles
    n = len(reqs)
    rows = [
        f"engine_batch,{batch_s / n * 1e6:.0f},"
        f"rps={n / batch_s:.2f}",
        f"engine_streamed,{streamed_s / n * 1e6:.0f},"
        f"rps={n / streamed_s:.2f};overhead_x={overhead:.2f};"
        f"target<={TARGET_OVERHEAD};tokens_equal={int(equal)}",
        f"engine_ttft,{ttft50 * 1e6:.0f},"
        f"p95_us={ttft95 * 1e6:.0f};itl_p50_us={itl50 * 1e6:.0f};"
        f"itl_p95_us={itl95 * 1e6:.0f}",
        f"engine_cancel,{reclaimed},"
        f"cancelled={eng_c.metrics.cancelled};"
        f"completed={eng_c.metrics.completed};"
        f"joins={eng_c.metrics.joins}",
    ]
    detail = {
        "batch_s": batch_s, "streamed_s": streamed_s,
        "overhead": overhead, "tokens_equal": equal,
        "ttft_p50_s": ttft50, "ttft_p95_s": ttft95,
        "itl_p50_s": itl50, "itl_p95_s": itl95,
        "cancel": {"cancelled": eng_c.metrics.cancelled,
                   "completed": eng_c.metrics.completed,
                   "joins": eng_c.metrics.joins,
                   "pages_reclaimed": reclaimed},
    }
    return rows, overhead, equal, recompiles, detail


def _measure_donation(smoke: bool, arch: str):
    """Donation A/B on the long-context cell: the same request served by a
    donating engine (default) and a ``donate=False`` engine. Gates that
    the un-donated watermark is >= DONATION_TARGET x the donated one (the
    double-buffer term is real, and donation actually removes it) and that
    tokens are byte-identical (XLA writing the cache in place must not
    change a logit)."""
    batch, context, new_tokens = (4, 360, 6) if smoke else (4, 480, 8)
    cfg = get_config(arch)
    out = {}
    for donate in (True, False):
        ecfg = EngineConfig(cache_capacity=8, donate=donate)
        eng = ecfg.build_engine(ecfg.build_server(cfg))
        eng.submit(ServeRequest(batch, context, new_tokens))
        recs = eng.drain()
        assert len(recs) == 1 and eng.idle
        out[donate] = recs[0]
    donated_wm = out[True]["watermark_bytes"]
    plain_wm = out[False]["watermark_bytes"]
    ratio = plain_wm / donated_wm if donated_wm else 0.0
    equal = np.array_equal(np.asarray(out[True]["tokens"]),
                           np.asarray(out[False]["tokens"]))
    rows = [
        f"engine_donation,{donated_wm:.0f},"
        f"undonated_bytes={plain_wm:.0f};ratio_x={ratio:.2f};"
        f"target>={DONATION_TARGET};tokens_equal={int(equal)}",
    ]
    detail = {
        "batch": batch, "context": context, "new_tokens": new_tokens,
        "donated_watermark_bytes": donated_wm,
        "undonated_watermark_bytes": plain_wm,
        "ratio": ratio, "tokens_equal": equal,
    }
    return rows, ratio, equal, detail


def run(smoke: bool = False, arch: str = "yi-6b-smoke"):
    """Harness entry point (benchmarks/run.py contract): CSV rows only."""
    return _measure(smoke, arch)[0]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream for CI (seconds, not minutes)")
    ap.add_argument("--arch", default="yi-6b-smoke")
    args = ap.parse_args(argv)

    # staleness verdict for the copy we're about to overwrite: a committed
    # artifact from an older revision must not read as a claim about HEAD
    prev_status = artifact_revision_status(RESULTS_JSON)
    if prev_status["status"] == "stale":
        print(f"# note: existing {RESULTS_JSON} was generated at "
              f"{prev_status['artifact_git']} (head is "
              f"{prev_status['head_git']}); regenerating", file=sys.stderr)

    print("name,us_per_call,derived")
    rows, overhead, equal, recompiles, detail = _measure(args.smoke,
                                                         args.arch)
    d_rows, d_ratio, d_equal, d_detail = _measure_donation(args.smoke,
                                                           args.arch)
    rows += d_rows
    detail["donation"] = d_detail
    for row in rows:
        print(row, flush=True)
    ok = True
    if d_ratio < DONATION_TARGET:
        print(f"FAIL: donation watermark gain {d_ratio:.2f}x < "
              f"{DONATION_TARGET}x target (double-buffer term not "
              f"recovered on the long-context cell)", file=sys.stderr)
        ok = False
    if not d_equal:
        print("FAIL: donated tokens diverged from the --no-donate path",
              file=sys.stderr)
        ok = False
    if overhead > TARGET_OVERHEAD:
        print(f"FAIL: streaming overhead {overhead:.2f}x > "
              f"{TARGET_OVERHEAD}x target", file=sys.stderr)
        ok = False
    if not equal:
        print("FAIL: streamed tokens diverged from batch-mode tokens",
              file=sys.stderr)
        ok = False
    if recompiles:
        print(f"FAIL: fp32 streams burned {recompiles} recompiles "
              f"(dtype-, pool- and page-aware estimates should need zero)",
              file=sys.stderr)
        ok = False
    with open(RESULTS_JSON, "w") as f:
        json.dump({
            "bench": "engine", "smoke": args.smoke, "arch": args.arch,
            "meta": scenario_meta(args.arch),
            "rows": rows, "ok": ok,
            "gates": {
                "streaming_overhead": {"value": overhead,
                                       "target": TARGET_OVERHEAD},
                "tokens_equal": {"value": bool(equal), "target": True},
                "recompiles": {"value": recompiles, "target": 0},
                "donation_watermark": {"value": d_ratio,
                                       "target": DONATION_TARGET},
                "donation_tokens_equal": {"value": bool(d_equal),
                                          "target": True},
            },
            "previous_artifact": prev_status,
            "detail": detail,
        }, f, indent=2)
        f.write("\n")
    print(f"# results -> {RESULTS_JSON}", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
