"""Serving launcher: every mode is one ``EngineClient`` behind one config.

All flags fold into a single :class:`repro.runtime.engine_config.
EngineConfig`; the modes differ only in how requests are fed and consumed,
and ``--replicas N`` swaps the bare engine for an
:class:`repro.runtime.router.EngineRouter` over N replicas without
changing anything else (both satisfy the ``EngineClient`` protocol):

Single-shot mode (streams the one request's tokens as they decode):

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b-smoke \
        --batch 4 --context 128 --tokens 32

Mixed-shape request-stream mode — the sequential front door
(``PlanServer.handle``, itself a submit-and-drain engine adapter):
requests of varying (batch, context) round up to power-of-two buckets,
steady-state requests hit cached compiled plans, and estimate breaches
trigger recompilation:

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b-smoke \
        --stream --requests 24 --tokens 4
    # explicit shape mix, cache disabled for A/B:
    PYTHONPATH=src python -m repro.launch.serve --stream \
        --shapes 2x100,1x40,4x60 --no-cache

Continuous-batching mode — the engine driven with simulated arrivals:
pending requests coalesce into shared shape buckets, prefill populates each
request's KV-cache pool rows, and ``--join-mid-decode`` (default on)
absorbs newly arrived same-bucket requests into free rows of in-flight
groups between decode steps. The new lifecycle knobs ride here: ``--eos-id``
stamps an end-of-sequence stop condition on every request, and
``--cancel-after N`` cancels each request after its N-th streamed token —
both release the request's cache rows/pages the same tick:

    PYTHONPATH=src python -m repro.launch.serve --scheduler \
        --requests 24 --arrival-rate 20 --slo-ms 2000
    # early termination exercises: EOS stops + client disconnects
    PYTHONPATH=src python -m repro.launch.serve --scheduler \
        --requests 24 --eos-id 450 --cancel-after 6

Multi-replica fleet mode — the same scheduler front door over an
``EngineRouter``: requests are placed across replicas (bucket affinity by
default, ``--placement load`` for queue-pressure ranking), and
``--drain-replica N`` takes replica N out mid-run to demonstrate failover
(its in-flight requests finish on the survivors, token streams intact):

    PYTHONPATH=src python -m repro.launch.serve --scheduler --replicas 2 \
        --requests 24 --arrival-rate 50
    PYTHONPATH=src python -m repro.launch.serve --scheduler --replicas 3 \
        --requests 24 --drain-replica 1
"""

from __future__ import annotations

import argparse
import random

from repro.configs import get_config
from repro.runtime.engine_config import EngineConfig
from repro.runtime.scheduler import simulate_arrivals
from repro.runtime.serve_loop import PlanServer, ServeRequest

DEFAULT_SHAPE_MIX = ((1, 40), (2, 100), (4, 60), (1, 200), (2, 250))


def _parse_shapes(spec: str):
    """``"2x100,1x40"`` -> ((2, 100), (1, 40))."""
    out = []
    for part in spec.split(","):
        try:
            b, c = part.lower().split("x")
            out.append((int(b), int(c)))
        except ValueError:
            raise SystemExit(
                f"--shapes: bad entry {part!r} (expected BATCHxCONTEXT, "
                f'e.g. "2x100,1x40")')
    return tuple(out)


def _build_server(args) -> PlanServer:
    # every flag folds into the one EngineConfig; the seed covers model
    # init, the request mix, and arrivals, so streams are reproducible
    # A/B runs (same params, same recompilation predicate)
    return EngineConfig.from_args(args).build_server(get_config(args.arch))


def _request_mix(args):
    mix = _parse_shapes(args.shapes) if args.shapes else DEFAULT_SHAPE_MIX
    rng = random.Random(args.seed)
    return mix, [ServeRequest(*mix[rng.randrange(len(mix))], args.tokens,
                              eos_id=args.eos_id)
                 for _ in range(args.requests)]


def serve_stream(args) -> None:
    """Sequential front door: one submit-and-drain engine pass per request
    (the plan cache + dynamic recompilation A/B harness)."""
    srv = _build_server(args)
    mix, reqs = _request_mix(args)
    print(f"# stream: {args.requests} requests over shape mix {mix} "
          f"cache={'off' if args.no_cache else 'on'}")
    for i, req in enumerate(reqs):
        out = srv.handle(req)
        flag = " RECOMPILED" if out["recompiled"] else ""
        fin = ("" if out["finish_reason"] == "length"
               else f" [{out['finish_reason']}]")
        print(f"req[{i:03d}] batch={req.batch} ctx={req.context} "
              f"-> bucket={out['bucket']} "
              f"{out['latency_s'] * 1e3:8.1f}ms{flag}{fin}")
        for r in out["recompile_reasons"]:
            print(f"         reason: {r}")
    print(srv.summary())


def serve_scheduled(args) -> None:
    """Continuous-batching mode, written once against the ``EngineClient``
    protocol: a bare engine for ``--replicas 1``, an ``EngineRouter`` for
    more — Poisson arrivals in, token-event stream out (cancelling
    mid-decode when ``--cancel-after`` says the client hung up, draining
    a replica mid-run when ``--drain-replica`` says it is going away)."""
    engine_cfg = EngineConfig.from_args(args)
    if args.drain_replica is not None and not (
            0 <= args.drain_replica < engine_cfg.replicas):
        raise SystemExit(f"--drain-replica {args.drain_replica}: no such "
                         f"replica (--replicas {engine_cfg.replicas})")
    client = engine_cfg.build_client(get_config(args.arch))
    mix, reqs = _request_mix(args)
    arrivals = simulate_arrivals(reqs, args.arrival_rate, seed=args.seed)
    print(f"# scheduler: {args.requests} requests over shape mix {mix} "
          f"arrival_rate={args.arrival_rate}/s "
          f"replicas={engine_cfg.replicas} "
          f"placement={engine_cfg.placement} "
          f"max_group_batch={engine_cfg.max_group_batch} "
          f"join_mid_decode={engine_cfg.join_mid_decode} "
          f"eos_id={args.eos_id} cancel_after={args.cancel_after}")

    drain = {"pending": args.drain_replica is not None}

    def on_event(ev):
        if (drain["pending"] and ev.token is not None and ev.index >= 1
                and any(h.replica is not None
                        and h.replica.idx == args.drain_replica
                        for h in client.handles.values())):
            moved = client.drain_replica(args.drain_replica)
            print(f"# drained replica {args.drain_replica}; resubmitted "
                  f"{[h.rid for h in moved]} to survivors")
            drain["pending"] = False
        if (args.cancel_after and ev.token is not None
                and ev.index + 1 >= args.cancel_after):
            handle = client.handles.get(ev.rid)
            if handle is not None:
                client.cancel(handle)

    need_hook = bool(args.cancel_after) or drain["pending"]
    client.run(arrivals, on_event=on_event if need_hook else None)
    for rec in client.results:
        joined = (f" joined@{rec['joined_at_step']}"
                  if rec["joined_at_step"] > 0 else "")
        fin = ("" if rec["finish_reason"] == "length"
               else f" [{rec['finish_reason']}]")
        print(f"req[{rec['rid']:03d}] batch={rec['batch']} "
              f"ctx={rec['context']} -> bucket={rec['bucket']} "
              f"group={rec['group_size']}{joined} "
              f"tokens={rec['tokens'].shape[1]}{fin} "
              f"queue={rec['queue_s'] * 1e3:7.1f}ms "
              f"exec={rec['exec_s'] * 1e3:7.1f}ms")
    print(client.summary())


def serve_once(args) -> None:
    """Single-shot mode: one request submitted into the engine, its tokens
    printed as the event stream produces them."""
    cfg = EngineConfig.from_args(args)
    eng = cfg.build_engine(cfg.build_server(get_config(args.arch)))
    req = ServeRequest(args.batch, args.context, args.tokens,
                       eos_id=args.eos_id)
    handle = eng.submit(req)
    toks = []
    t_first = None
    for ev in handle.stream():
        if ev.token is None:
            print(f"\n# finished: {ev.finish_reason}")
            break
        if t_first is None:
            t_first = ev.t
            print(f"# first token after {t_first * 1e3:.1f}ms")
        toks.append(int(ev.token[0, 0]))
        print(f"{toks[-1]}", end=" ", flush=True)
    rec = handle.result
    dt = max(1e-9, rec["exec_s"])
    n = rec["tokens"].shape[1]
    print(f"decoded {n} tokens x {req.batch} seqs in {dt:.2f}s "
          f"= {n * req.batch / dt:.1f} tok/s (bucket={rec['bucket']})")
    print(eng.summary())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--dtype", default="float32")
    # mixed-shape request-stream mode (plan cache + dynamic recompilation)
    ap.add_argument("--stream", action="store_true",
                    help="serve a mixed-shape request stream via PlanServer")
    ap.add_argument("--requests", type=int, default=16,
                    help="stream mode: number of requests")
    ap.add_argument("--shapes", default="",
                    help='stream mode: request mix as "BxC,BxC,..." '
                         "(default: built-in 5-shape mix)")
    ap.add_argument("--no-cache", action="store_true",
                    help="stream mode: disable the plan cache (A/B baseline)")
    ap.add_argument("--prefill", action="store_true",
                    help="stream mode: full prefill+decode requests with "
                         "KV-cache handoff (scheduler mode always prefills)")
    ap.add_argument("--cache-capacity", type=int, default=16)
    ap.add_argument("--pool-arenas", type=int, default=4,
                    help="KV-cache pool arenas the compile-time memory "
                         "statistics are provisioned for (pool growth past "
                         "them triggers dynamic recompilation)")
    ap.add_argument("--pool-max-arenas", type=int, default=0,
                    help="hard KV-cache pool budget in arenas (0 = "
                         "unbounded); a full pool queues new groups while "
                         "mid-decode joins keep absorbing work")
    ap.add_argument("--pool-max-bytes", type=float, default=0.0,
                    help="hard KV-cache pool budget in bytes (0 = "
                         "unbounded); with paged arenas the budget charges "
                         "page-exact committed bytes, so the same budget "
                         "admits more concurrently-resident requests")
    ap.add_argument("--page-size", type=int, default=64,
                    help="KV-cache page size in sequence slots: arenas "
                         "page the sequence dimension and rows commit only "
                         "the pages their span needs (vLLM-style); 0 "
                         "restores row-granular bucket-shaped leases")
    ap.add_argument("--decode-kernel", default="auto", dest="decode_kernel",
                    choices=("auto", "paged", "gather", "ref"),
                    help="physical decode-attention operator for paged "
                         "buckets: auto = planner picks per bucket from the "
                         "analytic cost terms; paged = fused Pallas kernel "
                         "(page tables resolved in-kernel); gather = jnp "
                         "gather + dense decode attention; ref = jnp oracle")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable decode-step cache donation (A/B escape "
                         "hatch): the tick double-buffers the KV cache "
                         "instead of updating it in place; expect the "
                         "live-bytes watermark to rise by one arena copy "
                         "per in-flight group, tokens byte-identical")
    ap.add_argument("--recompile-margin", type=float, default=0.25,
                    help="dynamic-recompilation watermark margin")
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds model init, the request mix, and arrivals")
    # continuous-batching scheduler mode
    ap.add_argument("--scheduler", action="store_true",
                    help="coalesce requests into shared shape buckets "
                         "(continuous batching) instead of serving one-by-one")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="scheduler mode: Poisson arrivals per second "
                         "(0 = closed burst, everything arrives at t=0)")
    ap.add_argument("--max-group-batch", type=int, default=8,
                    help="scheduler mode: batch-row capacity per group")
    ap.add_argument("--join-mid-decode", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="scheduler mode: absorb newly arrived same-bucket "
                         "requests into free cache-pool rows of in-flight "
                         "groups between decode steps (token-level "
                         "continuous batching); --no-join-mid-decode "
                         "falls back to admission-time coalescing only")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="scheduler mode: per-request latency objective "
                         "(0 disables SLO accounting)")
    ap.add_argument("--bucket-select", default="hol",
                    choices=("hol", "arrival"),
                    help="queue bucket policy: strict head-of-line (hol) "
                         "or arrival-aware (the pending bucket with the "
                         "most coalescable rows forms first, with bounded "
                         "deferral of the head bucket)")
    # multi-replica fleet (EngineRouter) knobs
    ap.add_argument("--replicas", type=int, default=1,
                    help="scheduler mode: serve through an EngineRouter "
                         "over N engine replicas (1 = bare engine; both "
                         "present the same EngineClient API)")
    ap.add_argument("--placement", default="affinity",
                    choices=("affinity", "load"),
                    help="router placement policy: deterministic bucket/"
                         "plan-cache affinity, or adaptive queue-pressure "
                         "+ observed-TTFT ranking")
    ap.add_argument("--drain-replica", type=int, default=None,
                    metavar="N",
                    help="fleet mode: drain replica N once it holds "
                         "streaming work — its in-flight requests finish "
                         "on the survivors (failover demo)")
    # request-lifecycle knobs (engine stop conditions + cancellation)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stamp an end-of-sequence stop condition on every "
                         "request: a row stops at its first eos token and "
                         "its cache rows/pages free the same tick")
    ap.add_argument("--cancel-after", type=int, default=0,
                    help="scheduler mode: cancel each request after its "
                         "N-th streamed token (simulated client disconnect; "
                         "0 disables)")
    ap.add_argument("--sanitize", action="store_true",
                    help="runtime sanitizer: re-derive pool/page/handle "
                         "invariants from scratch after every tick and "
                         "fail fast on the first drift (page double-lease, "
                         "orphaned pages, live-bytes drift, leaked event "
                         "buffers) instead of serving corrupt state")
    args = ap.parse_args()

    if args.scheduler:
        serve_scheduled(args)
    elif args.stream:
        serve_stream(args)
    else:
        serve_once(args)


if __name__ == "__main__":
    main()
